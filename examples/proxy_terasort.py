"""Paper reproduction, single workload: Proxy TeraSort vs 'Hadoop' TeraSort.

Mirrors the paper's §3 through the unified execution API: profile the
original at scale, emit the Table-3 proxy as a versioned spec, load it
back, run it on the ``openmp`` and ``hadoop`` stacks via the uniform
``Stack.run()`` contract, auto-tune over the pytree parameter space, and
print the Table-6/Fig-5 numbers.

Run:  PYTHONPATH=src python examples/proxy_terasort.py [--scale small|full]
"""

import argparse
import json
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.api import ProxySpec, get_stack
from repro.core import characterize, vector_accuracy
from repro.core.autotune import autotune
from repro.core.metrics import REPORT_METRICS
from repro.core.workloads import PROXY_SPECS, SCALES, workload_step_fn
from repro.data import gen_records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["tiny", "small", "full"])
    args = ap.parse_args()

    print(f"== Hadoop TeraSort ({args.scale}: "
          f"{SCALES[args.scale]['terasort_n']:,} records) ==")
    fn, wargs = workload_step_fn("terasort", args.scale)
    orig = characterize(fn, wargs, name="terasort", execute=True, exec_iters=2)
    print(f"   sort step: {orig.exec_s:.3f} s")

    # Hadoop-substrate run with host-spilled intermediates (the I/O axis)
    keys, _ = gen_records(jax.random.PRNGKey(0), SCALES[args.scale]["terasort_n"])
    rep = get_stack("hadoop").map_reduce(
        lambda c: jnp.sort(c.reshape(-1)), lambda x: jnp.sort(x), keys,
        n_chunks=8)
    print(f"   hadoop-substrate: {rep.wall_s:.2f} s, spill "
          f"{rep.io_bytes/1e6:.0f} MB ({rep.io_bandwidth/1e6:.0f} MB/s)")

    print("== Proxy TeraSort spec round-trip (versioned ProxySpec) ==")
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(PROXY_SPECS["terasort"], f)
    try:
        spec = ProxySpec.load(f.name)
    finally:
        os.unlink(f.name)
    print(f"   spec v{spec.spec_version}: {len(spec.edges)} edges, "
          f"default stack={spec.stack!r}")
    for stack_name in ("openmp", "hadoop"):
        r = get_stack(stack_name).run(spec)
        print(f"   run[{stack_name:7s}] wall={r.wall_s:.3f}s "
              f"io={r.io_bytes/1e3:.0f} kB")

    print("== Auto-tune over the pytree parameter space ==")
    res = autotune(spec.to_benchmark(), orig.metrics, tol=0.15, max_iter=20)
    pp = res.proxy.profile(execute=True, exec_iters=3)
    keys_m = [k for k in REPORT_METRICS if k in orig.metrics]
    acc = vector_accuracy(orig.metrics, pp.metrics, keys=keys_m)
    print(f"   tuned in {res.iterations} iterations; proxy runs "
          f"{pp.exec_s*1e3:.1f} ms")
    print(f"   speedup {orig.exec_s/pp.exec_s:.0f}x   "
          f"avg accuracy {acc['avg']:.3f} "
          f"(paper: 136x-336x at >=90%)")


if __name__ == "__main__":
    main()
