"""Paper reproduction, single workload: Proxy TeraSort vs 'Hadoop' TeraSort.

Mirrors the paper's §3: run the original at full scale (gensort-style
records, sample->partition->sort->count pipeline with Hadoop-style host
spills), then the tuned Table-3 proxy, and print the Table-6/Fig-5 numbers.

Run:  PYTHONPATH=src python examples/proxy_terasort.py [--scale small|full]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import characterize, vector_accuracy
from repro.core.autotune import autotune
from repro.core.metrics import REPORT_METRICS
from repro.core.stacks import hadoop
from repro.core.workloads import SCALES, WORKLOADS, workload_step_fn
from repro.data import gen_records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["tiny", "small", "full"])
    args = ap.parse_args()

    print(f"== Hadoop TeraSort ({args.scale}: "
          f"{SCALES[args.scale]['terasort_n']:,} records) ==")
    fn, wargs = workload_step_fn("terasort", args.scale)
    orig = characterize(fn, wargs, name="terasort", execute=True, exec_iters=2)
    print(f"   sort step: {orig.exec_s:.3f} s")

    # Hadoop-substrate run with host-spilled intermediates (the I/O axis)
    keys, _ = gen_records(jax.random.PRNGKey(0), SCALES[args.scale]["terasort_n"])
    t0 = time.perf_counter()
    _, io_bytes = hadoop(lambda c: jnp.sort(c.reshape(-1)),
                         lambda x: jnp.sort(x), keys, n_chunks=8)
    t = time.perf_counter() - t0
    print(f"   hadoop-substrate: {t:.2f} s, spill {io_bytes/1e6:.0f} MB "
          f"({io_bytes/t/1e6:.0f} MB/s)")

    print("== Proxy TeraSort (Table 3: 70% sort / 10% sampling / 20% graph) ==")
    res = autotune(WORKLOADS["terasort"].make_proxy(), orig.metrics,
                   tol=0.15, max_iter=20)
    pp = res.proxy.profile(execute=True, exec_iters=3)
    keys_m = [k for k in REPORT_METRICS if k in orig.metrics]
    acc = vector_accuracy(orig.metrics, pp.metrics, keys=keys_m)
    print(f"   tuned in {res.iterations} iterations; proxy runs "
          f"{pp.exec_s*1e3:.1f} ms")
    print(f"   speedup {orig.exec_s/pp.exec_s:.0f}x   "
          f"avg accuracy {acc['avg']:.3f} "
          f"(paper: 136x-336x at >=90%)")


if __name__ == "__main__":
    main()
