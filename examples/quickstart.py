"""Quickstart: the dwarf methodology end-to-end in ~a minute (CPU).

  1. profile an original workload (JAX Kmeans)        — 'perf' stage
  2. decompose its HLO cost channels into dwarfs      — hotspot analysis
  3. load the Table-3 proxy from its versioned spec   — proxy construction
  4. run it on a software stack via Stack.run()       — uniform execution
  5. auto-tune over the pytree parameter space        — adjust/feedback
  6. report Eq.1 accuracy + runtime speedup           — Fig.5/Table-6 style

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.api import ProxySpec, get_stack
from repro.core import characterize, decompose_to_dwarfs, vector_accuracy
from repro.core.autotune import autotune
from repro.core.metrics import REPORT_METRICS
from repro.core.workloads import PROXY_SPECS, workload_step_fn


def main():
    print("== 1. profile the original (Hadoop-Kmeans analog, 'small') ==")
    fn, args = workload_step_fn("kmeans", "small")
    orig = characterize(fn, args, name="kmeans", execute=True, exec_iters=2)
    print(f"   exec={orig.exec_s*1e3:.1f} ms  "
          f"flops={orig.metrics['flops']:.3g}  "
          f"AI={orig.metrics['arithmetic_intensity']:.1f}")

    print("== 2. dwarf decomposition (execution-ratio weights) ==")
    for dwarf, w in sorted(decompose_to_dwarfs(orig.report).items(),
                           key=lambda kv: -kv[1]):
        if w > 0.01:
            print(f"   {dwarf:10s} {w:.2f}")

    print("== 3. load the Table-3 proxy from its spec ==")
    spec = ProxySpec.from_json(PROXY_SPECS["kmeans"])
    proxy = spec.to_benchmark()
    print(f"   {spec.name}: v{spec.spec_version}, {len(spec.edges)} edges, "
          f"default stack={spec.stack!r}")

    print("== 4. uniform execution on a software stack ==")
    rep = get_stack(spec.stack).run(spec, rng=jax.random.PRNGKey(0))
    print(f"   run[{spec.stack}] wall={rep.wall_s:.3f}s "
          f"io={rep.io_bytes:.0f} B")

    print("== 5. auto-tune over the pytree parameter space "
          "(<=15% deviation) ==")
    res = autotune(proxy, orig.metrics, tol=0.15, max_iter=20)
    print(f"   converged={res.converged} after {res.iterations} iterations "
          f"({res.profiles_run} profiles)")

    print("== 6. validation ==")
    pp = res.proxy.profile(execute=True, exec_iters=2)
    keys = [k for k in REPORT_METRICS if k in orig.metrics]
    acc = vector_accuracy(orig.metrics, pp.metrics, keys=keys)
    print(f"   avg accuracy (Eq.1): {acc['avg']:.3f}")
    print(f"   runtime: original {orig.exec_s*1e3:.1f} ms -> proxy "
          f"{pp.exec_s*1e3:.2f} ms  ({orig.exec_s/pp.exec_s:.0f}x faster)")


if __name__ == "__main__":
    main()
