"""Batched serving example: prefill a batch of prompts, then greedy-decode
with the sharded KV cache — the decode_32k cells lower exactly this step.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch tinyllama-1.1b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import Model
from repro.serve import generate, make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()       # smoke-scale weights on CPU
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len),
                                 0, cfg.vocab)
    max_seq = args.prompt_len + args.new_tokens + (cfg.vision_tokens or 0)

    print(f"{args.arch} (reduced): batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    t0 = time.perf_counter()
    out = generate(model, params, prompts, max_new=args.new_tokens,
                   max_seq=max_seq)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.new_tokens
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")
    print("first sequence:", list(map(int, out[0][:12])), "...")


if __name__ == "__main__":
    main()
