"""End-to-end training driver: a ~100M-param dense LM with the production
stack — AdamW+ZeRO states, microbatch accumulation, atomic checkpointing,
restart-from-latest, straggler monitoring — on whatever devices exist.

Default runs a ~20M model for 60 steps (a few minutes on 1 CPU core);
--preset 100m trains the ~100M config for --steps steps.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60] [--preset 20m]
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.fault_tolerance import ResilientTrainLoop
from repro.data import gen_text_tokens
from repro.models import Model
from repro.train import AdamWConfig, TrainOptions, init_state, make_train_step

PRESETS = {
    "20m": ArchConfig(name="lm-20m", family="dense", n_layers=8, d_model=256,
                      n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192,
                      rope_theta=1e4, dtype="float32"),
    "100m": ArchConfig(name="lm-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                       vocab=32000, rope_theta=1e4, dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")
    model = Model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        TrainOptions(accum=2)))

    def batch_fn(step):
        rng = jax.random.PRNGKey(step)            # deterministic replay
        toks = gen_text_tokens(rng, args.batch * (args.seq + 1), cfg.vocab
                               ).reshape(args.batch, args.seq + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = ResilientTrainLoop(step_fn, ckpt_dir,
                                  ckpt_every=args.ckpt_every)
        result = loop.run(state, batch_fn, num_steps=args.steps)
    hist = result.metrics_history
    print(f"steps={len(hist)} restarts={result.restarts} "
          f"stragglers_flagged={len(result.straggler_reports)}")
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(ce {hist[0]['ce']:.3f} -> {hist[-1]['ce']:.3f})")
    assert hist[-1]["loss"] < hist[0]["loss"], "training did not learn"
    print("OK: loss decreased.")


if __name__ == "__main__":
    main()
