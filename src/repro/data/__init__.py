from .generators import (gen_graph, gen_images, gen_matrix, gen_records,
                         gen_sparse_csr, gen_text_tokens, host_spill_bytes)

__all__ = ["gen_graph", "gen_images", "gen_matrix", "gen_records",
           "gen_sparse_csr", "gen_text_tokens", "host_spill_bytes"]
