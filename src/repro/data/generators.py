"""Data generation tools (paper Fig. 3 — BDGS analog).

Text / matrix / graph / record generators with controllable distribution
parameters, so proxies consume data of the same type and distribution as the
original workloads (paper §2.4: "The input data to each proxy benchmark has
the same data type and distribution").
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def gen_records(rng: jax.Array, n: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """gensort analog: (keys, payload) uint32 records for TeraSort."""
    k1, k2 = jax.random.split(rng)
    keys = jax.random.bits(k1, (n,), jnp.uint32)
    payload = jax.random.bits(k2, (n,), jnp.uint32)
    return keys, payload


def gen_matrix(rng: jax.Array, rows: int, cols: int,
               sparsity: float = 0.0) -> jnp.ndarray:
    """Vector/matrix data with a controllable fraction of zero elements."""
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (rows, cols), jnp.float32)
    if sparsity > 0.0:
        mask = jax.random.uniform(k2, (rows, cols)) >= sparsity
        x = x * mask
    return x


def gen_sparse_csr(rng: jax.Array, rows: int, cols: int, sparsity: float
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """CSR-like (col_idx, values) with a *static* nnz-per-row = cols*(1-s).

    Sparsity changes the shapes (and therefore every cost channel), matching
    the paper's observation that input sparsity halves memory bandwidth.
    """
    nnz = max(1, int(round(cols * (1.0 - sparsity))))
    k1, k2 = jax.random.split(rng)
    idx = jax.random.randint(k1, (rows, nnz), 0, cols)
    vals = jax.random.normal(k2, (rows, nnz), jnp.float32)
    return idx, vals


def gen_graph(rng: jax.Array, n_edges: int, n_vertices: int,
              powerlaw: float = 1.2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Edge list with power-law-ish degree distribution (BDGS graph data)."""
    k1, k2 = jax.random.split(rng)
    u = jax.random.uniform(k1, (n_edges,))
    # inverse-CDF sample of a truncated zipf over vertex ids
    src = (n_vertices * u ** powerlaw).astype(jnp.int32) % n_vertices
    dst = jax.random.randint(k2, (n_edges,), 0, n_vertices)
    return src, dst


def gen_text_tokens(rng: jax.Array, n: int, vocab: int,
                    zipf_a: float = 1.1) -> jnp.ndarray:
    """Zipf-distributed token ids (wikipedia-ish text for LM pipelines)."""
    u = jax.random.uniform(rng, (n,), minval=1e-6)
    ranks = (u ** (-1.0 / (zipf_a - 1.0 + 1e-6))).astype(jnp.int32)
    return jnp.clip(ranks, 0, vocab - 1)


def gen_images(rng: jax.Array, batch: int, h: int, w: int) -> jnp.ndarray:
    """Smooth random images (low-frequency content like natural photos)."""
    base = jax.random.normal(rng, (batch, h // 4, w // 4), jnp.float32)
    img = jax.image.resize(base, (batch, h, w), "bilinear")
    return img


def host_spill_bytes(*arrays) -> float:
    """Bytes of a host round trip for the given arrays (I/O accounting)."""
    return float(sum(np.asarray(a).nbytes for a in arrays)) * 2.0
