"""Qwen2-VL-2B [arXiv:2409.12191; hf] — M-RoPE, dynamic resolution.

Vision tower is a stub: 256 precomputed patch embeddings prefix the
sequence; M-RoPE position ids (t/h/w streams) arrive as inputs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151936, qkv_bias=True, rope_theta=1e6, pattern=("attn",),
    vision_tokens=256, mrope=True)
