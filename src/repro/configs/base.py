"""Architecture config system.

One ``ArchConfig`` per assigned architecture (exact numbers from the public
sources listed in the brief).  ``reduced()`` yields the same family at smoke
scale for CPU tests; the full config is only ever lowered AOT (dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False

    # block pattern: layer types within one scanned block (see models/blocks).
    # n_layers must be divisible by len(pattern); the stack is
    # n_layers//len(pattern) scanned repetitions of the pattern.
    pattern: Tuple[str, ...] = ("attn",)

    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba) / xLSTM
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    chunk: int = 256               # chunkwise-scan length for ssm/mlstm

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0           # fixed frame count from the (stub) frontend

    # VLM
    vision_tokens: int = 0
    mrope: bool = False

    # numerics / technique knobs
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "none"     # none | dots  (what the block remat saves)
    logits_fp32: bool = True

    # distribution hints (set per-run by the launcher, not per-arch):
    # batch axes for activation constraints, and the mesh axis used for
    # sequence-parallel attention when n_heads % tp != 0 (head-replication
    # would otherwise compute attention redundantly on every model shard).
    mesh_batch_axes: Optional[Tuple[str, ...]] = None
    attn_seq_shard: Optional[str] = None
    # group-local MoE routing: tokens are routed within dp-local groups with
    # per-group capacity, so dispatch/combine (and their grads) never cross
    # data shards; 0 = single global group.
    moe_groups: int = 0
    # expert-parallel mode: experts sharded over 'model' (requires
    # moe_experts % tp == 0); else per-expert tensor parallelism.
    moe_ep: bool = False

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(f"{self.name}: n_layers {self.n_layers} not "
                             f"divisible by pattern {len(self.pattern)}")

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def attention_free(self) -> bool:
        return all(t in ("mlstm", "slstm") for t in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: recurrent/hybrid sequence mixing."""
        return any(t in ("mlstm", "slstm", "mamba", "mamba_moe")
                   for t in self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        qkv = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.qkv_bias:
            qkv += (self.n_heads + 2 * self.n_kv_heads) * hd
        mlp = 3 * d * ff
        moe = self.moe_experts * 3 * d * ff + d * self.moe_experts
        di, st = self.d_inner, self.ssm_state
        mamba = (d * 2 * di + di * (self.ssm_conv + 2 * st + 1)
                 + (di // 16 + 1) * di + di * st + di + di * d)
        mlstm = d * 2 * d + 3 * d * self.n_heads * self.hd_x() \
            + d * 2 * self.n_heads + d * d
        slstm = d * 4 * d + 4 * self.hd_x() * d + 2 * d
        per_type = {
            "attn": qkv + mlp + 2 * d,
            "attn_enc": qkv + mlp + 2 * d,
            "attn_cross": 2 * qkv + mlp + 3 * d,
            "attn_moe": qkv + moe + 2 * d,
            "mamba": mamba + d,
            "mamba_moe": mamba + moe + 2 * d,
            "mlstm": mlstm + d,
            "slstm": slstm + d,
        }
        total = sum(per_type[t] for t in self.pattern) * self.n_blocks
        if self.encoder_layers:
            total += self.encoder_layers * (qkv + mlp + 2 * d)
            total += self.encoder_seq * d          # learned enc positions
        total += self.vocab * d                    # embedding
        if not self.tie_embeddings:
            total += self.vocab * d                # lm head
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active params (MoE uses top-k of experts)."""
        if not self.moe_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_moe = self.moe_experts * 3 * d * ff
        active_moe = self.moe_topk * 3 * d * ff
        n_moe_layers = sum(1 for t in self.pattern if t.endswith("moe")) \
            * self.n_blocks
        return int(self.param_count() - n_moe_layers * (dense_moe - active_moe))

    def hd_x(self) -> int:
        """head dim for xLSTM cells (d_model / n_heads)."""
        return self.d_model // self.n_heads

    def reduced(self) -> "ArchConfig":
        """Same family at CPU-smoke scale (tiny layers/width/vocab)."""
        pat = self.pattern
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2 * len(pat),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_topk=min(self.moe_topk, 2) if self.moe_topk else 0,
            # no capacity drops at smoke scale: keeps prefill/decode
            # bit-consistent (drops are load-dependent, GShard semantics)
            moe_capacity_factor=4.0 if self.moe_experts else 1.25,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_seq else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            chunk=16,
            ssm_state=8,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned to every LM arch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_is_supported(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k only for sub-quadratic archs (skip noted in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k dense decode skipped"
    return True, ""
