"""Whisper large-v3 [arXiv:2212.04356; unverified] — enc-dec, conv stub.

Backbone only: 32 encoder + 32 decoder layers, d_model=1280, 20 heads
(MHA: kv=20).  The conv frontend is a stub — input_specs() provides 1500
precomputed frame embeddings.  Decoder layers add cross-attention.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51866, rope_theta=1e4, pattern=("attn_cross",),
    encoder_layers=32, encoder_seq=1500)
