"""Kimi K2 1T-a32b [arXiv:2501.kimi2; unverified] — trillion-param MoE.

61 layers, 384 experts top-8, per-expert d_ff=2048 (paper-table entry).
Brief gives a uniform layer spec; we model all layers as attention+MoE
(the released net keeps the first block dense — noted in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=112, rope_theta=5e4, pattern=("attn_moe",),
    moe_experts=384, moe_topk=8)
