"""Granite MoE 3B-a800m [hf:ibm-granite; hf] — 40 experts top-8.

Per the brief: d_ff=512 is the per-expert hidden size; every layer is MoE.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, rope_theta=1e4, pattern=("attn_moe",),
    moe_experts=40, moe_topk=8)
