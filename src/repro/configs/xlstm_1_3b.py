"""xLSTM 1.3B [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks (7:1).

d_ff=0: xLSTM blocks carry their own 2x up-projection instead of an FFN.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, chunk=256,
    pattern=("mlstm", "mlstm", "mlstm", "mlstm",
             "mlstm", "mlstm", "mlstm", "slstm"))
