"""Architecture registry: one module per assigned architecture."""
from typing import Dict

from .base import SHAPES, ArchConfig, ShapeSpec, cell_is_supported
from . import (granite_moe_3b_a800m, jamba_1_5_large_398b, kimi_k2_1t_a32b,
               phi4_mini_3_8b, qwen2_7b, qwen2_vl_2b, qwen3_4b,
               tinyllama_1_1b, whisper_large_v3, xlstm_1_3b)

ARCHS: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in (
    qwen2_7b, phi4_mini_3_8b, tinyllama_1_1b, qwen3_4b, xlstm_1_3b,
    granite_moe_3b_a800m, kimi_k2_1t_a32b, qwen2_vl_2b, whisper_large_v3,
    jamba_1_5_large_398b)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeSpec", "get_arch",
           "cell_is_supported"]
