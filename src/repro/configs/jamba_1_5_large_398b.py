"""Jamba-1.5-large 398B [arXiv:2403.19887; hf] — Mamba+attn 1:7, MoE 16e top-2.

Block of 8 layers: 1 attention + 7 mamba, MoE on every other layer
(4 of 8), repeated 9x = 72 layers.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, rope_theta=1e4,
    pattern=("attn_moe", "mamba", "mamba_moe", "mamba",
             "mamba_moe", "mamba", "mamba_moe", "mamba"),
    moe_experts=16, moe_topk=2, ssm_state=16, chunk=256)
