"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: jit with production shardings -> .lower() -> .compile() ->
memory_analysis + cost_analysis + HLO collective schedule -> roofline terms.
Results cached as JSON under experiments/dryrun/ (one file per cell); this
is the data EXPERIMENTS.md §Dry-run/§Roofline and the proxy generator read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--force] [--fsdp/--no-fsdp]
"""

import os
import sys

# The production dry-run emulates a 512-chip fleet with host devices, which
# only works if the flag lands before jax initializes.  Gate it to the CLI
# entry point: benchmarks/lm_proxy.py imports this module in-process (to
# regenerate missing cells at reduced scale), and hijacking the caller's
# device count there — or mutating the env after jax is already up — would
# silently change every subsequent jit in the host process.
if __name__ == "__main__" and "jax" not in sys.modules:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, cell_is_supported, get_arch
from ..core.metrics import analyze_hlo_text, roofline_from_report
from ..distributed.sharding import (cache_specs_tree, input_shardings, named,
                                    param_specs)
from ..models.model import Model, cache_specs, input_specs
from ..serve.serve_step import make_decode_step, make_prefill_step
from ..train.optimizer import AdamWConfig
from ..train.train_step import TrainOptions, TrainState, init_state, make_train_step
from .analytic import model_flops
from .mesh import make_host_mesh, make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _sds_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp: bool = True, opts: Optional[TrainOptions] = None,
             remat: bool = True, accum: int = 4,
             vmem_fused: float = 0.0, remat_policy: str = "none",
             reduced: bool = False) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if reduced:
        # CPU-smoke variant (benchmarks/lm_proxy.py regenerates missing
        # cells with this): same record schema and step construction, but
        # the family's ``reduced()`` config, a tiny shape, and whatever
        # devices exist instead of the 512-chip fleet emulation.
        cfg = cfg.reduced()
        shape = dataclasses.replace(shape, seq_len=64, global_batch=2)
        accum = 1
    if cfg.remat != remat or cfg.remat_policy != remat_policy:
        cfg = dataclasses.replace(cfg, remat=remat, remat_policy=remat_policy)
    ok, why = cell_is_supported(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "fsdp": fsdp, "reduced": reduced,
           "params_total": cfg.param_count(),
           "params_active": cfg.active_param_count()}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    if reduced:
        mesh = make_host_mesh(1, 1)
        tp = dp_total = 1
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        tp = 16
        dp_total = 32 if multi_pod else 16
    chips = mesh.size
    if opts is None:
        opts = TrainOptions(accum=accum,
                            batch_axes=(("pod", "data") if multi_pod
                                        else ("data",)))
    cfg = dataclasses.replace(
        cfg, mesh_batch_axes=opts.batch_axes,
        attn_seq_shard=("model" if cfg.n_heads % tp != 0 else None),
        moe_groups=dp_total,
        moe_ep=bool(cfg.moe_experts) and cfg.moe_experts % tp == 0)
    model = Model(cfg, batch_axes=opts.batch_axes)
    specs = input_specs(cfg, shape)
    t0 = time.perf_counter()

    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = param_specs(params_sds, mesh, fsdp=fsdp, cfg=cfg)
    pshard = named(pspecs, mesh)
    in_sh = input_shardings(cfg, specs, mesh)

    if shape.kind == "train":
        state_sds = jax.eval_shape(
            lambda: init_state(model, jax.random.PRNGKey(0)))
        opt_sh = {"mu": pshard, "nu": pshard, "master": pshard}
        state_sh = TrainState(params=pshard, opt=opt_sh,
                              step=jax.NamedSharding(
                                  mesh, jax.sharding.PartitionSpec()))
        step_fn = make_train_step(model, AdamWConfig(), opts)
        jfn = jax.jit(step_fn, in_shardings=(state_sh, in_sh),
                      donate_argnums=(0,))
        args = (state_sds, specs)
    elif shape.kind == "prefill":
        cache_sds = cache_specs(cfg, shape)
        cache_sh = named(cache_specs_tree(cfg, cache_sds, mesh), mesh)
        fn = make_prefill_step(model)
        jfn = jax.jit(fn, in_shardings=(pshard, cache_sh, in_sh),
                      donate_argnums=(1,))
        args = (params_sds, cache_sds, specs)
    else:  # decode
        cache_sds = cache_specs(cfg, shape)
        cache_sh = named(cache_specs_tree(cfg, cache_sds, mesh), mesh)
        fn = make_decode_step(model)
        rep = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
        jfn = jax.jit(fn, in_shardings=(pshard, cache_sh,
                                        in_sh["tokens"], rep),
                      donate_argnums=(1,))
        args = (params_sds, cache_sds, specs["tokens"], specs["index"])

    with mesh:
        lowered = jfn.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    mem = compiled.memory_analysis()
    text = compiled.as_text()
    report = analyze_hlo_text(text, vmem_bytes=vmem_fused)
    mf = model_flops(cfg, shape)
    roof = roofline_from_report(report, chips, mf)
    xla_cost = {}
    try:
        ca = compiled.cost_analysis()
        xla_cost = {k: float(v) for k, v in ca.items()
                    if k in ("flops", "bytes accessed")}
    except Exception:
        pass

    rec.update({
        "status": "ok",
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "chips": chips,
        "model_flops": mf,
        "bytes_per_device": {
            "args": float(mem.argument_size_in_bytes),
            "temp": float(mem.temp_size_in_bytes),
            "out": float(mem.output_size_in_bytes),
            "peak": float(mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes),
        },
        "fits_16GB": bool(mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes < 16e9),
        "hlo_lines": text.count("\n"),
        "xla_cost_uncorrected": xla_cost,
        "report": report.to_json(),
        "roofline": roof.to_json(),
    })
    return rec


def cell_path(arch, shape, mesh_name, tag="") -> Path:
    return OUT_DIR / f"{arch}__{shape}__{mesh_name}{tag}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--tag", default="", help="suffix for perf-variant runs")
    ap.add_argument("--no-remat", dest="remat", action="store_false")
    ap.add_argument("--vmem-fused", type=float, default=0.0,
                    help="VMEM budget (bytes) for fused-kernel accounting")
    ap.add_argument("--remat-policy", default="none", choices=["none", "dots"])
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                path = cell_path(arch, shape, mesh_name, args.tag)
                if path.exists() and not args.force:
                    print(f"[cached] {path.name}")
                    continue
                t0 = time.perf_counter()
                try:
                    rec = run_cell(arch, shape, multi, fsdp=args.fsdp,
                                   remat=args.remat,
                                   vmem_fused=args.vmem_fused,
                                   remat_policy=args.remat_policy)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                rec["wall_s"] = time.perf_counter() - t0
                path.write_text(json.dumps(rec, indent=1))
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']} step={r['step_time_s']:.4f}s"
                             f" mfu={r['mfu']:.3f}"
                             f" peak={rec['bytes_per_device']['peak']/1e9:.1f}GB")
                if st == "error":
                    extra = " " + rec["error"][:160]
                print(f"[{st}] {arch} {shape} {mesh_name}"
                      f" ({rec['wall_s']:.0f}s){extra}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
