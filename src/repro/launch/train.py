"""Production training launcher: ``--arch <id>`` + mesh + resilient loop.

On real hardware this runs under ``jax.distributed`` with the production
mesh from mesh.py; on this container it runs reduced configs on the host
devices (the full configs are exercised AOT by dryrun.py).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 50 --reduced --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_arch
from ..data import gen_text_tokens
from ..distributed.fault_tolerance import ResilientTrainLoop
from ..models import Model
from ..train import AdamWConfig, TrainOptions, init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-sized)")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params on "
          f"{len(jax.devices())} device(s)")
    model = Model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(
        model,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps),
        TrainOptions(accum=args.accum, compress_grads=args.compress_grads)))

    def batch_fn(step):
        rng = jax.random.PRNGKey(step)
        toks = gen_text_tokens(rng, args.batch * (args.seq + 1), cfg.vocab
                               ).reshape(args.batch, args.seq + 1)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.vision_tokens:
            b = args.batch
            batch["vision_embeds"] = jnp.zeros(
                (b, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
            total = args.seq + cfg.vision_tokens
            batch["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(total)[None, None], (b, 3, total)).astype(jnp.int32)
            batch["labels"] = jnp.pad(batch["labels"],
                                      ((0, 0), (cfg.vision_tokens, 0)))
        if cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return batch

    loop = ResilientTrainLoop(step_fn, args.ckpt_dir,
                              ckpt_every=args.ckpt_every)
    result = loop.run(state, batch_fn, num_steps=args.steps)
    h = result.metrics_history
    print(f"done: steps={len(h)} restarts={result.restarts} "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
