"""Render EXPERIMENTS.md roofline tables from cached dry-run JSONs."""

from __future__ import annotations

import json
import sys
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _load(tag: str):
    cells = {}
    for f in sorted(DRYRUN.glob("*.json")):
        stem = f.stem
        if tag and not stem.endswith(tag):
            continue
        if not tag and ("_opt" in stem):
            continue
        d = json.loads(f.read_text())
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def roofline_table(tag: str = "", mesh: str = "16x16") -> str:
    cells = _load(tag)
    out = ["| arch | shape | dom | compute s | memory s | coll s | "
           "step s | MFU | useful | peak GB | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), d in sorted(cells.items()):
        if m != mesh:
            continue
        if d["status"] == "skipped":
            out.append(f"| {arch} | {shape} | — | — | — | — | — | — | — | — "
                       f"| skip: sub-quadratic-only shape |")
            continue
        if d["status"] != "ok":
            out.append(f"| {arch} | {shape} | ERROR | | | | | | | | |")
            continue
        r = d["roofline"]
        out.append(
            f"| {arch} | {shape} | {r['dominant'][:4]} | "
            f"{r['compute_s']:.2f} | {r['memory_s']:.2f} | "
            f"{r['collective_s']:.2f} | {r['step_time_s']:.2f} | "
            f"{r['mfu']:.3f} | {r['useful_flops_ratio']:.2f} | "
            f"{d['bytes_per_device']['peak'] / 1e9:.1f} | "
            f"{'Y' if d['fits_16GB'] else 'N'} |")
    return "\n".join(out)


def dryrun_summary(tag: str = "") -> str:
    cells = _load(tag)
    n_ok = sum(1 for d in cells.values() if d["status"] == "ok")
    n_skip = sum(1 for d in cells.values() if d["status"] == "skipped")
    lines = [f"cells={len(cells)} ok={n_ok} skipped={n_skip} "
             f"errors={len(cells) - n_ok - n_skip}"]
    for (arch, shape, m), d in sorted(cells.items()):
        if d["status"] != "ok" or m != "2x16x16":
            continue
        coll = d["report"]["collective_count"]
        lines.append(
            f"  {arch} {shape} {m}: compile={d['compile_s']:.0f}s "
            f"bytes/dev={d['bytes_per_device']['peak']/1e9:.1f}GB "
            f"collectives={{{', '.join(f'{k}:{int(v)}' for k, v in sorted(coll.items()))}}}")
    return "\n".join(lines)


if __name__ == "__main__":
    what = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    tag = sys.argv[2] if len(sys.argv) > 2 else ""
    mesh = sys.argv[3] if len(sys.argv) > 3 else "16x16"
    if what == "roofline":
        print(roofline_table(tag, mesh))
    else:
        print(dryrun_summary(tag))
