"""Analytic MODEL_FLOPS per (arch, shape) — the roofline 'useful compute'.

Conventions:
  * N_eff = active params excluding the input embedding table (gather).
  * train  : 6 * N_eff * tokens  (+ attention term, x3 for fwd+bwd)
  * prefill: 2 * N_eff * tokens  (+ attention term)
  * decode : per-step — 2 * N_eff * B + attention-cache reads 4*B*S*H*hd
    per attention layer.
MoE uses 6 * N_active * D per the brief.
"""

from __future__ import annotations

from ..configs.base import ArchConfig, ShapeSpec


def _n_attn_layers(cfg: ArchConfig) -> int:
    per_block = sum(1 for t in cfg.pattern if t.startswith("attn"))
    n = per_block * cfg.n_blocks
    return n


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    n_eff = cfg.active_param_count() - cfg.vocab * cfg.d_model
    d_attn = cfg.n_heads * cfg.hd
    n_attn = _n_attn_layers(cfg)
    if shape.kind == "train":
        tokens = B * S
        attn = 2.0 * B * S * S * d_attn * n_attn      # causal-halved qk+av
        return 6.0 * n_eff * tokens + 3.0 * attn
    if shape.kind == "prefill":
        tokens = B * S
        attn = 2.0 * B * S * S * d_attn * n_attn
        return 2.0 * n_eff * tokens + attn
    # decode: one token per sequence against an S-deep cache
    attn = 4.0 * B * S * d_attn * n_attn
    if cfg.is_encdec:
        attn += 4.0 * B * cfg.encoder_seq * d_attn * n_attn
    return 2.0 * n_eff * B + attn
