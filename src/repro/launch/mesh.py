"""Production mesh factory.

Function (not module constant) so importing never touches jax device state.
Single pod: 16x16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); 'pod' is a pure
data-parallel axis (weights replicated across pods, gradients all-reduced
across the slower inter-pod links once per step).
"""

from __future__ import annotations

import jax


def _axis_type_kw(n: int) -> dict:
    # AxisType only exists on newer jax; older versions default to Auto.
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kw(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh(
        (data, model), ("data", "model"), **_axis_type_kw(2))
