"""repro: dwarf-based scalable benchmarking methodology on a multi-pod JAX
LM framework (see DESIGN.md)."""

__version__ = "0.1.0"
