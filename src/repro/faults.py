"""Deterministic fault injection for resilience testing (`repro.faults`).

A benchmark that stands in for a production service must also stand in
for production *failure*: executors die mid-batch, stragglers hold a
dispatch hostage, cache pressure evicts hot executables at the worst
moment.  This module makes those events first-class and — critically —
**seeded**: a :class:`FaultPlan` precomputes every fault decision from
``(seed, n)`` up front, so a chaos run is bit-reproducible.  The serving
engine consults the plan with pure lookups (``fail_attempts``,
``straggler_delay_s``, ``evicts``) — no RNG is drawn at serve time, which
is what lets the virtual clock report identical percentiles for the same
chaos plan on any machine, any number of times.

Fault kinds (all keyed by request index ``rid``):

* **failure** — the executor raises :class:`InjectedFailure` for the
  first ``fail_attempts(rid)`` dispatch attempts of that request, then
  succeeds (the transient-fault model).  ``poison`` rids fail on *every*
  attempt — the request that must be isolated by chunk bisection and
  terminally failed without taking its batch down.
* **straggler** — the request's dispatch is delayed by
  ``straggler_delay_s(rid)`` (a slow host / late shard), charged to its
  chunk's service time under both clocks.
* **eviction storm** — before serving ``rid``, every compiled executable
  of the serving stack is evicted (cache-pressure chaos); the next
  dispatch re-compiles (wall clock) or pays the modeled cold overhead
  (virtual clock).

This module also absorbs the fault primitives that previously lived in
``repro.distributed.fault_tolerance`` (:class:`InjectedFailure`,
:class:`StragglerMonitor`, :class:`StragglerReport`); that module keeps
deprecation shims.  No jax imports here: the plan must be constructible
anywhere without initializing a backend.
"""

from __future__ import annotations

import dataclasses
import os
import statistics
from typing import Dict, FrozenSet, List, Optional, Sequence

import numpy as np


class InjectedFailure(RuntimeError):
    """Stands in for a dead host / preempted slice in tests and chaos
    runs (moved here from ``repro.distributed.fault_tolerance``)."""


def default_fault_rate() -> float:
    """Process-wide chaos knob (``REPRO_FAULT_RATE`` env var, default 0):
    the injected executor-failure rate the benchmark serve smoke runs
    under — CI's ``chaos`` matrix leg sets it non-zero."""
    raw = os.environ.get("REPRO_FAULT_RATE")
    if raw is None or raw.strip() == "":
        return 0.0
    return max(0.0, float(raw))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, fully-precomputed chaos schedule over ``n`` requests.

    Build one with :meth:`sample` (rates -> deterministic rid sets) or
    directly from explicit per-rid tables.  Frozen: a plan can be shared
    across serve runs and threads, and two runs under the same plan see
    byte-identical fault decisions.
    """

    seed: int = 0
    #: rid -> number of leading dispatch attempts that raise
    failures: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: rids that fail on *every* attempt (terminal after retry budget)
    poison: FrozenSet[int] = frozenset()
    #: rid -> artificial dispatch delay in seconds
    stragglers: Dict[int, float] = dataclasses.field(default_factory=dict)
    #: rids whose dispatch is preceded by an executable-eviction storm
    evictions: FrozenSet[int] = frozenset()

    @classmethod
    def sample(cls, n: int, seed: int = 0, *,
               failure_rate: float = 0.0,
               straggler_rate: float = 0.0,
               eviction_rate: float = 0.0,
               fail_attempts: int = 1,
               straggler_delay_s: float = 0.005,
               poison: Sequence[int] = ()) -> "FaultPlan":
        """Draw a deterministic plan: each of the ``n`` rids independently
        fails / straggles / triggers an eviction storm at the given rates,
        all from one ``numpy.random.RandomState(seed)`` stream (so the
        same ``(n, seed, rates)`` always yields the same plan)."""
        rs = np.random.RandomState(seed)
        draws = rs.uniform(size=(3, max(n, 1)))
        delays = rs.uniform(0.5, 1.5, size=max(n, 1)) * straggler_delay_s
        failures = {i: int(fail_attempts) for i in range(n)
                    if draws[0, i] < failure_rate}
        stragglers = {i: float(delays[i]) for i in range(n)
                      if draws[1, i] < straggler_rate}
        evictions = frozenset(i for i in range(n)
                              if draws[2, i] < eviction_rate)
        return cls(seed=seed, failures=failures,
                   poison=frozenset(int(p) for p in poison),
                   stragglers=stragglers, evictions=evictions)

    # -- pure lookups (no state, no RNG) -------------------------------------

    def fail_attempts(self, rid: int) -> int:
        """Leading attempts of ``rid`` that must raise (poison = all)."""
        if rid in self.poison:
            return 1 << 30
        return self.failures.get(rid, 0)

    def should_fail(self, rid: int, attempt: int) -> bool:
        """Whether dispatch ``attempt`` (0-based) of ``rid`` raises."""
        return attempt < self.fail_attempts(rid)

    def straggler_delay_s(self, rid: int) -> float:
        return self.stragglers.get(rid, 0.0)

    def evicts(self, rid: int) -> bool:
        return rid in self.evictions

    @property
    def empty(self) -> bool:
        return not (self.failures or self.poison or self.stragglers
                    or self.evictions)

    def summary(self) -> Dict[str, int]:
        return {"failure_rids": len(self.failures),
                "poison_rids": len(self.poison),
                "straggler_rids": len(self.stragglers),
                "eviction_rids": len(self.evictions)}


# ---------------------------------------------------------------------------
# straggler monitoring (absorbed from distributed.fault_tolerance)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    median: float
    action: str


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x running median.

    Mitigation hook: on TPU pods the actionable responses are (1) re-dispatch
    the straggler's microbatches to its DP peers for this step (collective-
    free: grad contribution re-weighted), or (2) mark the host for
    replacement at the next checkpoint boundary.  Here the hook records the
    decision; the re-dispatch itself needs a real multi-host runtime.
    """

    def __init__(self, threshold: float = 2.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.reports: List[StragglerReport] = []

    def observe(self, step: int, step_time: float) -> Optional[StragglerReport]:
        self.times.append(step_time)
        self.times = self.times[-self.window:]
        if len(self.times) < 5:
            return None
        med = statistics.median(self.times)
        if step_time > self.threshold * med:
            rep = StragglerReport(step, step_time, med,
                                  "re-dispatch microbatches to DP peers")
            self.reports.append(rep)
            return rep
        return None
