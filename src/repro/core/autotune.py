"""Auto-tuning tool (paper §2.3, Fig. 4): parameter initialization →
adjusting stage (impact analysis / decision mechanism) → feedback stage.

The paper "learns the impact of each parameter on all metrics and builds a
decision tree" by changing one parameter at a time and re-executing.  We do
the same with log-space elasticities over the proxy's **pytree parameter
space** (:class:`repro.api.ParamSpace`): every tunable — the Table-2 fields
plus numeric per-component extras — is one named, bounded leaf of a flat
vector.  For each leaf we probe a x2 change and record
d(log metric)/d(log param) for every metric.  The adjusting stage then
picks, for the worst-deviating metric, the leaf with the strongest
corrective elasticity (penalizing collateral damage to already-satisfied
metrics), computes the multiplicative step that the linear model predicts
closes the gap, and the feedback stage re-measures.  Converged when every
tracked metric deviates ≤ tol (paper default 15%).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..api.params import ParamSpace
from .metrics import vector_accuracy
from .proxy import ProxyBenchmark

# Structural (size-independent) metrics tuned without executing the proxy.
# Rates (mips / mem_bw) follow once intensity and mix match; a second tuning
# pass with execute=True can target them directly if needed.
DEFAULT_METRICS = (
    "arithmetic_intensity", "vpu_share",
    "mix_dot", "mix_sort", "mix_gather_scatter", "mix_reduce",
    "mix_rng", "mix_fft", "mix_logic", "mix_compare_select", "mix_elementwise",
)

DEFAULT_WEIGHTS = {"arithmetic_intensity": 3.0, "vpu_share": 1.5,
                   "mix_dot": 2.0}


@dataclasses.dataclass
class TuneStep:
    iteration: int
    worst_metric: str
    deviation_before: float
    param: str                    # ParamSpace leaf name, e.g. "e1.quick_sort.weight"
    old_value: float
    new_value: float
    avg_accuracy_after: float


@dataclasses.dataclass
class TuneResult:
    proxy: ProxyBenchmark
    converged: bool
    iterations: int
    profiles_run: int
    initial_accuracy: Dict[str, float]
    final_accuracy: Dict[str, float]
    history: List[TuneStep]
    sensitivity: Dict[str, Dict[str, float]]   # leaf name -> metric -> elasticity

    def summary(self) -> str:
        rows = [f"autotune[{self.proxy.name}]: converged={self.converged} "
                f"iters={self.iterations} profiles={self.profiles_run} "
                f"avg_acc {self.initial_accuracy.get('avg', 0):.3f} -> "
                f"{self.final_accuracy.get('avg', 0):.3f}"]
        for s in self.history:
            rows.append(
                f"  it{s.iteration:02d} worst={s.worst_metric}"
                f"(dev {s.deviation_before:+.2f}) adjust {s.param} "
                f"{s.old_value:g}->{s.new_value:g}"
                f" => avg_acc {s.avg_accuracy_after:.3f}")
        return "\n".join(rows)


def _is_share(k: str) -> bool:
    return k.startswith("mix_") or k in ("vpu_share", "coll_share")


def coerce_target(target) -> Dict[str, float]:
    """Normalize a tuner target to a metric dict.

    Every tuner (:class:`AutoTuner`, :class:`PopulationTuner`,
    :class:`~repro.core.structsearch.StructuralTuner`) accepts either a
    hand-declared Table-3 metric dict or any measurement with a
    ``metrics()`` method — in particular a
    :class:`~repro.core.engine.WorkloadFingerprint` — so
    ``tune_structure(proxy, target=fingerprint(fn, args))`` distills a
    proxy straight from a measurement with no hand-modeling step.
    """
    if isinstance(target, dict):
        return target
    m = getattr(target, "metrics", None)
    if callable(m):
        return m()
    raise TypeError(
        f"tuner target must be a metric dict or an object with a "
        f".metrics() method (e.g. WorkloadFingerprint); got "
        f"{type(target).__name__}")


def _deviations(target: Dict[str, float], proxy: Dict[str, float],
                keys: Sequence[str]) -> Dict[str, float]:
    """Share metrics deviate in absolute share points; others relatively."""
    devs = {}
    for k in keys:
        h, p = target.get(k, 0.0), proxy.get(k, 0.0)
        if _is_share(k):
            devs[k] = p - h
            continue
        if abs(h) < 1e-12 and abs(p) < 1e-12:
            continue
        devs[k] = (p - h) / h if abs(h) > 1e-12 else math.inf
    return devs


class AutoTuner:
    def __init__(self, target_metrics: Dict[str, float],
                 metric_keys: Sequence[str] = DEFAULT_METRICS,
                 tol: float = 0.15, max_iter: int = 40,
                 execute: bool = False,
                 weights: Optional[Dict[str, float]] = None,
                 measurement: str = "engine"):
        target_metrics = coerce_target(target_metrics)
        self.target = target_metrics
        self.keys = [k for k in metric_keys if abs(target_metrics.get(k, 0.0)) > 1e-12]
        self.tol = tol
        self.max_iter = max_iter
        self.execute = execute
        self.weights = dict(DEFAULT_WEIGHTS) if weights is None else weights
        if measurement not in ("engine", "profile"):
            raise ValueError(f"measurement must be 'engine' or 'profile', "
                             f"got {measurement!r}")
        self.measurement = measurement
        self.profiles_run = 0

    # -- measurement ---------------------------------------------------------

    def _measure(self, proxy: ProxyBenchmark) -> Dict[str, float]:
        """One adjust/feedback measurement.

        ``measurement="engine"`` (default) goes through the compile-once
        :mod:`repro.core.engine`: stepping a dynamic param (weight, shape-
        free extras) between measurements triggers zero retraces, so sweep
        cost no longer scales with compile time.  ``"profile"`` is the
        legacy whole-program lower+compile per measurement (kept as the
        baseline the engine benchmarks compare against).
        """
        self.profiles_run += 1
        if self.measurement == "profile":
            prof = proxy.profile(execute=self.execute, exec_iters=1)
            return prof.metrics
        from .engine import measure
        return measure(proxy.dag, execute=self.execute, exec_iters=1)

    # -- impact analysis (the "decision tree" learning pass) ------------------

    def _learn_sensitivity(self, proxy: ProxyBenchmark, space: ParamSpace,
                           vec: np.ndarray, base: Dict[str, float]
                           ) -> Dict[str, Dict[str, float]]:
        table: Dict[str, Dict[str, float]] = {}
        for li, leaf in enumerate(space.leaves):
            old = float(vec[li])
            if old <= 0:   # pruned edge: probe re-enabling it
                old = 1.0
            probe = min(max(old * 2.0, leaf.lo), leaf.hi)
            if probe == old:
                probe = max(old / 2.0, leaf.lo)
            if probe == old:
                continue
            trial = proxy.clone()
            trial_vec = vec.copy()
            trial_vec[li] = probe
            space.apply(trial.dag, trial_vec)
            m = self._measure(trial)
            dlogp = math.log(probe / old)
            elast = {}
            for k in self.keys:
                b, t = base.get(k, 0.0), m.get(k, 0.0)
                if _is_share(k):
                    # share metrics: linear sensitivity d(share)/d(log p)
                    elast[k] = (t - b) / dlogp
                elif b > 1e-12 and t > 1e-12:
                    elast[k] = math.log(t / b) / dlogp
                elif b <= 1e-12 and t > 1e-12:
                    elast[k] = 10.0   # parameter can *create* this metric
                else:
                    elast[k] = 0.0
            table[leaf.name] = elast
        return table

    # -- adjusting stage -------------------------------------------------------

    def _pick_adjustment(self, sens, devs, satisfied, banned
                         ) -> Optional[Tuple[str, str, float]]:
        """Pick (metric, leaf name, step-ratio): try metrics worst-first so a
        banned/exhausted worst metric doesn't stall the whole loop."""
        for worst in sorted(devs, key=lambda k: -abs(devs[k])):
            if abs(devs[worst]) <= self.tol:
                break
            is_mix = _is_share(worst)
            best_leaf, best_score, best_ratio = None, 0.0, 1.0
            for leaf_name, elast in sens.items():
                e = elast.get(worst, 0.0)
                if abs(e) < (0.02 if is_mix else 0.05):
                    continue
                dev = devs[worst]
                if is_mix:
                    want = -dev / e                     # linear share model
                else:
                    want = -math.log1p(max(min(dev, 8.0), -0.95)) / e
                direction = 1 if want > 0 else -1
                if (leaf_name, worst, direction) in banned:
                    continue
                collateral = sum(abs(elast.get(k, 0.0)) for k in satisfied)
                score = abs(e) - 0.25 * collateral
                if score > best_score:
                    # big gaps may take up-to-x8 steps; damp by 0.8 vs model
                    big = abs(dev) > (0.3 if is_mix else 0.75)
                    cap = math.log(8.0) if big else math.log(2.0)
                    ratio = math.exp(max(min(want * 0.8, cap), -cap))
                    best_leaf, best_score, best_ratio = leaf_name, score, ratio
            if best_leaf is not None:
                return worst, best_leaf, best_ratio
        return None

    # -- main loop -------------------------------------------------------------

    def tune(self, proxy: ProxyBenchmark) -> TuneResult:
        proxy = proxy.clone()
        space = ParamSpace.from_dag(proxy.dag)
        vec = space.values(proxy.dag)
        base = self._measure(proxy)
        init_acc = vector_accuracy(self.target, base, self.keys, self.weights)
        sens = self._learn_sensitivity(proxy, space, vec, base)
        history: List[TuneStep] = []
        best = (init_acc, proxy.clone())
        banned: set = set()
        cur = base
        it = 0
        for it in range(1, self.max_iter + 1):
            devs = _deviations(self.target, cur, self.keys)
            if not devs or all(abs(d) <= self.tol for d in devs.values()):
                acc = vector_accuracy(self.target, cur, self.keys, self.weights)
                return TuneResult(proxy, True, it - 1, self.profiles_run,
                                  init_acc, acc, history, sens)
            satisfied = [k for k, d in devs.items() if abs(d) <= self.tol]
            pick = self._pick_adjustment(sens, devs, satisfied, banned)
            if pick is None:
                break
            worst, leaf_name, ratio = pick
            li = space.index_of(leaf_name)
            leaf = space.leaves[li]
            old = float(vec[li])
            new = min(max(max(old, leaf.lo if old <= 0 else old) * ratio,
                          leaf.lo), leaf.hi)
            if leaf.integer:
                new = float(round(new))
            if new == old:
                banned.add((leaf_name, worst, 1 if ratio > 1 else -1))
                continue
            acc_before = vector_accuracy(self.target, cur, self.keys,
                                         self.weights)["avg"]
            vec[li] = new
            space.apply(proxy.dag, vec)
            cur_new = self._measure(proxy)          # feedback stage
            acc = vector_accuracy(self.target, cur_new, self.keys, self.weights)
            history.append(TuneStep(it, worst, devs[worst], leaf_name,
                                    old, new, acc["avg"]))
            if acc["avg"] < acc_before - 1e-6:
                # regression: revert and prune this decision-tree branch
                # (clamp=False: the prior value may sit outside bounds)
                vec[li] = old
                space.apply(proxy.dag, vec, clamp=False)
                banned.add((leaf_name, worst, 1 if ratio > 1 else -1))
                continue
            cur = cur_new
            if acc["avg"] > best[0]["avg"]:
                best = (acc, proxy.clone())
        final_acc = vector_accuracy(self.target, cur, self.keys, self.weights)
        if best[0]["avg"] > final_acc["avg"]:
            final_acc, proxy = best
        devs = _deviations(self.target, cur, self.keys)
        converged = bool(devs) and all(abs(d) <= self.tol for d in devs.values())
        return TuneResult(proxy, converged, it, self.profiles_run,
                          init_acc, final_acc, history, sens)


def autotune(proxy: ProxyBenchmark, target_metrics: Dict[str, float],
             **kw) -> TuneResult:
    return AutoTuner(target_metrics, **kw).tune(proxy)


# ---------------------------------------------------------------------------
# Population-based tuning (batched autotuning over the dynamic-param axis)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Generation:
    """One population-tuner generation's summary."""

    index: int
    best_accuracy: float          # weighted avg accuracy of the elite
    mean_accuracy: float          # population mean (search health signal)
    best_deviation: float         # worst |deviation| of the elite
    candidates: int               # candidates scored this generation
    #: per-bucket share of the generation's weighted-cost mass (the
    #: stratified schedule the candidates scored/executed under)
    bucket_masses: Optional[List[float]] = None
    #: per-bucket vmapped-while trip bounds (execution cost diagnostics)
    bucket_trips: Optional[List[int]] = None


@dataclasses.dataclass
class PopulationTuneResult:
    proxy: ProxyBenchmark
    converged: bool
    generations: int
    candidates_evaluated: int
    initial_accuracy: Dict[str, float]
    final_accuracy: Dict[str, float]
    final_deviation: float        # worst |deviation| of the returned proxy
    history: List[Generation]

    def summary(self) -> str:
        rows = [f"population_tune[{self.proxy.name}]: "
                f"converged={self.converged} gens={self.generations} "
                f"candidates={self.candidates_evaluated} "
                f"avg_acc {self.initial_accuracy.get('avg', 0):.3f} -> "
                f"{self.final_accuracy.get('avg', 0):.3f} "
                f"worst_dev {self.final_deviation:+.3f}"]
        for g in self.history:
            rows.append(f"  gen{g.index:02d} best_acc={g.best_accuracy:.3f} "
                        f"mean_acc={g.mean_accuracy:.3f} "
                        f"worst_dev={g.best_deviation:+.3f}")
        return "\n".join(rows)


class PopulationTuner:
    """Gradient-free population tuner over a proxy's *dynamic* parameters
    (weights + shape-free extras) — the batched-autotuning counterpart of
    the greedy one-parameter-at-a-time :class:`AutoTuner`.

    Each generation scores a whole candidate batch through the
    compile-once machinery, so tuner throughput no longer pays per
    candidate:

    * **metrics** come from :class:`repro.core.engine.PopulationScorer` —
      the compositional cost model assembled as one numpy matrix product
      over the population (zero executable traces, zero compiles beyond
      what measuring one candidate costs);
    * **outputs** come from one vmapped executable call
      (``Stack.run_population``), used to reject candidates whose
      parameters drive the proxy non-finite — one compile per (structure,
      population size), shared across all generations, candidate axis
      sharded over the stack's mesh.

    The search is random-search seeded (generation 0 is a log-uniform
    ``ParamSpace.sample_dynamic``) followed by a simple evolution strategy
    (CMA-ES style diagonal model): each later generation draws log-normal
    candidates around the elite mean with per-leaf elite sigma, keeps the
    best candidate unchanged (elitism), and re-injects a fresh random
    fraction against premature collapse.  Deterministic for a fixed seed.
    """

    def __init__(self, target_metrics: Dict[str, float],
                 metric_keys: Sequence[str] = DEFAULT_METRICS,
                 tol: float = 0.15,
                 population: int = 16,
                 generations: int = 8,
                 max_candidates: Optional[int] = None,
                 elite_frac: float = 0.25,
                 explore_frac: float = 0.125,
                 sigma_floor: float = 0.05,
                 seed: int = 0,
                 stack: str = "openmp",
                 execute: bool = True,
                 weights: Optional[Dict[str, float]] = None,
                 stratify: bool = True,
                 bucket_size: Optional[int] = None):
        target_metrics = coerce_target(target_metrics)
        self.target = target_metrics
        self.keys = [k for k in metric_keys
                     if abs(target_metrics.get(k, 0.0)) > 1e-12]
        self.tol = tol
        self.population = max(2, int(population))
        self.generations = max(1, int(generations))
        self.max_candidates = max_candidates
        self.elite_frac = elite_frac
        self.elite = max(1, int(round(elite_frac * self.population)))
        self.explore = max(1, int(round(explore_frac * self.population)))
        self.sigma_floor = sigma_floor
        self.seed = seed
        self.stack = stack
        self.execute = execute
        self.weights = dict(DEFAULT_WEIGHTS) if weights is None else weights
        #: score/sample per weight bucket (the ExecutionPlan stratification)
        #: so the candidate budget is spent where the cost mass sits
        self.stratify = stratify
        self.bucket_size = bucket_size
        self.candidates_evaluated = 0
        self._scorer = None

    # -- scoring --------------------------------------------------------------

    def _accuracies(self, metrics: Sequence[Dict[str, float]]) -> np.ndarray:
        return np.array([vector_accuracy(self.target, m, self.keys,
                                         self.weights)["avg"]
                         for m in metrics])

    def _worst_dev(self, metrics: Dict[str, float]) -> float:
        devs = _deviations(self.target, metrics, self.keys)
        return max((abs(d) for d in devs.values()), default=math.inf)

    def _finite_mask(self, proxy: ProxyBenchmark,
                     matrix: np.ndarray) -> np.ndarray:
        """One bucketed population execution (one vmapped call per weight
        stratum); rejects candidates whose dynamic params drive the proxy
        non-finite."""
        from ..api.stack import get_stack
        report = get_stack(self.stack).run_population(
            proxy, matrix, space=self._space, bucket_size=self.bucket_size)
        return np.isfinite(np.asarray(report.result, np.float64))

    # -- sampling -------------------------------------------------------------

    @staticmethod
    def _log_normal_draw(rows: np.ndarray, count: int, sigma_floor: float,
                         rs: np.random.RandomState) -> np.ndarray:
        """``count`` log-normal samples around an elite subset's mean."""
        log_e = np.log(np.maximum(rows, 1e-3))
        mu = log_e.mean(axis=0)
        sigma = np.maximum(log_e.std(axis=0), sigma_floor)
        return np.exp(mu + sigma * rs.standard_normal((count, mu.size)))

    def _search_bucket_size(self, n: int) -> int:
        """Stratification granularity for *search* (sampling/budget): a
        handful of multi-candidate strata over the population, independent
        of the per-device *execution* bucket size (which degenerates to
        singleton buckets on CPU — useless as an elite pool)."""
        if self.bucket_size is not None:
            return self.bucket_size
        return max(2, math.ceil(n / 4))

    @staticmethod
    def _slot_allocation(masses: np.ndarray, slots: int) -> np.ndarray:
        """Largest-remainder split of ``slots`` proportional to the bucket
        cost masses — the candidate budget lands where the weight mass is.
        Always sums exactly to ``slots`` (zero-mass populations fall back
        to round-robin over the stable remainder order)."""
        raw = np.asarray(masses, np.float64) * slots
        counts = np.floor(raw).astype(int)
        rem = slots - int(counts.sum())
        if rem > 0:
            order = np.argsort(-(raw - counts), kind="stable")
            np.add.at(counts, order[np.arange(rem) % order.size], 1)
        return counts

    def _evolve(self, matrix: np.ndarray, acc: np.ndarray,
                gen: int) -> np.ndarray:
        """Next generation: log-normal around elite means (diagonal
        sigma), elitism for the single best, fresh log-uniform samples for
        the explore slots.

        With ``stratify`` (default) the evolved slots are allocated across
        the population's weight buckets proportional to each bucket's cost
        mass, every bucket evolving around its *own* local elite — the
        candidate budget concentrates where the workload's weight mass
        (and execution cost) actually sits, instead of treating a
        glue-weight candidate and a straggler identically."""
        space, dyn = self._space, self._dyn_mask
        rs = np.random.RandomState(self.seed + 1000 * (gen + 1))
        order = np.argsort(-acc)
        n = self.population
        out = np.tile(self._base, (n, 1))
        sched = (self._scorer.bucket_schedule(
                     matrix, self._search_bucket_size(matrix.shape[0]))
                 if self.stratify and self._scorer is not None else None)
        evolved = n - self.explore - 1
        if sched is not None and len(sched.buckets) > 1 and evolved > 0:
            rows: List[np.ndarray] = []
            counts = self._slot_allocation(sched.bucket_masses(), evolved)
            for bi, b in enumerate(sched.buckets):
                if counts[bi] == 0:
                    continue
                idx = b.indices[:b.valid]
                local = idx[np.argsort(-acc[idx], kind="stable")]
                k_elite = max(1, int(round(self.elite_frac * b.valid)))
                rows.append(self._log_normal_draw(
                    matrix[local[:k_elite]][:, dyn], int(counts[bi]),
                    self.sigma_floor, rs))
            out[self.explore:n - 1, dyn] = np.concatenate(rows, axis=0)
        else:
            elite = matrix[order[: self.elite]][:, dyn]
            out[:, dyn] = self._log_normal_draw(elite, n, self.sigma_floor,
                                                rs)
        out[: self.explore, dyn] = space.sample(
            self.explore, seed=self.seed + 7777 * (gen + 1))[:, dyn]
        out[-1] = matrix[order[0]]                    # elitism
        # clamp only the dynamic columns: static leaves must stay exactly
        # at base (they define the shared structure and may legitimately
        # sit outside the nominal bounds)
        out[:, dyn] = space.clamp(out)[:, dyn]
        return out

    def _trim_to_budget(self, matrix: np.ndarray, budget: int) -> np.ndarray:
        """Trim a generation to the remaining candidate budget, draining
        buckets heaviest-cost-mass first (schedule-by-cost, not
        enumeration order) while preserving the original candidate order
        of the survivors."""
        if not self.stratify or self._scorer is None:
            return matrix[:budget]
        sched = self._scorer.bucket_schedule(
            matrix, self._search_bucket_size(matrix.shape[0]))
        keep: List[int] = []
        for bi in np.argsort(-sched.bucket_masses(), kind="stable"):
            for i in sched.buckets[bi].indices[:sched.buckets[bi].valid]:
                if len(keep) < budget:
                    keep.append(int(i))
        return matrix[np.sort(np.asarray(keep, np.int64))]

    # -- main loop ------------------------------------------------------------

    def tune(self, proxy: ProxyBenchmark) -> PopulationTuneResult:
        from ..api.params import ParamSpace
        from .engine import PopulationScorer, measure

        proxy = proxy.clone()
        self.candidates_evaluated = 0      # budget is per tune() call
        space = self._space = ParamSpace.from_dag(proxy.dag)
        self._dyn_mask = space.dynamic_mask()
        self._base = space.values(proxy.dag)
        init_metrics = measure(proxy.dag)
        init_acc = vector_accuracy(self.target, init_metrics, self.keys,
                                   self.weights)
        if not self._dyn_mask.any():
            return PopulationTuneResult(
                proxy, False, 0, 0, init_acc, init_acc,
                self._worst_dev(init_metrics), [])

        scorer = self._scorer = PopulationScorer(proxy.dag, space)
        matrix = space.sample_dynamic(self.population, self._base,
                                      seed=self.seed)
        matrix[-1] = self._base       # the un-tuned start competes too
        best_vec, best_acc = self._base.copy(), init_acc["avg"]
        best_metrics = init_metrics
        history: List[Generation] = []
        converged = False
        gen = 0
        for gen in range(1, self.generations + 1):
            budget_left = (None if self.max_candidates is None
                           else self.max_candidates
                           - self.candidates_evaluated)
            if budget_left is not None and budget_left <= 0:
                gen -= 1
                break
            if budget_left is not None and budget_left < matrix.shape[0]:
                matrix = self._trim_to_budget(matrix, budget_left)
            if self.stratify:
                metrics, sched = scorer.score_bucketed(
                    matrix, self._search_bucket_size(matrix.shape[0]))
            else:
                metrics, sched = scorer(matrix), None
            acc = self._accuracies(metrics)
            self.candidates_evaluated += matrix.shape[0]
            if self.execute:
                acc = np.where(self._finite_mask(proxy, matrix), acc, -1.0)
            bi = int(np.argmax(acc))
            if acc[bi] > best_acc:
                best_acc = float(acc[bi])
                best_vec = matrix[bi].copy()
                best_metrics = metrics[bi]
            history.append(Generation(
                index=gen, best_accuracy=float(acc[bi]),
                mean_accuracy=float(acc.mean()),
                best_deviation=self._worst_dev(best_metrics),
                candidates=int(matrix.shape[0]),
                bucket_masses=(None if sched is None
                               else [float(m)
                                     for m in sched.bucket_masses()]),
                bucket_trips=(None if sched is None
                              else sched.trip_bounds())))
            if self._worst_dev(best_metrics) <= self.tol:
                converged = True
                break
            matrix = self._evolve(matrix, acc, gen)
        space.apply(proxy.dag, best_vec)
        final_acc = vector_accuracy(self.target, best_metrics, self.keys,
                                    self.weights)
        return PopulationTuneResult(
            proxy, converged, gen, self.candidates_evaluated,
            init_acc, final_acc, self._worst_dev(best_metrics), history)


def population_tune(proxy: ProxyBenchmark, target_metrics: Dict[str, float],
                    **kw) -> PopulationTuneResult:
    return PopulationTuner(target_metrics, **kw).tune(proxy)


# ---------------------------------------------------------------------------
# Structural tuning (the outer loop over the Fig.-3 DAG design space)
# ---------------------------------------------------------------------------
#
# PopulationTuner searches weights and dynamic params under ONE frozen
# structure; repro.core.structsearch.StructuralTuner wraps it with an outer
# evolutionary loop over *structure mutations* (edge insertion/removal,
# component swaps, chain split/merge), running this module's PopulationTuner
# as the inner weight loop only for surviving elite structures.  The two
# loops share one total candidate budget, split here.

#: default share of ``max_candidates`` spent scoring structures (the rest
#: funds the inner per-elite weight generations)
DEFAULT_STRUCTURE_BUDGET_FRAC = 0.25


def split_budget(total: int, structure_frac: float
                 ) -> Tuple[int, int]:
    """Split a total candidate budget into ``(structure, weight)`` shares.

    Every *structure* scored by the outer loop counts one candidate
    against the first share; the remainder funds the inner
    :class:`PopulationTuner` runs on elite structures.  The split is the
    fairness knob that lets ``StructuralTuner`` compete with a weight-only
    tuner under one fixed ``max_candidates``."""
    total = max(0, int(total))
    frac = min(max(float(structure_frac), 0.0), 1.0)
    s = int(round(total * frac))
    return s, total - s
