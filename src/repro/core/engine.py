"""Compile-once/run-many measurement engine for DAG proxies.

The auto-tuner (paper §2.3) re-measures the proxy after every parameter
probe and every adjustment step.  The seed paid a full XLA lower+compile
per measurement — and, because weights were Python-unrolled, that compile
scaled with total DAG weight.  This engine makes the run-many regime cheap
by splitting measurement along the same static/dynamic boundary as
``ProxyDAG``:

* **Structural metrics** (instruction mix, arithmetic intensity, …) come
  from a *compositional* cost model: each edge's single-repeat body is
  lowered, compiled and HLO-analyzed **once per static structure key** and
  cached process-wide; a proxy's report is then

      sources + Σ_edge weight_e × body_e + finalize

  so stepping any dynamic param (weight, shape-free extras) is pure
  arithmetic — zero compiles, zero traces.  Changing a shape-affecting
  param recompiles only the touched edge.
* **Rate metrics** (mips / flop_rate / mem_bw analogs, ``execute=True``)
  additionally time a real execution through a cached parametric
  executable (one compile per DAG structure key; dynamic params are jitted
  arguments, so weight sweeps re-run the same compiled program).

``stats()`` exposes compile/trace counters so tests and benchmarks can
assert the no-retrace contract.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .dag import _INT_DYNAMIC, ProxyDAG, _init_sources, _terminals
from .dwarfs import get_component
from .dwarfs.base import fit_buffer
from .metrics import CostReport, analyze_hlo_text, metric_vector

# process-wide caches: structure keys are value-hashable, so clones and
# re-built DAGs with identical structure share entries.  Report caches hold
# small dataclasses and can grow large; the executable cache retains
# compiled XLA programs, so it is kept tight (FIFO eviction)
_BODY_CACHE: Dict[Tuple, CostReport] = {}
_PIECE_CACHE: Dict[Tuple, CostReport] = {}
_EXEC_CACHE: Dict[Tuple, Callable] = {}

_REPORT_CACHE_CAP = 4096
_EXEC_CACHE_CAP = 128


def _evict_oldest(cache: Dict, cap: int) -> None:
    while len(cache) > cap:
        cache.pop(next(iter(cache)))

_STATS = {"compiles": 0, "traces": 0, "hits": 0, "exec_compiles": 0}


def stats() -> Dict[str, int]:
    """Counters of engine compile/trace activity (monotonic)."""
    return dict(_STATS)


def reset_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def clear_caches() -> None:
    """Drop every cached report/executable (tests and benchmarks use this
    to measure cold-vs-warm behaviour)."""
    _BODY_CACHE.clear()
    _PIECE_CACHE.clear()
    _EXEC_CACHE.clear()


def _analyze(fn: Callable, args: Tuple) -> CostReport:
    """Lower+compile ``fn`` (abstract args are fine) and analyze its HLO."""
    _STATS["compiles"] += 1
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(compiled.as_text())


def _rng_spec() -> jax.Array:
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# compositional pieces
# ---------------------------------------------------------------------------


def _body_key(e) -> Tuple:
    """Body-report cache key.  Unlike the *executable* caches (where dynamic
    extras are jitted arguments), the analyzed body HLO bakes the current
    dynamic-extra values in (e.g. hash ``rounds`` sets a loop trip count),
    so the report is only valid for those values — weight alone stays
    factored out as the linear multiplier."""
    p = e.params.rounded()
    dyn_vals = tuple(sorted(
        (k, int(round(float(p.extra[k]))) if k in _INT_DYNAMIC
         else float(p.extra[k]))
        for k in e.dynamic_fields() if k != "weight"))
    return (e.structure_key(), dyn_vals)


def _body_report(e) -> CostReport:
    """Cost of ONE repeat of edge ``e`` (the fori_loop body): component
    application + the fit-back glue, exactly as ``dag._edge_out`` traces it."""
    key = _body_key(e)
    rep = _BODY_CACHE.get(key)
    if rep is not None:
        _STATS["hits"] += 1
        return rep
    p = e.params.rounded()
    comp = get_component(e.component)

    def body(x, rng):
        return fit_buffer(comp(x, p, jax.random.fold_in(rng, 0)), p.data_size)

    x_spec = jax.ShapeDtypeStruct((p.data_size,), jnp.float32)
    rep = _analyze(body, (x_spec, _rng_spec()))
    _BODY_CACHE[key] = rep
    _evict_oldest(_BODY_CACHE, _REPORT_CACHE_CAP)
    return rep


def _sources_report(sources: Tuple[Tuple[str, int], ...]) -> CostReport:
    key = ("sources", sources)
    rep = _PIECE_CACHE.get(key)
    if rep is not None:
        _STATS["hits"] += 1
        return rep
    rep = _analyze(lambda rng: _init_sources(dict(sources), rng),
                   (_rng_spec(),))
    _PIECE_CACHE[key] = rep
    return rep


def _finalize_report(n: int) -> CostReport:
    key = ("finalize", n)
    rep = _PIECE_CACHE.get(key)
    if rep is not None:
        _STATS["hits"] += 1
        return rep
    rep = _analyze(lambda x: jnp.sum(x),
                   (jax.ShapeDtypeStruct((max(n, 1),), jnp.float32),))
    _PIECE_CACHE[key] = rep
    return rep


def _sink_sizes(dag: ProxyDAG) -> int:
    """Element count feeding the final reduction(s)."""
    sizes = {name: int(n) for name, n in dag.sources.items()}
    for e in dag.edges:
        sizes[e.dst] = e.params.rounded().data_size
    if dag.sink is not None:
        return sizes.get(dag.sink, 1)
    return sum(sizes.get(t, 1) for t in _terminals(dag.edges))


def structural_report(dag: ProxyDAG) -> CostReport:
    """Whole-proxy cost report assembled from cached per-edge pieces."""
    total = CostReport()
    total.add(_sources_report(tuple(sorted(dag.sources.items()))))
    for e in dag.edges:
        w = float(e.params.rounded().weight)
        if w > 0:
            total.add(_body_report(e), mult=w)
    total.add(_finalize_report(_sink_sizes(dag)))
    return total


# ---------------------------------------------------------------------------
# cached execution (rate metrics)
# ---------------------------------------------------------------------------


def executable(dag: ProxyDAG) -> Callable[[jax.Array], Any]:
    """Cached compiled runner for ``dag``: ``fn(rng) -> scalar`` binding the
    dag's *current* dynamic params as jitted arguments.  One compile per
    structure key; stepping weights/extras re-uses the executable."""
    key = dag.structure_key()
    jfn = _EXEC_CACHE.get(key)
    if jfn is None:
        _STATS["exec_compiles"] += 1
        pfn = dag.build_parametric()

        def counted(rng, dyn):
            _STATS["traces"] += 1
            return pfn(rng, dyn)

        jfn = jax.jit(counted)
        _EXEC_CACHE[key] = jfn
        _evict_oldest(_EXEC_CACHE, _EXEC_CACHE_CAP)
    else:
        _STATS["hits"] += 1
    return lambda rng: jfn(rng, dag.dynamic_params())


def measure(dag: ProxyDAG, execute: bool = False, exec_iters: int = 1,
            host_bytes: float = 0.0) -> Dict[str, float]:
    """The tuner's metric vector for ``dag`` under the compile-once contract.

    ``execute=False``: compositional structural metrics only (no tracing
    once edges are cached).  ``execute=True``: additionally times the
    cached executable to derive the rate metrics (mips / flop_rate /
    mem_bw), still without retracing across dynamic-param steps.
    """
    report = structural_report(dag)
    exec_s = 0.0
    if execute:
        cold = dag.structure_key() not in _EXEC_CACHE
        fn = executable(dag)
        rng = jax.random.PRNGKey(0)
        if cold:                             # exclude compile from the timing
            jax.block_until_ready(fn(rng))
        t0 = time.perf_counter()
        for _ in range(max(exec_iters, 1)):
            out = fn(rng)
        jax.block_until_ready(out)
        exec_s = (time.perf_counter() - t0) / max(exec_iters, 1)
    return metric_vector(report, host_bytes=host_bytes, exec_time=exec_s)
