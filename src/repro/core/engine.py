"""Compile-once/run-many measurement engine for DAG proxies.

The auto-tuner (paper §2.3) re-measures the proxy after every parameter
probe and every adjustment step.  The seed paid a full XLA lower+compile
per measurement — and, because weights were Python-unrolled, that compile
scaled with total DAG weight.  This engine makes the run-many regime cheap
by splitting measurement along the same static/dynamic boundary as
``ProxyDAG``:

* **Structural metrics** (instruction mix, arithmetic intensity, …) come
  from a *compositional* cost model: each edge's single-repeat body is
  lowered, compiled and HLO-analyzed **once per static structure key** and
  cached process-wide; a proxy's report is then

      sources + Σ_edge weight_e × body_e + finalize

  so stepping any dynamic param (weight, shape-free extras) is pure
  arithmetic — zero compiles, zero traces.  Changing a shape-affecting
  param recompiles only the touched edge.
* **Rate metrics** (mips / flop_rate / mem_bw analogs, ``execute=True``)
  additionally time a real execution through a cached parametric
  executable (one compile per DAG structure key; dynamic params are jitted
  arguments, so weight sweeps re-run the same compiled program).

``stats()`` exposes compile/trace counters so tests and benchmarks can
assert the no-retrace contract.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cachetools import LOCK
from .dag import _INT_DYNAMIC, ProxyDAG, _init_sources, _terminals
from .dwarfs import get_component
from .dwarfs.base import fit_buffer
from .metrics import CostReport, analyze_hlo_text, metric_vector
from .pool import get_pool

# process-wide caches: structure keys are value-hashable, so clones and
# re-built DAGs with identical structure share entries.  Report caches hold
# small dataclasses and can grow large; the executable cache retains
# compiled XLA programs, so it is kept tight.  All three register as
# domains of the process-wide ExecutablePool — one admission/eviction
# policy with the stack and plan caches — while the dicts themselves stay
# module-level (the pool owns bookkeeping, not values).
_BODY_CACHE: Dict[Tuple, CostReport] = {}
_PIECE_CACHE: Dict[Tuple, CostReport] = {}
_EXEC_CACHE: Dict[Tuple, Callable] = {}

_REPORT_CACHE_CAP = 4096
_EXEC_CACHE_CAP = 128

_BODY_DOM = get_pool().register("engine:body", _BODY_CACHE, kind="report",
                                cap=_REPORT_CACHE_CAP)
_PIECE_DOM = get_pool().register("engine:piece", _PIECE_CACHE, kind="report",
                                 cap=_REPORT_CACHE_CAP)
_EXEC_DOM = get_pool().register("engine:exec", _EXEC_CACHE,
                                kind="executable", cap=_EXEC_CACHE_CAP)

_STATS = {"compiles": 0, "traces": 0, "hits": 0, "exec_compiles": 0}


def stats() -> Dict[str, int]:
    """Counters of engine compile/trace activity (monotonic)."""
    return dict(_STATS)


def reset_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def clear_caches() -> None:
    """Drop every cached report/executable (tests and benchmarks use this
    to measure cold-vs-warm behaviour).  Clears through the pool so the
    eviction-order bookkeeping stays coherent with the dicts."""
    pool = get_pool()
    for name in ("engine:body", "engine:piece", "engine:exec"):
        pool.clear(name)


def _analyze(fn: Callable, args: Tuple) -> CostReport:
    """Lower+compile ``fn`` (abstract args are fine) and analyze its HLO."""
    _STATS["compiles"] += 1
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(compiled.as_text())


def _rng_spec() -> jax.Array:
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# compositional pieces
# ---------------------------------------------------------------------------


def _body_key(e) -> Tuple:
    """Body-report cache key.  Unlike the *executable* caches (where dynamic
    extras are jitted arguments), the analyzed body HLO bakes the current
    dynamic-extra values in (e.g. hash ``rounds`` sets a loop trip count),
    so the report is only valid for those values — weight alone stays
    factored out as the linear multiplier."""
    p = e.params.rounded()
    dyn_vals = tuple(sorted(
        (k, int(round(float(p.extra[k]))) if k in _INT_DYNAMIC
         else float(p.extra[k]))
        for k in e.dynamic_fields() if k != "weight"))
    return (e.structure_key(), dyn_vals)


def _body_report(e) -> CostReport:
    """Cost of ONE repeat of edge ``e`` (the fori_loop body): component
    application + the fit-back glue, exactly as ``dag._edge_out`` traces it."""
    key = _body_key(e)
    with LOCK:
        rep = _BODY_CACHE.get(key)
        if rep is not None:
            _STATS["hits"] += 1
            _BODY_DOM.stats["hits"] += 1
            return rep
        _BODY_DOM.stats["misses"] += 1
        p = e.params.rounded()
        comp = get_component(e.component)

        def body(x, rng):
            return fit_buffer(comp(x, p, jax.random.fold_in(rng, 0)),
                              p.data_size)

        x_spec = jax.ShapeDtypeStruct((p.data_size,), jnp.float32)
        rep = _analyze(body, (x_spec, _rng_spec()))
        return get_pool().put(_BODY_DOM, key, rep)


def _piece_report(key: Tuple, make: Callable[[], CostReport]) -> CostReport:
    with LOCK:
        rep = _PIECE_CACHE.get(key)
        if rep is not None:
            _STATS["hits"] += 1
            _PIECE_DOM.stats["hits"] += 1
            return rep
        _PIECE_DOM.stats["misses"] += 1
        return get_pool().put(_PIECE_DOM, key, make())


def _sources_report(sources: Tuple[Tuple[str, int], ...]) -> CostReport:
    return _piece_report(
        ("sources", sources),
        lambda: _analyze(lambda rng: _init_sources(dict(sources), rng),
                         (_rng_spec(),)))


def _finalize_report(n: int) -> CostReport:
    return _piece_report(
        ("finalize", n),
        lambda: _analyze(lambda x: jnp.sum(x),
                         (jax.ShapeDtypeStruct((max(n, 1),), jnp.float32),)))


def _sink_sizes_from(sources: Dict[str, int], edges, sink) -> int:
    """Element count feeding the final reduction(s)."""
    sizes = {name: int(n) for name, n in sources.items()}
    for e in edges:
        sizes[e.dst] = e.params.rounded().data_size
    if sink is not None:
        return sizes.get(sink, 1)
    return sum(sizes.get(t, 1) for t in _terminals(list(edges)))


def _sink_sizes(dag: ProxyDAG) -> int:
    return _sink_sizes_from(dag.sources, dag.edges, dag.sink)


def _assemble_report(sources: Dict[str, int], edges, sink) -> CostReport:
    total = CostReport()
    total.add(_sources_report(tuple(sorted(sources.items()))))
    for e in edges:
        w = float(e.params.rounded().weight)
        if w > 0:
            total.add(_body_report(e), mult=w)
    total.add(_finalize_report(_sink_sizes_from(sources, edges, sink)))
    return total


def structural_report(dag: ProxyDAG) -> CostReport:
    """Whole-proxy cost report assembled from cached per-edge pieces."""
    return _assemble_report(dag.sources, dag.edges, dag.sink)


def measure_plan(plan, host_bytes: float = 0.0) -> Dict[str, float]:
    """The compositional metric vector straight from an
    :class:`~repro.core.schedule.ExecutionPlan` — no ProxyDAG rebuild, no
    stack, no execution.  The plan's rounded lowering-time edges carry
    everything the cost model needs, so a structural search can score
    candidate plans as pure IR."""
    return metric_vector(
        _assemble_report(plan.sources, plan.edges, plan.sink),
        host_bytes=host_bytes)


# ---------------------------------------------------------------------------
# population measurement (vectorized compositional model)
# ---------------------------------------------------------------------------

#: the CostReport channels :func:`repro.core.metrics.metric_vector` reads,
#: flattened so population reports assemble as numpy linear algebra
_BASIS_FIELDS = ("flops", "vpu_ops", "bytes_accessed", "rng_elems",
                 "sort_elems", "fft_elems", "gather_elems", "reduce_elems",
                 "logic_elems", "compare_elems", "elementwise_elems",
                 "attention_flops")


def _report_to_vec(rep: CostReport) -> np.ndarray:
    return np.array([getattr(rep, f) for f in _BASIS_FIELDS]
                    + [rep.total_collective_bytes], dtype=np.float64)


def _vec_to_report(v: np.ndarray) -> CostReport:
    rep = CostReport(**{f: float(v[i]) for i, f in enumerate(_BASIS_FIELDS)})
    if v[-1]:
        rep.collective_bytes["all"] = float(v[-1])
    return rep


def _edge_with_extras(e, fields: Tuple[str, ...], values: Tuple) -> Any:
    work = dataclasses.replace(
        e, params=e.params.replace(extra=dict(e.params.extra)))
    for f, v in zip(fields, values):
        work.params.extra[f] = v
    return work


class PopulationScorer:
    """Precomputed flat-basis scorer for populations sharing one DAG
    structure — the :class:`~repro.core.autotune.PopulationTuner` hot path.

    Exploits the compositional model's linearity in the weights: at
    construction each edge's single-repeat body report is fetched once
    (per distinct dynamic-extra setting, lazily) and flattened to a
    channel vector, so every subsequent ``score(matrix)`` assembles all
    ``n`` candidates as

        M = const + W @ B          (numpy, one row per candidate)

    instead of ``n`` independent ``measure()`` walks.  Zero executable
    traces ever; body compiles only for dynamic-extra values never
    analyzed before (identical to what a single measurement at those
    values costs).  Candidate rows must differ from the construction-time
    parameters only in *dynamic* leaves — static leaves define the shared
    structure; rebuild the scorer after a structural step.
    """

    def __init__(self, dag: ProxyDAG, space, host_bytes: float = 0.0):
        self.host_bytes = host_bytes
        self._dag = dag
        self._space = space
        self._n_leaves = len(space)
        self._static = ~space.dynamic_mask()
        self._static_vals = space.values(dag)[self._static]
        self._static_names = [n for n, s in zip(space.names, self._static)
                              if s]
        const = _report_to_vec(
            _sources_report(tuple(sorted(dag.sources.items()))))
        const += _report_to_vec(_finalize_report(_sink_sizes(dag)))
        self._const = const
        # per edge: (weight column, dynamic-extra columns/fields, body
        # vector for extra-free edges, lazy per-extra-value vector cache)
        self._edges = []
        for ei, e in enumerate(dag.edges):
            prefix = f"e{ei}.{e.component}"
            extra_fields = tuple(f for f in e.dynamic_fields()
                                 if f != "weight")
            self._edges.append({
                "edge": e,
                "w_idx": space.index_of(f"{prefix}.weight"),
                "extra_fields": extra_fields,
                "extra_idx": [space.index_of(f"{prefix}.{f}")
                              for f in extra_fields],
                "body": (None if extra_fields
                         else _report_to_vec(_body_report(e))),
                "by_extras": {},
            })

    def _body_vec(self, info: Dict, values: Tuple) -> np.ndarray:
        vec = info["by_extras"].get(values)
        if vec is None:
            vec = _report_to_vec(_body_report(
                _edge_with_extras(info["edge"], info["extra_fields"],
                                  values)))
            info["by_extras"][values] = vec
        return vec

    def score(self, matrix) -> List[Dict[str, float]]:
        """Metric dicts (``measure(execute=False)``-identical keys) for
        every row of a ``(n, len(space))`` candidate matrix."""
        matrix = np.asarray(matrix, np.float64)
        if matrix.ndim != 2 or matrix.shape[1] != self._n_leaves:
            raise ValueError(f"expected a (n, {self._n_leaves}) candidate "
                             f"matrix, got shape {matrix.shape}")
        n = matrix.shape[0]
        if n and (matrix[:, self._static] != self._static_vals).any():
            bad = np.nonzero((matrix[:, self._static]
                              != self._static_vals).any(axis=0))[0]
            names = [self._static_names[b] for b in bad[:4]]
            raise ValueError(
                f"population rows change static leaves {names}; a "
                f"population shares one structure — rebuild the scorer "
                f"per structure instead")
        total = np.tile(self._const, (n, 1))
        for info in self._edges:
            w_col = np.round(matrix[:, info["w_idx"]])
            if info["body"] is not None:
                total += np.outer(w_col, info["body"])
                continue
            # dynamic extras bake into the body HLO: one vector per
            # distinct value tuple present in the population
            vals = np.stack([matrix[:, i] for i in info["extra_idx"]], axis=1)
            for row in np.unique(vals, axis=0):
                mask = (vals == row).all(axis=1)
                total[mask] += np.outer(
                    w_col[mask], self._body_vec(info, tuple(row)))
        return [metric_vector(_vec_to_report(total[i]),
                              host_bytes=self.host_bytes) for i in range(n)]

    __call__ = score

    # -- weight-stratified (per-bucket) view --------------------------------

    def bucket_schedule(self, matrix, bucket_size: Optional[int] = None):
        """The population's weight-stratified
        :class:`~repro.core.schedule.BucketSchedule`, computed with the
        same per-edge body costs the execution plan uses — so the scorer's
        strata line up exactly with the strata the stacks execute, and the
        tuner can spend its candidate budget where the weight mass is."""
        from .schedule import (make_bucket_schedule, resolve_bucket_size,
                               _edge_body_cost)
        matrix = np.asarray(matrix, np.float64)
        n = matrix.shape[0]
        costs = np.zeros(n, np.float64)
        trips = np.zeros(n, np.float64)
        for info in self._edges:
            w = np.round(np.maximum(matrix[:, info["w_idx"]], 0.0))
            costs += w * max(_edge_body_cost(info["edge"]), 1.0)
            trips += w
        if bucket_size is None:
            bucket_size = resolve_bucket_size(n)
        return make_bucket_schedule(costs, trips, bucket_size)

    def score_bucketed(self, matrix, bucket_size: Optional[int] = None):
        """``(metrics, schedule)``: metric dicts in the caller's candidate
        order plus the schedule that stratifies them — per-bucket scoring
        for the population tuner (scores are bucket-composition
        independent; the schedule carries the per-bucket mass/trip
        accounting)."""
        return self.score(matrix), self.bucket_schedule(matrix, bucket_size)


def measure_population(dag: ProxyDAG, space, matrix,
                       host_bytes: float = 0.0) -> List[Dict[str, float]]:
    """One-shot :class:`PopulationScorer`: metric vectors for a whole
    population of candidate vectors sharing ``dag``'s structure."""
    return PopulationScorer(dag, space, host_bytes=host_bytes)(matrix)


# ---------------------------------------------------------------------------
# structure measurement (mutation-delta scoring)
# ---------------------------------------------------------------------------


def _edge_vec(e) -> np.ndarray:
    """One edge's weighted contribution to the flat channel basis."""
    w = float(e.params.rounded().weight)
    if w <= 0:
        return np.zeros(len(_BASIS_FIELDS) + 1, np.float64)
    return w * _report_to_vec(_body_report(e))


def _dag_score_key(dag: ProxyDAG) -> Tuple:
    """Cache key of a dag's *compositional score*: the canonical structure
    plus every dynamic value (weights, extras) — two dags share a score
    vector only when they are relabelings with identical parameters."""
    dyn = tuple(
        tuple(sorted(
            (k, int(round(float(v))) if k in _INT_DYNAMIC else float(v))
            for k, v in (
                (f, e.params.rounded().weight if f == "weight"
                 else e.params.rounded().extra[f])
                for f in e.dynamic_fields())))
        for e in dag.edges)
    return (dag.canonical_structure_key(), dyn)


class StructureScorer:
    """Compositional scorer over *structures* — the outer-loop counterpart
    of :class:`PopulationScorer` (which scores weight candidates of one
    structure).

    Whole-structure reports are cached as flat channel vectors keyed on
    the canonical structure *plus* every dynamic value (weights change the
    score but not the structure), and a mutated child scores as a
    **delta** from its parent's cached vector:

        child = parent - Σ removed (weight × body) + Σ added (weight × body)
                ± the finalize-size correction

    so scoring ``m`` mutations of one parent costs ``O(Σ |edit|)`` cached
    body lookups rather than ``m`` full DAG walks — and *zero* compiles or
    traces when every (component, shape) involved has already been
    analyzed.  ``new_compiles`` counts the body analyses a scoring run did
    trigger (a structure introducing a never-profiled component pays
    exactly one)."""

    def __init__(self, host_bytes: float = 0.0):
        self.host_bytes = host_bytes
        self._vecs: Dict[Tuple, np.ndarray] = {}
        self._compiles0 = _STATS["compiles"]

    @property
    def new_compiles(self) -> int:
        """Body analyses triggered since this scorer was constructed."""
        return _STATS["compiles"] - self._compiles0

    def structures_cached(self) -> int:
        return len(self._vecs)

    def _vec(self, dag: ProxyDAG) -> np.ndarray:
        key = _dag_score_key(dag)
        vec = self._vecs.get(key)
        if vec is None:
            vec = _report_to_vec(structural_report(dag))
            self._vecs[key] = vec
        return vec

    def score(self, dag: ProxyDAG) -> Dict[str, float]:
        """Metric vector of ``dag`` (``measure(execute=False)``-identical
        keys), cached per canonical structure."""
        return metric_vector(_vec_to_report(self._vec(dag).copy()),
                             host_bytes=self.host_bytes)

    def score_child(self, parent: ProxyDAG, child: ProxyDAG,
                    removed: Sequence = (), added: Sequence = ()
                    ) -> Dict[str, float]:
        """Score ``child`` as a mutation delta from ``parent``.

        ``removed`` are the *parent* edges the mutation dropped and
        ``added`` the edges it introduced (a rewired-only edge — src
        renames — appears in neither: node names do not enter the body
        cost).  Falls back to a full assembly when the mutation touched
        the sources.  The resulting vector is cached under the child's
        canonical key, so it can seed further delta scoring."""
        key = _dag_score_key(child)
        vec = self._vecs.get(key)
        if vec is None:
            if dict(parent.sources) != dict(child.sources):
                return self.score(child)
            vec = self._vec(parent).copy()
            for e in removed:
                vec -= _edge_vec(e)
            for e in added:
                vec += _edge_vec(e)
            fin_p = _sink_sizes(parent)
            fin_c = _sink_sizes(child)
            if fin_p != fin_c:
                vec -= _report_to_vec(_finalize_report(fin_p))
                vec += _report_to_vec(_finalize_report(fin_c))
            self._vecs[key] = vec
        return metric_vector(_vec_to_report(vec.copy()),
                             host_bytes=self.host_bytes)


# ---------------------------------------------------------------------------
# cached execution (rate metrics)
# ---------------------------------------------------------------------------


def executable(dag: ProxyDAG) -> Callable[[jax.Array], Any]:
    """Cached compiled runner for ``dag``: ``fn(rng) -> scalar`` binding the
    dag's *current* dynamic params as jitted arguments.  One compile per
    *canonical* structure key (stable under node relabeling, so
    machine-generated isomorphic structures share the compile); stepping
    weights/extras re-uses the executable."""
    key = dag.canonical_structure_key()
    with LOCK:
        jfn = _EXEC_CACHE.get(key)
        if jfn is None:
            _STATS["exec_compiles"] += 1
            _EXEC_DOM.stats["misses"] += 1
            pfn = dag.build_parametric()

            def counted(rng, dyn):
                _STATS["traces"] += 1
                return pfn(rng, dyn)

            jfn = jax.jit(counted)
            get_pool().put(_EXEC_DOM, key, jfn)
        else:
            _STATS["hits"] += 1
            _EXEC_DOM.stats["hits"] += 1
    return lambda rng: jfn(rng, dag.dynamic_params())


def measure(dag: ProxyDAG, execute: bool = False, exec_iters: int = 1,
            host_bytes: float = 0.0) -> Dict[str, float]:
    """The tuner's metric vector for ``dag`` under the compile-once contract.

    ``execute=False``: compositional structural metrics only (no tracing
    once edges are cached).  ``execute=True``: additionally times the
    cached executable to derive the rate metrics (mips / flop_rate /
    mem_bw), still without retracing across dynamic-param steps.
    """
    report = structural_report(dag)
    exec_s = 0.0
    if execute:
        cold = dag.canonical_structure_key() not in _EXEC_CACHE
        fn = executable(dag)
        rng = jax.random.PRNGKey(0)
        if cold:                             # exclude compile from the timing
            jax.block_until_ready(fn(rng))
        t0 = time.perf_counter()
        for _ in range(max(exec_iters, 1)):
            out = fn(rng)
        jax.block_until_ready(out)
        exec_s = (time.perf_counter() - t0) / max(exec_iters, 1)
    return metric_vector(report, host_bytes=host_bytes, exec_time=exec_s)


# ---------------------------------------------------------------------------
# workload fingerprints (measurement -> tuner target)
# ---------------------------------------------------------------------------

#: schema version stamped into every serialized fingerprint
FINGERPRINT_VERSION = 1

#: ordered channel names of the fingerprint vector — the engine's flat
#: basis (:data:`_BASIS_FIELDS`) plus total collective bytes, i.e. exactly
#: the channels :func:`repro.core.metrics.metric_vector` reads
FINGERPRINT_CHANNELS: Tuple[str, ...] = _BASIS_FIELDS + ("collective_bytes",)


@dataclasses.dataclass(frozen=True)
class WorkloadFingerprint:
    """A workload's measured cost signature in the engine's channel basis.

    The lossless intermediate between *measurement* and *tuning*: the 13
    :data:`FINGERPRINT_CHANNELS` floats are precisely the CostReport fields
    :func:`~repro.core.metrics.metric_vector` consumes, so
    ``fp.metrics()`` reproduces the metric dict the measurement would have
    produced bit-for-bit — and any tuner accepting a Table-3 target dict
    accepts a fingerprint unchanged (see
    :func:`repro.core.autotune.coerce_target`).

    Attributes:
        name: human label for the fingerprinted workload.
        channels: the channel values, ordered as
            :data:`FINGERPRINT_CHANNELS`.
        host_bytes: host-side IO bytes observed alongside (feeds the
            ``io_fraction`` metric; 0 when unknown).
        source: provenance tag — ``"fn"`` (HLO cost analysis of a jitted
            callable), ``"dag"`` (compositional model of a ProxyDAG /
            spec), ``"report"`` (a CostReport or WorkloadProfile),
            ``"run"`` (a recorded RunReport), ``"serve"`` (a ServeReport's
            per-structure aggregate), or ``"json"`` (deserialized).
        version: schema version (:data:`FINGERPRINT_VERSION`).
    """

    name: str
    channels: Tuple[float, ...]
    host_bytes: float = 0.0
    source: str = "fn"
    version: int = FINGERPRINT_VERSION

    def __post_init__(self):
        if len(self.channels) != len(FINGERPRINT_CHANNELS):
            raise ValueError(
                f"fingerprint needs {len(FINGERPRINT_CHANNELS)} channels "
                f"({', '.join(FINGERPRINT_CHANNELS)}); got "
                f"{len(self.channels)}")

    def vector(self) -> np.ndarray:
        """The channel values as a float64 array (fresh copy)."""
        return np.asarray(self.channels, dtype=np.float64)

    def channel_dict(self) -> Dict[str, float]:
        """Channel name -> value mapping (insertion-ordered)."""
        return dict(zip(FINGERPRINT_CHANNELS, self.channels))

    def metrics(self) -> Dict[str, float]:
        """The tuner-facing metric dict (instruction mix, arithmetic
        intensity, …) reconstructed from the channels — identical to what
        :func:`measure` would report for the fingerprinted workload."""
        return metric_vector(_vec_to_report(self.vector()),
                             host_bytes=self.host_bytes)

    def to_json(self) -> Dict[str, Any]:
        """Versioned, JSON-serializable dict (round-trips via
        :meth:`from_json`)."""
        return {
            "fingerprint_version": self.version,
            "name": self.name,
            "source": self.source,
            "host_bytes": float(self.host_bytes),
            "channels": {k: float(v) for k, v in
                         zip(FINGERPRINT_CHANNELS, self.channels)},
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "WorkloadFingerprint":
        """Validate + rebuild a fingerprint serialized by :meth:`to_json`.

        Raises :class:`repro.api.spec.SpecError` with a path-precise
        message when the payload doesn't match the schema.
        """
        from ..api.spec import validate_fingerprint_json  # avoid cycle
        validate_fingerprint_json(d)
        return cls(
            name=str(d["name"]),
            channels=tuple(float(d["channels"][k])
                           for k in FINGERPRINT_CHANNELS),
            host_bytes=float(d.get("host_bytes", 0.0)),
            source="json",
            version=int(d["fingerprint_version"]),
        )


def _fingerprint_from_vec(vec: np.ndarray, name: str, host_bytes: float,
                          source: str) -> WorkloadFingerprint:
    return WorkloadFingerprint(
        name=name, channels=tuple(float(x) for x in vec),
        host_bytes=float(host_bytes), source=source)


def fingerprint(obj: Any, *args: Any, name: Optional[str] = None,
                host_bytes: Optional[float] = None) -> WorkloadFingerprint:
    """Fingerprint a workload into the engine's channel basis.

    One entry point for every measurement the repo can produce.  Accepts,
    in dispatch order:

    * a :class:`WorkloadFingerprint` (returned as-is, ``name`` aside);
    * a serialized fingerprint dict (``{"fingerprint_version": ...}``);
    * a recorded ``repro.api.RunReport`` — uses the report's attached DAG
      through the compositional model, scaled by the report's batch width,
      with ``host_bytes`` defaulting to the measured ``io_bytes``;
    * a ``repro.api.ServeReport`` — the request-count-weighted sum of the
      served structures' compositional reports;
    * a ``ProxyDAG`` / ``ProxySpec`` / ``ProxyBenchmark`` — the cached
      compositional cost model (zero compiles warm);
    * a ``CostReport`` or ``repro.core.WorkloadProfile``;
    * any jittable callable plus its (abstract or concrete) example
      ``*args`` — lowered once and HLO-cost-analyzed, exactly like
      :func:`repro.core.profiler.characterize`.

    Returns a versioned :class:`WorkloadFingerprint` whose ``metrics()``
    feed straight into ``repro.api.tune_structure(proxy, target=fp)``.
    """
    if isinstance(obj, WorkloadFingerprint):
        if name is not None and name != obj.name:
            return dataclasses.replace(obj, name=name)
        return obj
    if isinstance(obj, dict) and "fingerprint_version" in obj:
        fp = WorkloadFingerprint.from_json(obj)
        return fp if name is None else dataclasses.replace(fp, name=name)

    # recorded stack run: RunReport carries the executed DAG
    if hasattr(obj, "wall_s") and hasattr(obj, "io_bytes"):
        dag = getattr(obj, "dag", None)
        if dag is None:
            raise ValueError(
                "RunReport has no attached DAG (raw-callable runs are not "
                "fingerprintable from the report; fingerprint the callable "
                "directly: fingerprint(fn, *args))")
        vec = _report_to_vec(structural_report(dag)) * max(
            int(getattr(obj, "batch", 1) or 1), 1)
        hb = float(obj.io_bytes) if host_bytes is None else host_bytes
        return _fingerprint_from_vec(
            vec, name or f"run:{obj.stack}", hb, "run")

    # serve trace: per-structure aggregate weighted by request mix
    if hasattr(obj, "structure_mix") and hasattr(obj, "templates"):
        mix = dict(obj.structure_mix)
        templates = dict(obj.templates or {})
        missing = sorted(set(mix) - set(templates))
        if not mix or missing:
            raise ValueError(
                "ServeReport is missing structure templates for "
                f"{missing or 'all structures'}; re-run serve() to record "
                "them")
        vec = np.zeros(len(FINGERPRINT_CHANNELS), dtype=np.float64)
        for sname, count in sorted(mix.items()):
            vec += float(count) * _report_to_vec(
                structural_report(templates[sname]))
        return _fingerprint_from_vec(
            vec, name or f"serve:{obj.stack}",
            0.0 if host_bytes is None else host_bytes, "serve")

    dag = None
    if isinstance(obj, ProxyDAG):
        dag = obj
    elif hasattr(obj, "to_dag"):                       # ProxySpec
        dag = obj.to_dag()
    elif isinstance(getattr(obj, "dag", None), ProxyDAG):  # ProxyBenchmark
        dag = obj.dag
    if dag is not None:
        return _fingerprint_from_vec(
            _report_to_vec(structural_report(dag)),
            name or getattr(obj, "name", None) or "dag",
            0.0 if host_bytes is None else host_bytes, "dag")

    rep = obj.report if hasattr(obj, "report") else obj  # WorkloadProfile
    if isinstance(rep, CostReport):
        return _fingerprint_from_vec(
            _report_to_vec(rep),
            name or getattr(obj, "name", None) or "report",
            0.0 if host_bytes is None else host_bytes, "report")

    if callable(obj):
        rep = _analyze(obj, args)
        return _fingerprint_from_vec(
            _report_to_vec(rep),
            name or getattr(obj, "__name__", "fn"),
            0.0 if host_bytes is None else host_bytes, "fn")

    raise TypeError(
        f"cannot fingerprint {type(obj).__name__}: expected a callable, "
        "ProxyDAG/ProxySpec/ProxyBenchmark, CostReport/WorkloadProfile, "
        "RunReport, ServeReport, WorkloadFingerprint, or serialized "
        "fingerprint dict")
