"""Shared cache plumbing for the compile-once/run-many machinery.

Three subsystems keep keyed caches of expensive artifacts — the per-stack
compiled-executable caches (:mod:`repro.api.stack`), the engine's HLO
report / executable caches (:mod:`repro.core.engine`), and the execution-
plan cache (:mod:`repro.core.schedule`).  They all share the same two
needs, deduplicated here:

* **FIFO eviction** — a long-lived tuning or serving process sweeping
  *structural* params must not accumulate compiled programs or reports
  without bound; dicts preserve insertion order, so popping the first key
  evicts the oldest entry.
* **hit/miss accounting** — the no-retrace tests and the engine
  benchmarks assert the compile-once contract through these counters.

Both helpers hold :data:`LOCK` (one process-wide reentrant lock): the
population path dispatches strata over the ``REPRO_POP_WORKERS`` host
thread pool and the serving engine admits requests from caller threads,
so lookup-or-build and eviction must be atomic — an unlocked
``cache.pop(next(iter(cache)))`` racing a concurrent insert can double-pop
or corrupt the stats counters.  ``make`` runs *under* the lock: two
threads missing the same key must not both compile the artifact (the
whole point of the caches), and jitted execution — the expensive
concurrent work — never happens inside ``make``.  The lock is reentrant
because a build may itself consult another cache (a stack executable
build fetches the plan cache).

No jax imports: this module must stay importable from anywhere in the
package without initializing a backend.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional

#: process-wide lock serializing every cache mutation (reentrant: builds
#: may nest cache lookups, e.g. executable build -> plan cache)
LOCK = threading.RLock()


def evict_oldest(cache: Dict, cap: Optional[int],
                 stats: Optional[Dict[str, int]] = None,
                 evict: str = "evictions") -> int:
    """Drop oldest-inserted entries until ``cache`` holds at most ``cap``;
    returns (and counts into ``stats``) how many were dropped.  A nonzero
    steady-state eviction rate means the cap is thrashing — a structural
    search sweeping many DAG shapes watches this counter."""
    if cap is None:
        return 0
    with LOCK:
        dropped = 0
        while len(cache) > cap:
            cache.pop(next(iter(cache)))
            dropped += 1
        if dropped and stats is not None:
            stats[evict] = stats.get(evict, 0) + dropped
    return dropped


def cached_get(cache: Dict, key: Any, make: Callable[[], Any],
               stats: Optional[Dict[str, int]] = None,
               cap: Optional[int] = None,
               hit: str = "hits", miss: str = "misses") -> Any:
    """The shared lookup-or-build pattern: fetch ``key`` from ``cache``,
    building (and FIFO-evicting) on a miss, bumping the ``stats`` counters
    either way.  Atomic under :data:`LOCK`, including ``make`` — a miss
    races another thread's identical miss otherwise and the artifact
    (typically a compile) gets built twice."""
    with LOCK:
        value = cache.get(key)
        if value is None:
            if stats is not None:
                stats[miss] = stats.get(miss, 0) + 1
            value = make()
            cache[key] = value
            evict_oldest(cache, cap, stats)
        elif stats is not None:
            stats[hit] = stats.get(hit, 0) + 1
    return value


def hit_rate(stats: Dict[str, int], hit: str = "hits",
             miss: str = "misses") -> float:
    """Warm-serving fraction of all lookups (0.0 when none happened) —
    the cold-vs-warm axis the serving benchmarks report."""
    lookups = stats.get(hit, 0) + stats.get(miss, 0)
    return stats.get(hit, 0) / lookups if lookups else 0.0
