"""AI dwarf components (Data-Dwarfs extension, arxiv 1802.00699).

The follow-up paper extends the eight big-data dwarfs to AI workloads; these
components make the repo's AI raw material — the Pallas ``flash_attention``
and ``matmul`` kernels and the ``models/ssm.py`` selective scan — reachable
from the dwarf DAG:

  * ``attention``       — flash-attention forward, GQA-aware
  * ``gemm_train``      — matmul forward + backward (``jax.vjp``)
  * ``scan_recurrent``  — chunked SSM associative scan + output projection

Each is ``pallas_capable`` and dispatches through
:func:`repro.kernels.dispatch.resolve_backend` exactly like ``topk`` /
``hash_mix``: the backend is resolved *outside* the jitted wrapper so
``REPRO_BACKEND`` / the circuit breaker's ``forced_backend`` key the
executable caches.  Unlike the integer kernels, the blocked float kernels
accumulate in a different order than stock XLA, so each declares a
``parity_tol`` instead of bit-identity.

Shape extras (``seq_len`` / ``heads`` / ``kv_heads`` / ``state``) are
*static* tunables — they change traced shapes, so the tuner pays a
recompile to move them (bounded in ``repro.api.params.FIELD_BOUNDS``);
``rounds`` on the training/recurrent components is a loop count and stays
dynamic where the kernel does not consume it.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ...kernels.dispatch import default_interpret
from .base import (ComponentParams, DwarfComponent, as_chunks, fit_buffer,
                   loop_count, register)


def _int_extra(extra: Dict[str, Any], key: str, default: int,
               lo: int, hi: int) -> int:
    """Static shape extra -> bounded int (tuners write floats)."""
    v = extra.get(key, default)
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        v = default
    return int(max(lo, min(int(round(float(v))), hi)))


@register
class Attention(DwarfComponent):
    """Causal softmax attention over the buffer viewed as (1, S, H, hd).

    GQA-aware: ``kv_heads`` < ``heads`` shares each KV head across a query
    group (``kv_heads`` is snapped down to a divisor of ``heads``).  Q comes
    from the buffer, K from its reversal and V from a rotation, so the three
    projections are distinct views of the same data stream.
    """

    name = "attention"
    dwarf = "attention"
    pallas_capable = True
    parity_tol = 1e-3      # online softmax vs. full softmax accumulation

    def _geometry(self, p: ComponentParams):
        H = _int_extra(p.extra, "heads", 4, 1, 16)
        kv = _int_extra(p.extra, "kv_heads", H, 1, H)
        while H % kv:
            kv -= 1
        hd = max(8, min(128, (p.chunk_size // 8) * 8))
        s_default = max(8, p.data_size // (H * hd))
        S = _int_extra(p.extra, "seq_len", s_default, 8, 1024)
        return S, H, kv, hd

    def apply(self, x: jnp.ndarray, p: ComponentParams,
              rng: jax.Array) -> jnp.ndarray:
        x = x.astype(jnp.float32)      # backend-independent f32 numerics
        S, H, kv, hd = self._geometry(p)
        q = fit_buffer(x, S * H * hd).reshape(1, S, H, hd)
        k = fit_buffer(x[::-1], S * kv * hd).reshape(1, S, kv, hd)
        v = fit_buffer(jnp.roll(x, x.shape[0] // 3), S * kv * hd
                       ).reshape(1, S, kv, hd)
        if self.uses_pallas(p):
            from ...kernels.flash_attention.ops import flash_attention
            # resolve interpret here, not inside the jitted wrapper: as an
            # explicit static arg it keys the jit cache (same contract as
            # mix_u32 / topk)
            out = flash_attention(q, k, v, causal=True, block_q=64,
                                  block_kv=64, interpret=default_interpret(),
                                  backend="pallas")
        else:
            from ...kernels.flash_attention.ref import attention_ref
            out = attention_ref(q.transpose(0, 2, 1, 3),
                                k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3),
                                causal=True).transpose(0, 2, 1, 3)
        return out.reshape(-1)


@register
class GemmTrain(DwarfComponent):
    """Matmul forward + backward — the training-step GEMM triple.

    ``rounds`` SGD-style steps over A (k x k weights B built from the
    buffer): forward ``C = A @ B``, cotangent ``G = C / k``, backward
    ``dA = G @ B^T`` via :func:`jax.vjp` on the XLA path or explicit tiled
    matmul kernel calls on the Pallas path, then a per-row RMS
    renormalization (the layer-norm analog that keeps round counts stable).
    The final step also produces ``dB = A^T @ G`` — all three GEMMs of a
    dense layer's train step.
    """

    name = "gemm_train"
    dwarf = "gemm"
    dynamic_extras = ("rounds",)
    pallas_static = ("rounds",)
    pallas_capable = True
    parity_tol = 1e-3      # tiled f32 scratch vs. XLA accumulation order

    def apply(self, x: jnp.ndarray, p: ComponentParams,
              rng: jax.Array) -> jnp.ndarray:
        x = x.astype(jnp.float32)      # backend-independent f32 numerics
        a = as_chunks(x, p)                                   # (m, k)
        k = a.shape[1]
        bmat = fit_buffer(x[::-1], k * k).reshape(k, k) * (1.0 / k)
        inv_k = 1.0 / k
        rounds = loop_count(p.extra.get("rounds", 1), default=1)

        def _renorm(u):
            return u * jax.lax.rsqrt(
                jnp.mean(u * u, axis=1, keepdims=True) + 1e-6)

        if self.uses_pallas(p) and isinstance(rounds, int):
            from ...kernels.matmul.ops import matmul
            interp = default_interpret()
            mm = lambda u, w: matmul(u, w, block_m=64, block_n=64,
                                     block_k=64, interpret=interp,
                                     backend="pallas")
            acc = a
            for _ in range(rounds):
                c = mm(acc, bmat)
                da = mm(c * inv_k, bmat.T)
                acc = _renorm(acc - 0.1 * da)
            c = mm(acc, bmat)
            g = c * inv_k
            da = mm(g, bmat.T)
            db = mm(acc.T, g)
        else:
            def step(acc):
                c, vjp = jax.vjp(lambda u: u @ bmat, acc)
                (da,) = vjp(c * inv_k)
                return _renorm(acc - 0.1 * da)

            acc = jax.lax.fori_loop(0, rounds, lambda i, u: step(u), a)
            c, vjp = jax.vjp(lambda u, w: u @ w, acc, bmat)
            da, db = vjp(c * inv_k)
        return jnp.concatenate([(c + da).reshape(-1),
                                db.reshape(-1)]) * inv_k


@register
class ScanRecurrent(DwarfComponent):
    """Selective-scan recurrence (``models/ssm.py`` chunk) + readout GEMM.

    The buffer becomes one SSM chunk — inputs ``u`` (L, di=chunk), gates
    ``dt`` and input/output maps ``Bc``/``Cc`` from shifted views, a fixed
    stable decay ``A`` — advanced ``rounds`` times by the associative scan,
    then read out through a (di, di) projection: the projection is the
    Pallas-dispatched hot spot, the scan itself is shared VPU work on both
    backends.  ``rounds`` stays dynamic even on Pallas (the kernel does not
    consume it).
    """

    name = "scan_recurrent"
    dwarf = "recurrent"
    dynamic_extras = ("rounds",)
    pallas_capable = True
    parity_tol = 1e-3      # readout matmul kernel vs. XLA dot

    def apply(self, x: jnp.ndarray, p: ComponentParams,
              rng: jax.Array) -> jnp.ndarray:
        from ...models.ssm import _ssm_chunk
        x = x.astype(jnp.float32)      # scan mixes with the f32 A matrix
        u2 = as_chunks(x, p)                                  # (L, di)
        L, di = u2.shape
        st = _int_extra(p.extra, "state", 8, 2, 64)
        u = u2[None]                                          # (1, L, di)
        dt = 0.01 + 0.1 * jax.nn.sigmoid(u)
        Bc = fit_buffer(x[::-1], L * st).reshape(1, L, st) * (st ** -0.5)
        Cc = fit_buffer(jnp.roll(x, 7), L * st).reshape(1, L, st) \
            * (st ** -0.5)
        A = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32) / st, (di, 1))
        h0 = jnp.zeros((1, di, st), jnp.float32)
        rounds = loop_count(p.extra.get("rounds", 1), default=1)
        pre = max(rounds - 1, 0) if isinstance(rounds, int) \
            else jnp.maximum(rounds - 1, 0)
        h = jax.lax.fori_loop(
            0, pre, lambda i, c: _ssm_chunk(c, (dt, Bc, Cc, u), A)[0], h0)
        _, y = _ssm_chunk(h, (dt, Bc, Cc, u), A)
        w = fit_buffer(x, di * di).reshape(di, di) * (1.0 / di)
        y2 = y.reshape(L, di)
        if self.uses_pallas(p):
            from ...kernels.matmul.ops import matmul
            out = matmul(y2, w, block_m=64, block_n=64, block_k=64,
                         interpret=default_interpret(), backend="pallas")
        else:
            out = jnp.dot(y2, w, preferred_element_type=jnp.float32)
        return out.reshape(-1)
