"""Transform computation dwarf — FFT / DCT / wavelet (paper Fig. 3)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ComponentParams, DwarfComponent, as_chunks, register


@register
class FFTTransform(DwarfComponent):
    """rFFT -> spectrum magnitude -> irFFT round trip over chunks."""

    name = "fft"
    dwarf = "transform"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        spec = jnp.fft.rfft(rows, axis=1)
        out = jnp.fft.irfft(spec * jnp.conj(spec), n=rows.shape[1], axis=1)
        return out * (1.0 / rows.shape[1])


@register
class DCTTransform(DwarfComponent):
    """DCT-II via FFT of the even extension (MPEG/SIFT frontends)."""

    name = "dct"
    dwarf = "transform"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        n = rows.shape[1]
        ext = jnp.concatenate([rows, rows[:, ::-1]], axis=1)
        spec = jnp.fft.rfft(ext, axis=1)[:, :n]
        k = jnp.arange(n)
        phase = jnp.exp(-1j * jnp.pi * k / (2 * n))
        return jnp.real(spec * phase)


@register
class HaarWavelet(DwarfComponent):
    """Multi-level Haar lifting (avg/diff butterflies)."""

    name = "wavelet"
    dwarf = "transform"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        levels = int(p.extra.get("levels", 3))
        n = rows.shape[1]
        out = rows
        width = n
        for _ in range(levels):
            if width < 2:
                break
            half = width // 2
            a = out[:, : 2 * half: 2]
            b = out[:, 1: 2 * half: 2]
            avg = (a + b) * 0.70710678
            diff = (a - b) * 0.70710678
            out = jnp.concatenate([avg, diff, out[:, 2 * half:]], axis=1)
            width = half
        return out
