"""The eight big data dwarfs (paper §2.2) as JAX dwarf components, plus the
AI extension dwarfs (Data-Dwarfs follow-up, arxiv 1802.00699).

Importing this package populates the component registry with all dwarf
components (paper Fig. 3): matrix, sampling, logic, transform, set, graph,
sort, basic statistic — and the AI classes attention / gemm / recurrent
(:mod:`repro.core.dwarfs.ai`).
"""

from .base import (REGISTRY, ComponentParams, DwarfComponent,
                   components_of_dwarf, fit_buffer, get_component)
from . import matrix, sampling, logic, transform, set_ops, graph, sort, statistic  # noqa: F401
from . import ai  # noqa: F401

DWARFS = ("matrix", "sampling", "logic", "transform", "set", "graph", "sort",
          "statistic", "attention", "gemm", "recurrent")

__all__ = [
    "REGISTRY", "ComponentParams", "DwarfComponent", "components_of_dwarf",
    "get_component", "fit_buffer", "DWARFS",
]
