"""The eight big data dwarfs (paper §2.2) as JAX dwarf components.

Importing this package populates the component registry with all dwarf
components (paper Fig. 3): matrix, sampling, logic, transform, set, graph,
sort, basic statistic.
"""

from .base import (REGISTRY, ComponentParams, DwarfComponent,
                   components_of_dwarf, fit_buffer, get_component)
from . import matrix, sampling, logic, transform, set_ops, graph, sort, statistic  # noqa: F401

DWARFS = ("matrix", "sampling", "logic", "transform", "set", "graph", "sort",
          "statistic")

__all__ = [
    "REGISTRY", "ComponentParams", "DwarfComponent", "components_of_dwarf",
    "get_component", "fit_buffer", "DWARFS",
]
