"""Dwarf component base: the paper's Table-2 tunable parameter set.

Every component is a shape-static, jit-able transform over a flat f32 buffer.
The four tunables map 1:1 to the paper (§2.3, Table 2):

  * ``data_size``    — input data size for the component
  * ``chunk_size``   — block processed "per thread" (tile/row length)
  * ``parallelism``  — number of parallel lanes (vmap width / mesh shards)
  * ``weight``       — contribution (repeat count) of the component in the DAG
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ...kernels.dispatch import default_interpret, resolve_backend


@dataclasses.dataclass
class ComponentParams:
    data_size: int = 1 << 14
    chunk_size: int = 256
    parallelism: int = 1
    weight: int = 1
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def replace(self, **kw) -> "ComponentParams":
        return dataclasses.replace(self, **kw)

    def rounded(self) -> "ComponentParams":
        """Clamp/round to legal values (tuner moves in continuous space).

        ``weight`` rounds to nearest — the same coercion the dynamic-param
        path applies (``dag._INT_DYNAMIC`` scalars go through
        ``int(round(...))``), so a fractional tuner weight executes and
        serializes identically."""
        data_size = int(max(256, min(self.data_size, 1 << 26)))
        chunk = int(max(8, min(self.chunk_size, data_size)))
        # keep chunks lane-friendly (multiples of 8; TPU-sublane aligned)
        chunk = max(8, (chunk // 8) * 8)
        par = int(max(1, min(self.parallelism, 256)))
        weight = int(round(max(0.0, min(float(self.weight), 128.0))))
        data_size = max(chunk, (data_size // chunk) * chunk)
        return ComponentParams(data_size, chunk, par, weight, dict(self.extra))


def fit_buffer(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """Resize a flat buffer to n elements (tile or slice) — DAG glue."""
    x = x.reshape(-1)
    if x.shape[0] == n:
        return x
    if x.shape[0] > n:
        return x[:n]
    reps = -(-n // x.shape[0])
    return jnp.tile(x, reps)[:n]


def as_chunks(x: jnp.ndarray, p: ComponentParams) -> jnp.ndarray:
    """View the buffer as (rows, chunk) — 'chunk per thread' layout."""
    c = p.chunk_size
    n = (x.shape[0] // c) * c
    n = max(n, c)
    x = fit_buffer(x, n)
    return x.reshape(-1, c)


def as_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic f32 -> u32 reinterpretation for logic/sort dwarfs."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def loop_count(v: Any, default: int = 0):
    """Coerce a repeat/round count into a ``fori_loop``-compatible bound.

    Python numbers (the static path) round to a non-negative int; traced
    scalars (a dynamic param stepped without retracing) pass through.
    """
    if v is None:
        v = default
    if isinstance(v, (int, float)):
        return max(int(round(v)), 0)
    return v


def mix_u32(u: jnp.ndarray, rounds: Any, backend: Optional[str] = None
            ) -> jnp.ndarray:
    """murmur3-finalizer avalanche rounds over u32, backend-dispatched.

    The hash-indexed dwarfs (logic ``hash``, statistic ``histogram`` /
    ``grouped_count``) share this hot spot.  On the Pallas backend with a
    static round count it runs :func:`repro.kernels.hash_mix.hash_mix`
    (bit-identical to the XLA path); a traced round count — a dynamic
    param — always takes the ``fori_loop`` XLA path, since kernel rounds
    are compile-time static.
    """
    rounds = loop_count(rounds)
    if isinstance(rounds, int):
        if rounds <= 0:
            return u
        if resolve_backend(backend) == "pallas":
            from ...kernels.hash_mix.ops import hash_mix
            # resolve interpret here, not inside the jitted wrapper: as an
            # explicit static arg it keys the jit cache, so flipping
            # REPRO_PALLAS_INTERPRET can never hit a stale compilation
            return hash_mix(u, rounds=rounds, interpret=default_interpret())
    return jax.lax.fori_loop(0, rounds, lambda i, v: _mix32_round(v), u)


def _mix32_round(u: jnp.ndarray) -> jnp.ndarray:
    u = u ^ (u >> 16)
    u = u * jnp.uint32(0x85EBCA6B)
    u = u ^ (u >> 13)
    u = u * jnp.uint32(0xC2B2AE35)
    u = u ^ (u >> 16)
    return u


def u32_to_f32(u: jnp.ndarray) -> jnp.ndarray:
    """u32 -> well-behaved f32 in [0, 1) (avoids NaN-laden bitcasts)."""
    return (u >> 8).astype(jnp.float32) * (1.0 / (1 << 24))


class DwarfComponent:
    """One dwarf component (paper Fig. 3): name + dwarf class + apply()."""

    name: str = "abstract"
    dwarf: str = "abstract"

    #: ``extra`` keys that do not affect shapes: they may be passed as traced
    #: scalars, so the tuner can step them without an XLA retrace.
    dynamic_extras: Tuple[str, ...] = ()
    #: subset of ``dynamic_extras`` that must stay static when this component
    #: dispatches to a Pallas kernel (kernel loop bounds are compile-time).
    pallas_static: Tuple[str, ...] = ()
    #: whether a Pallas fast path exists for this component's hot spot
    pallas_capable: bool = False
    #: backend-parity tolerance.  ``None`` means the Pallas and XLA paths are
    #: bit-identical (integer kernels like ``topk``/``hash_mix``); a float is
    #: the allclose rtol/atol for kernels whose blocked accumulation order
    #: legitimately differs from the stock XLA lowering (flash attention's
    #: online softmax, the tiled matmul's f32 scratch accumulation).
    parity_tol: Optional[float] = None

    def uses_pallas(self, p: ComponentParams) -> bool:
        return self.pallas_capable and resolve_backend(
            p.extra.get("backend")) == "pallas"

    def dynamic_fields(self, p: ComponentParams) -> Tuple[str, ...]:
        """Names of this component's dynamic (retrace-free) tunables:
        always ``weight`` (the DAG repeat count becomes a ``fori_loop``
        bound) plus the declared dynamic extras actually present."""
        static = set(self.pallas_static) if self.uses_pallas(p) else set()
        return ("weight",) + tuple(
            k for k in self.dynamic_extras
            if k in p.extra and k not in static
            and isinstance(p.extra[k], (int, float))
            and not isinstance(p.extra[k], bool))

    def apply(self, x: jnp.ndarray, p: ComponentParams,
              rng: jax.Array) -> jnp.ndarray:
        raise NotImplementedError

    def __call__(self, x: jnp.ndarray, p: ComponentParams,
                 rng: jax.Array) -> jnp.ndarray:
        p = p.rounded()
        x = fit_buffer(x, p.data_size)
        if p.parallelism > 1:
            rows = x.shape[0]
            lanes = min(p.parallelism, max(1, rows // max(p.chunk_size, 8)))
            if lanes > 1 and rows % lanes == 0:
                xs = x.reshape(lanes, -1)
                rngs = jax.random.split(rng, lanes)
                sub = p.replace(data_size=rows // lanes, parallelism=1)
                out = jax.vmap(lambda xi, ri: self.apply(xi, sub, ri))(xs, rngs)
                return out.reshape(-1)
        return self.apply(x, p, rng).reshape(-1)

    def __repr__(self) -> str:
        return f"<{self.dwarf}:{self.name}>"


REGISTRY: Dict[str, DwarfComponent] = {}


def register(cls):
    inst = cls()
    REGISTRY[inst.name] = inst
    return cls


def get_component(name: str) -> DwarfComponent:
    if name not in REGISTRY:
        raise KeyError(f"unknown dwarf component {name!r}; "
                       f"known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def components_of_dwarf(dwarf: str):
    return [c for c in REGISTRY.values() if c.dwarf == dwarf]
