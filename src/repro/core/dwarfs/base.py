"""Dwarf component base: the paper's Table-2 tunable parameter set.

Every component is a shape-static, jit-able transform over a flat f32 buffer.
The four tunables map 1:1 to the paper (§2.3, Table 2):

  * ``data_size``    — input data size for the component
  * ``chunk_size``   — block processed "per thread" (tile/row length)
  * ``parallelism``  — number of parallel lanes (vmap width / mesh shards)
  * ``weight``       — contribution (repeat count) of the component in the DAG
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ComponentParams:
    data_size: int = 1 << 14
    chunk_size: int = 256
    parallelism: int = 1
    weight: int = 1
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def replace(self, **kw) -> "ComponentParams":
        return dataclasses.replace(self, **kw)

    def rounded(self) -> "ComponentParams":
        """Clamp/round to legal values (tuner moves in continuous space)."""
        data_size = int(max(256, min(self.data_size, 1 << 26)))
        chunk = int(max(8, min(self.chunk_size, data_size)))
        # keep chunks lane-friendly (multiples of 8; TPU-sublane aligned)
        chunk = max(8, (chunk // 8) * 8)
        par = int(max(1, min(self.parallelism, 256)))
        weight = int(max(0, min(self.weight, 128)))
        data_size = max(chunk, (data_size // chunk) * chunk)
        return ComponentParams(data_size, chunk, par, weight, dict(self.extra))


def fit_buffer(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """Resize a flat buffer to n elements (tile or slice) — DAG glue."""
    x = x.reshape(-1)
    if x.shape[0] == n:
        return x
    if x.shape[0] > n:
        return x[:n]
    reps = -(-n // x.shape[0])
    return jnp.tile(x, reps)[:n]


def as_chunks(x: jnp.ndarray, p: ComponentParams) -> jnp.ndarray:
    """View the buffer as (rows, chunk) — 'chunk per thread' layout."""
    c = p.chunk_size
    n = (x.shape[0] // c) * c
    n = max(n, c)
    x = fit_buffer(x, n)
    return x.reshape(-1, c)


def as_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic f32 -> u32 reinterpretation for logic/sort dwarfs."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def u32_to_f32(u: jnp.ndarray) -> jnp.ndarray:
    """u32 -> well-behaved f32 in [0, 1) (avoids NaN-laden bitcasts)."""
    return (u >> 8).astype(jnp.float32) * (1.0 / (1 << 24))


class DwarfComponent:
    """One dwarf component (paper Fig. 3): name + dwarf class + apply()."""

    name: str = "abstract"
    dwarf: str = "abstract"

    def apply(self, x: jnp.ndarray, p: ComponentParams,
              rng: jax.Array) -> jnp.ndarray:
        raise NotImplementedError

    def __call__(self, x: jnp.ndarray, p: ComponentParams,
                 rng: jax.Array) -> jnp.ndarray:
        p = p.rounded()
        x = fit_buffer(x, p.data_size)
        if p.parallelism > 1:
            rows = x.shape[0]
            lanes = min(p.parallelism, max(1, rows // max(p.chunk_size, 8)))
            if lanes > 1 and rows % lanes == 0:
                xs = x.reshape(lanes, -1)
                rngs = jax.random.split(rng, lanes)
                sub = p.replace(data_size=rows // lanes, parallelism=1)
                out = jax.vmap(lambda xi, ri: self.apply(xi, sub, ri))(xs, rngs)
                return out.reshape(-1)
        return self.apply(x, p, rng).reshape(-1)

    def __repr__(self) -> str:
        return f"<{self.dwarf}:{self.name}>"


REGISTRY: Dict[str, DwarfComponent] = {}


def register(cls):
    inst = cls()
    REGISTRY[inst.name] = inst
    return cls


def get_component(name: str) -> DwarfComponent:
    if name not in REGISTRY:
        raise KeyError(f"unknown dwarf component {name!r}; "
                       f"known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def components_of_dwarf(dwarf: str):
    return [c for c in REGISTRY.values() if c.dwarf == dwarf]
