"""Sort computation dwarf — quick sort, merge sort, top-k, min/max."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ComponentParams, DwarfComponent, as_chunks, register


def _sort_net_rows(rows: jnp.ndarray) -> jnp.ndarray:
    """Dispatch a (rows, chunk) tile to the bitonic sort-network kernel.

    The sorted row is multiset-determined, so the network's output is
    value-identical to ``jnp.sort`` — the sort dwarfs stay in the
    bit-identical (``parity_tol is None``) class."""
    from ...kernels.dispatch import default_interpret
    from ...kernels.sort_net.ops import sort_rows
    return sort_rows(rows, interpret=default_interpret())


@register
class QuickSort(DwarfComponent):
    """Full comparison sort per chunk row (XLA lowers to its sort network;
    the Pallas path runs the bitonic compare-exchange network)."""

    name = "quick_sort"
    dwarf = "sort"

    pallas_capable = True

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        if self.uses_pallas(p):
            return _sort_net_rows(rows)
        return jnp.sort(rows, axis=1)


@register
class MergeSort(DwarfComponent):
    """Sort row halves independently, then merge via rank interleave."""

    name = "merge_sort"
    dwarf = "sort"

    pallas_capable = True

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        c = rows.shape[1]
        h = c // 2
        if self.uses_pallas(p) and 2 * h == c:
            # the stable merge of two sorted halves IS the full row sort
            # whenever the chunk is even (rounded chunks always are) —
            # run it on the network instead of the rank interleave
            return _sort_net_rows(rows)
        a = jnp.sort(rows[:, :h], axis=1)
        b = jnp.sort(rows[:, h: 2 * h], axis=1)
        # merge: position of each element = own rank + rank in other run
        pa = jnp.arange(h) + jax.vmap(jnp.searchsorted)(b, a)
        pb = jnp.arange(h) + jax.vmap(lambda bb, aa: jnp.searchsorted(aa, bb, side="right"))(b, a)
        merged = jnp.zeros((rows.shape[0], 2 * h), rows.dtype)
        merged = jax.vmap(lambda m, i, v: m.at[i].set(v))(merged, pa, a)
        merged = jax.vmap(lambda m, i, v: m.at[i].set(v))(merged, pb, b)
        if 2 * h < c:
            merged = jnp.concatenate([merged, rows[:, 2 * h:]], axis=1)
        return merged


@register
class TopK(DwarfComponent):
    name = "top_k"
    dwarf = "sort"

    pallas_capable = True

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        k = min(int(p.extra.get("k", 32)), rows.shape[1])
        if self.uses_pallas(p):
            from ...kernels.dispatch import default_interpret
            from ...kernels.topk.ops import topk
            vals, _ = topk(rows, k, interpret=default_interpret())
        else:
            vals, _ = jax.lax.top_k(rows, k)
        reps = -(-rows.shape[1] // k)
        return jnp.tile(vals, (1, reps))[:, : rows.shape[1]]


@register
class MinMaxCalc(DwarfComponent):
    """Per-row min/max normalization.  Its Pallas fast path is the
    megakernel *segment body* (``kernels.megakernel.bodies``) — the
    standalone apply is one fused normalize either way, so it dispatches
    nothing itself and stays bit-identical across backends."""

    name = "min_max"
    dwarf = "sort"

    pallas_capable = True

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        mn = rows.min(axis=1, keepdims=True)
        mx = rows.max(axis=1, keepdims=True)
        return (rows - mn) / jnp.maximum(mx - mn, 1e-6)
