"""Logic computation dwarf — hash / compression / encryption-style bit ops."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import (ComponentParams, DwarfComponent, _mix32_round as _mix32,
                   as_u32, loop_count, mix_u32, register, u32_to_f32)


@register
class HashComputation(DwarfComponent):
    name = "hash"
    dwarf = "logic"

    dynamic_extras = ("rounds",)
    pallas_static = ("rounds",)
    pallas_capable = True

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rounds = p.extra.get("rounds", 4)
        return u32_to_f32(mix_u32(as_u32(x), rounds,
                                  backend=p.extra.get("backend")))


@register
class EncryptionRounds(DwarfComponent):
    """Feistel-network rounds over u32 pairs (TEA-like, add/shift/xor)."""

    name = "encryption"
    dwarf = "logic"

    dynamic_extras = ("rounds",)

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rounds = loop_count(p.extra.get("rounds", 4))
        u = as_u32(x)
        n2 = (u.shape[0] // 2) * 2
        k0, k1 = jnp.uint32(0x9E3779B9), jnp.uint32(0x7F4A7C15)

        def round_fn(i, st):
            s, v0, v1 = st
            s = s + k0
            v0 = v0 + (((v1 << 4) ^ (v1 >> 5)) + v1) ^ (s + k1)
            v1 = v1 + (((v0 << 4) ^ (v0 >> 5)) + v0) ^ (s + k0)
            return (s, v0, v1)

        _, v0, v1 = jax.lax.fori_loop(
            0, rounds, round_fn, (jnp.uint32(0), u[:n2:2], u[1:n2:2]))
        out = jnp.stack([v0, v1], axis=1).reshape(-1)
        return u32_to_f32(jnp.concatenate([out, u[n2:]]))


@register
class RLECompression(DwarfComponent):
    """Run-length-style compression proxy: quantize + run-boundary flags."""

    name = "compression"
    dwarf = "logic"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        q = (as_u32(x) >> jnp.uint32(24)).astype(jnp.uint32)   # 8-bit symbols
        boundary = jnp.concatenate(
            [jnp.ones((1,), jnp.uint32), (q[1:] != q[:-1]).astype(jnp.uint32)])
        run_id = jnp.cumsum(boundary)
        packed = q ^ (run_id.astype(jnp.uint32) << jnp.uint32(8))
        return u32_to_f32(_mix32(packed))
