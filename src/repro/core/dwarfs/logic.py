"""Logic computation dwarf — hash / compression / encryption-style bit ops."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import (ComponentParams, DwarfComponent, as_u32, register,
                   u32_to_f32)


def _mix32(u: jnp.ndarray) -> jnp.ndarray:
    """murmur3-style finalizer round (xor-shift-multiply avalanche)."""
    u = u ^ (u >> 16)
    u = u * jnp.uint32(0x85EBCA6B)
    u = u ^ (u >> 13)
    u = u * jnp.uint32(0xC2B2AE35)
    u = u ^ (u >> 16)
    return u


@register
class HashComputation(DwarfComponent):
    name = "hash"
    dwarf = "logic"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rounds = int(p.extra.get("rounds", 4))
        u = as_u32(x)
        for _ in range(rounds):
            u = _mix32(u)
        return u32_to_f32(u)


@register
class EncryptionRounds(DwarfComponent):
    """Feistel-network rounds over u32 pairs (TEA-like, add/shift/xor)."""

    name = "encryption"
    dwarf = "logic"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rounds = int(p.extra.get("rounds", 4))
        u = as_u32(x)
        n2 = (u.shape[0] // 2) * 2
        v0, v1 = u[:n2:2], u[1:n2:2]
        k0, k1 = jnp.uint32(0x9E3779B9), jnp.uint32(0x7F4A7C15)
        s = jnp.uint32(0)
        for _ in range(rounds):
            s = s + k0
            v0 = v0 + (((v1 << 4) ^ (v1 >> 5)) + v1) ^ (s + k1)
            v1 = v1 + (((v0 << 4) ^ (v0 >> 5)) + v0) ^ (s + k0)
        out = jnp.stack([v0, v1], axis=1).reshape(-1)
        return u32_to_f32(jnp.concatenate([out, u[n2:]]))


@register
class RLECompression(DwarfComponent):
    """Run-length-style compression proxy: quantize + run-boundary flags."""

    name = "compression"
    dwarf = "logic"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        q = (as_u32(x) >> jnp.uint32(24)).astype(jnp.uint32)   # 8-bit symbols
        boundary = jnp.concatenate(
            [jnp.ones((1,), jnp.uint32), (q[1:] != q[:-1]).astype(jnp.uint32)])
        run_id = jnp.cumsum(boundary)
        packed = q ^ (run_id.astype(jnp.uint32) << jnp.uint32(8))
        return u32_to_f32(_mix32(packed))
