"""Set computation dwarf — intersection / union / Jaccard (paper Fig. 3)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import (ComponentParams, DwarfComponent, as_u32, register,
                   u32_to_f32)


def _keys(x: jnp.ndarray, buckets: int) -> jnp.ndarray:
    return (as_u32(x) % jnp.uint32(buckets)).astype(jnp.uint32)


@register
class SetIntersection(DwarfComponent):
    """Sorted-set intersection of the two buffer halves (searchsorted)."""

    name = "set_intersection"
    dwarf = "set"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        buckets = int(p.extra.get("buckets", 1 << 16))
        keys = _keys(x, buckets)
        h = keys.shape[0] // 2
        a = jnp.sort(keys[:h])
        b = jnp.sort(keys[h: 2 * h])
        pos = jnp.searchsorted(b, a)
        pos = jnp.clip(pos, 0, h - 1)
        member = (b[pos] == a).astype(jnp.uint32)
        out = jnp.concatenate([member * a, keys[2 * h:]])
        return u32_to_f32(out << jnp.uint32(8))


@register
class JaccardSimilarity(DwarfComponent):
    """|A∩B| / |A∪B| of the two halves — similarity-analysis kernel."""

    name = "jaccard"
    dwarf = "set"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        buckets = int(p.extra.get("buckets", 1 << 12))
        keys = _keys(x, buckets)
        h = keys.shape[0] // 2
        a_mask = jnp.zeros((buckets,), jnp.bool_).at[keys[:h]].set(True)
        b_mask = jnp.zeros((buckets,), jnp.bool_).at[keys[h: 2 * h]].set(True)
        inter = jnp.sum(a_mask & b_mask)
        union = jnp.sum(a_mask | b_mask)
        sim = inter.astype(jnp.float32) / jnp.maximum(union, 1).astype(jnp.float32)
        return x * 0.0 + sim


@register
class SetDifference(DwarfComponent):
    """A \\ B via sorted membership test (Project/Filter analog)."""

    name = "set_difference"
    dwarf = "set"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        buckets = int(p.extra.get("buckets", 1 << 16))
        keys = _keys(x, buckets)
        h = keys.shape[0] // 2
        a = keys[:h]
        b = jnp.sort(keys[h: 2 * h])
        pos = jnp.clip(jnp.searchsorted(b, a), 0, h - 1)
        keep = (b[pos] != a)
        out = jnp.where(keep, a, jnp.uint32(0))
        return u32_to_f32(jnp.concatenate([out, keys[2 * h:]]) << jnp.uint32(8))
