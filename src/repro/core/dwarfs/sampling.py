"""Sampling computation dwarf — random & interval sampling (paper Fig. 3)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ComponentParams, DwarfComponent, fit_buffer, register


@register
class RandomSampling(DwarfComponent):
    """Uniform random subsampling with replacement (RNG + gather)."""

    name = "random_sampling"
    dwarf = "sampling"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        n = x.shape[0]
        frac = float(p.extra.get("fraction", 0.25))
        m = max(1, int(n * frac))
        idx = jax.random.randint(rng, (m,), 0, n)
        return fit_buffer(x[idx], n)


@register
class IntervalSampling(DwarfComponent):
    """Strided (systematic) sampling — TeraSort partitioner's sampler."""

    name = "interval_sampling"
    dwarf = "sampling"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        stride = int(p.extra.get("stride", 4))
        s = x[::stride]
        return fit_buffer(s, x.shape[0])


@register
class MonteCarlo(DwarfComponent):
    """Monte-Carlo estimation (RNG-dominant): mean of f over random draws."""

    name = "monte_carlo"
    dwarf = "sampling"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        n = x.shape[0]
        u = jax.random.uniform(rng, (n,))
        v = jax.random.uniform(jax.random.fold_in(rng, 1), (n,))
        inside = (u * u + v * v) < 1.0
        est = inside.astype(jnp.float32).mean()
        return x * 0.0 + est
