"""Matrix computation dwarf — matmul, distance calculations (paper Fig. 3)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ComponentParams, DwarfComponent, as_chunks, fit_buffer, register


@register
class MatMul(DwarfComponent):
    """Dense C = A @ B on (rows, chunk) x (chunk, chunk) — MXU-dominant."""

    name = "matrix_multiplication"
    dwarf = "matrix"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        a = as_chunks(x, p)                     # (m, c)
        c = a.shape[1]
        b = fit_buffer(x, c * c).reshape(c, c)  # weight tile from same data
        out = a @ b
        return out * (1.0 / c)                  # keep magnitudes bounded


@register
class MatrixConstruction(DwarfComponent):
    """Outer-product construction A = u v^T (PageRank matrix build analog)."""

    name = "matrix_construction"
    dwarf = "matrix"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        a = as_chunks(x, p)
        u = a.mean(axis=1)
        v = a.mean(axis=0)
        return a + 0.1 * jnp.outer(u, v)


@register
class EuclideanDistance(DwarfComponent):
    """Pairwise point-to-centroid euclidean distances (Kmeans hotspot)."""

    name = "euclidean_distance"
    dwarf = "matrix"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        pts = as_chunks(x, p)                       # (n, d)
        k = int(p.extra.get("centers", 16))
        ctr = fit_buffer(x[::-1], k * pts.shape[1]).reshape(k, -1)
        # ||a-b||^2 = ||a||^2 - 2 a.b + ||b||^2  -> dot-dominant
        d2 = (jnp.sum(pts * pts, 1, keepdims=True)
              - 2.0 * pts @ ctr.T + jnp.sum(ctr * ctr, 1))
        return d2 * (1.0 / pts.shape[1])


@register
class CosineDistance(DwarfComponent):
    name = "cosine_distance"
    dwarf = "matrix"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        pts = as_chunks(x, p)
        k = int(p.extra.get("centers", 16))
        ctr = fit_buffer(x[::-1], k * pts.shape[1]).reshape(k, -1)
        num = pts @ ctr.T
        den = (jnp.linalg.norm(pts, axis=1, keepdims=True)
               * jnp.linalg.norm(ctr, axis=1) + 1e-6)
        return 1.0 - num / den
