"""Basic statistic computation dwarf — count, average, histogram, probability."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import (ComponentParams, DwarfComponent, as_chunks, as_u32,
                   mix_u32, register)


@register
class CountAverage(DwarfComponent):
    """Per-chunk count/mean/variance (cluster count & average, Kmeans)."""

    name = "count_average"
    dwarf = "statistic"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        mean = rows.mean(axis=1, keepdims=True)
        var = rows.var(axis=1, keepdims=True)
        return (rows - mean) / jnp.sqrt(var + 1e-6)


@register
class Histogram(DwarfComponent):
    """Hash-bucketize + bincount (word-count / TF-IDF style counting).

    The bucket index is derived through murmur3 avalanche rounds
    (``mix_rounds``, default 1) — the hash hot spot dispatches to the
    ``kernels.hash_mix`` Pallas kernel on accelerator backends.
    """

    name = "histogram"
    dwarf = "statistic"

    dynamic_extras = ("mix_rounds",)
    pallas_static = ("mix_rounds",)
    pallas_capable = True

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        bins = int(p.extra.get("bins", 256))
        u = mix_u32(as_u32(x), p.extra.get("mix_rounds", 1),
                    backend=p.extra.get("backend"))
        idx = (u % jnp.uint32(bins)).astype(jnp.int32)
        counts = jnp.zeros((bins,), jnp.float32).at[idx].add(1.0)
        return counts[idx] * (1.0 / x.shape[0])


@register
class ProbabilityStats(DwarfComponent):
    """Softmax-normalized probabilities + entropy (naive-bayes style)."""

    name = "probability"
    dwarf = "statistic"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        rows = as_chunks(x, p)
        logp = jax.nn.log_softmax(rows, axis=1)
        ent = -jnp.sum(jnp.exp(logp) * logp, axis=1, keepdims=True)
        return logp + ent


@register
class DegreeCount(DwarfComponent):
    """Grouped counting via segment-sum (out/in degree counting); the
    hash-derived group id dispatches like :class:`Histogram`."""

    name = "grouped_count"
    dwarf = "statistic"

    dynamic_extras = ("mix_rounds",)
    pallas_static = ("mix_rounds",)
    pallas_capable = True

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        groups = int(p.extra.get("groups", 128))
        u = mix_u32(as_u32(x), p.extra.get("mix_rounds", 1),
                    backend=p.extra.get("backend"))
        gid = (u % jnp.uint32(groups)).astype(jnp.int32)
        sums = jax.ops.segment_sum(x, gid, num_segments=groups)
        cnts = jax.ops.segment_sum(jnp.ones_like(x), gid, num_segments=groups)
        means = sums / jnp.maximum(cnts, 1.0)
        return x - means[gid]
