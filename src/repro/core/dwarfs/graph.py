"""Graph computation dwarf — construction, traversal, degree counting.

Irregular gather/scatter-dominant access patterns (the paper singles graph
computations out for exactly this).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ComponentParams, DwarfComponent, as_u32, register


def _edges_from_buffer(x: jnp.ndarray, n_vertices: int):
    u = as_u32(x)
    n2 = (u.shape[0] // 2) * 2
    src = (u[:n2:2] % jnp.uint32(n_vertices)).astype(jnp.int32)
    dst = (u[1:n2:2] % jnp.uint32(n_vertices)).astype(jnp.int32)
    return src, dst


@register
class GraphConstruction(DwarfComponent):
    """Edge list -> degree arrays (out/in degree count of nodes)."""

    name = "graph_construction"
    dwarf = "graph"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        v = int(p.extra.get("vertices", max(64, x.shape[0] // 8)))
        src, dst = _edges_from_buffer(x, v)
        out_deg = jnp.zeros((v,), jnp.float32).at[src].add(1.0)
        in_deg = jnp.zeros((v,), jnp.float32).at[dst].add(1.0)
        gathered = out_deg[src] + in_deg[dst]       # gather back along edges
        return gathered


@register
class GraphTraversal(DwarfComponent):
    """Frontier-propagation BFS sweep (hops x scatter-max + gather)."""

    name = "graph_traversal"
    dwarf = "graph"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        v = int(p.extra.get("vertices", max(64, x.shape[0] // 8)))
        hops = int(p.extra.get("hops", 4))
        src, dst = _edges_from_buffer(x, v)
        frontier = jnp.zeros((v,), jnp.float32).at[0].set(1.0)

        def hop(f, _):
            nxt = jnp.zeros((v,), jnp.float32).at[dst].max(f[src])
            return jnp.maximum(f, nxt), ()

        frontier, _ = jax.lax.scan(hop, frontier, None, length=hops)
        return frontier[dst % v]


@register
class SpMV(DwarfComponent):
    """Sparse matrix-vector product y[dst] += x[src]/deg[src] (PageRank)."""

    name = "spmv"
    dwarf = "graph"

    def apply(self, x: jnp.ndarray, p: ComponentParams, rng: jax.Array):
        v = int(p.extra.get("vertices", max(64, x.shape[0] // 8)))
        src, dst = _edges_from_buffer(x, v)
        deg = jnp.zeros((v,), jnp.float32).at[src].add(1.0)
        rank = jnp.full((v,), 1.0 / v)
        contrib = rank[src] / jnp.maximum(deg[src], 1.0)
        new_rank = jnp.zeros((v,), jnp.float32).at[dst].add(contrib)
        return new_rank[dst]
