"""Workload characterization — the paper's 'tracing and profiling' stage.

The paper profiles a Hadoop workload (JVM tracing, CPU/cycle breakdown),
identifies hotspot functions, and maps them to dwarfs with initial weights
proportional to execution ratios (§2.3).  Our TPU-native analog:

  workload (jit-able fn + input specs + shardings)
    -> AOT lower + compile                       (the "run" a simulator costs)
    -> HLO cost analysis (trip-count corrected)  (the "perf counters")
    -> op-class mix -> dwarf weights             (the "hotspot -> dwarf" map)

``characterize`` is also the measurement used for the full-model dry-run and
for proxy validation, so proxy and original are measured identically —
mirroring the paper running `perf` on both sides.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax

from .metrics import (SORT_ELEM_COST, CostReport, HloCostAnalyzer, Roofline,
                      analyze_hlo_text, metric_vector, roofline_from_report)


@dataclasses.dataclass
class WorkloadProfile:
    name: str
    report: CostReport
    metrics: Dict[str, float]
    lower_s: float
    compile_s: float
    exec_s: float = 0.0              # wall time when actually executed
    arg_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0
    out_bytes_per_device: float = 0.0
    num_devices: int = 1
    hlo_lines: int = 0
    collective_schedule: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def simulation_s(self) -> float:
        """'Architecture simulation' cost for this workload: AOT pipeline."""
        return self.lower_s + self.compile_s

    @property
    def peak_bytes_per_device(self) -> float:
        return self.arg_bytes_per_device + self.temp_bytes_per_device

    def roofline(self, chips: int, model_flops: float = 0.0) -> Roofline:
        return roofline_from_report(self.report, chips, model_flops)

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "metrics": self.metrics,
            "lower_s": self.lower_s,
            "compile_s": self.compile_s,
            "exec_s": self.exec_s,
            "simulation_s": self.simulation_s,
            "arg_bytes_per_device": self.arg_bytes_per_device,
            "temp_bytes_per_device": self.temp_bytes_per_device,
            "num_devices": self.num_devices,
            "collective_schedule": self.collective_schedule,
            "report": self.report.to_json(),
        }


def characterize(fn: Callable, args: Sequence[Any], *,
                 name: str = "workload",
                 in_shardings: Any = None,
                 out_shardings: Any = None,
                 donate_argnums: Sequence[int] = (),
                 static_argnums: Sequence[int] = (),
                 mesh: Optional[jax.sharding.Mesh] = None,
                 execute: bool = False,
                 exec_iters: int = 3,
                 host_bytes: float = 0.0) -> WorkloadProfile:
    """Lower + compile ``fn`` and derive the metric vector from the HLO.

    ``args`` may be ShapeDtypeStructs (dry-run) or concrete arrays; with
    ``execute=True`` (requires concrete arrays) wall-time is also measured,
    which is how the paper-reproduction benchmarks time original vs. proxy.
    """
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    jfn = jax.jit(fn, donate_argnums=tuple(donate_argnums),
                  static_argnums=tuple(static_argnums), **kw)

    def _lower():
        if mesh is not None:
            with mesh:
                return jfn.lower(*args)
        return jfn.lower(*args)

    t0 = time.perf_counter()
    lowered = _lower()
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    text = compiled.as_text()
    report = analyze_hlo_text(text)
    mem = compiled.memory_analysis()
    exec_s = 0.0
    if execute:
        # concrete execution (paper workloads + proxies run for real on CPU)
        if mesh is not None:
            with mesh:
                out = jfn(*args)
                jax.block_until_ready(out)
                t3 = time.perf_counter()
                for _ in range(exec_iters):
                    out = jfn(*args)
                jax.block_until_ready(out)
                exec_s = (time.perf_counter() - t3) / exec_iters
        else:
            out = jfn(*args)
            jax.block_until_ready(out)
            t3 = time.perf_counter()
            for _ in range(exec_iters):
                out = jfn(*args)
            jax.block_until_ready(out)
            exec_s = (time.perf_counter() - t3) / exec_iters

    metrics = metric_vector(report, host_bytes=host_bytes, exec_time=exec_s)
    return WorkloadProfile(
        name=name, report=report, metrics=metrics,
        lower_s=t1 - t0, compile_s=t2 - t1, exec_s=exec_s,
        arg_bytes_per_device=float(mem.argument_size_in_bytes),
        temp_bytes_per_device=float(mem.temp_size_in_bytes),
        out_bytes_per_device=float(mem.output_size_in_bytes),
        num_devices=len(jax.devices()),
        hlo_lines=text.count("\n"),
        collective_schedule=dict(report.collective_count),
    )


# ---------------------------------------------------------------------------
# Dwarf decomposition ("hotspot analysis" -> initial weights)
# ---------------------------------------------------------------------------

#: share of each HLO cost channel attributed to each dwarf
def decompose_to_dwarfs(report: CostReport) -> Dict[str, float]:
    """Map a workload's HLO cost channels to the dwarfs: the paper's eight
    (§2.2) plus the Data-Dwarfs AI classes (arxiv 1802.00699).

    Returns normalized weights — the 'initial weights proportional to
    execution ratios' of the paper's parameter-initialization stage.
    ``attention_flops`` (exp-gated contractions, see
    :class:`~repro.core.metrics.HloCostAnalyzer`) feed the ``attention``
    dwarf; when a workload shows *any* attention mass its remaining dot
    flops are classed as ``gemm`` (dense-layer train/inference GEMMs)
    rather than the big-data ``matrix`` dwarf — a pure big-data report
    (no attention signal) keeps the original eight-dwarf decomposition,
    so TeraSort/Kmeans/PageRank/SIFT attributions are unchanged.
    """
    attn = max(min(report.attention_flops, report.flops), 0.0)
    plain = max(report.flops - attn, 0.0) / 2.0
    # Cost channels in comparable units (approx. element-ops)
    channels = {
        "matrix": plain if attn <= 0 else 0.0,            # MAC -> elem-ops
        "gemm": plain if attn > 0 else 0.0,
        "attention": attn / 2.0,
        "transform": report.fft_elems * 10.0,
        "sort": report.sort_elems * SORT_ELEM_COST,
        "sampling": report.rng_elems * 4.0,
        "graph": report.gather_elems * 2.0,
        "statistic": report.reduce_elems,
        "logic": report.logic_elems,
        "set": report.compare_elems,
    }
    total = sum(channels.values())
    if total <= 0:
        return {k: 1.0 / len(channels) for k in channels}
    return {k: v / total for k, v in channels.items()}
