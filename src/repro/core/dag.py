"""DAG-like combination of dwarf components (paper §2.1/§2.3).

A node represents an original or intermediate data set; an edge represents a
dwarf component applied with its own tunable parameters.  ``weight`` is the
component's contribution — realized as a repeat count, so doubling a weight
doubles that component's share of the proxy's cost channels (which is exactly
what the auto-tuner exploits).

Repeats execute as a ``jax.lax.fori_loop``, so graph size and compile time
are O(edges) — independent of the DAG's total weight.  Every edge's tunables
split into a **static structure** (component, the shape-affecting sizes —
:meth:`Edge.structure_key`) and a **dynamic param vector** (weight plus
shape-free extras — :meth:`ProxyDAG.dynamic_params`) that
:meth:`ProxyDAG.build_parametric` accepts as a jitted argument: stepping a
dynamic param re-executes the same compiled program, no retrace.

Every execution form lowers through one pipeline —
:func:`repro.core.schedule.lower` — which turns the DAG into an
:class:`~repro.core.schedule.ExecutionPlan` (ordered fused stages + the
population bucket schedule).  The historical ``build*`` methods remain as
thin shims over an *unfused* plan (legacy one-stage-per-edge semantics,
current params baked):

* :meth:`ProxyDAG.build` — one jit-able ``fn(rng) -> scalar`` with the
  current params baked in (fully analyzable HLO with ``known_trip_count``
  weights for the profiler).
* :meth:`ProxyDAG.build_parametric` — ``fn(rng, dyn) -> scalar``, the
  compile-once/run-many form the ``repro.core.engine`` cost model keys on
  ``structure_key()``.
* :meth:`ProxyDAG.build_population` — the vmapped candidate-batch form.
* :meth:`ProxyDAG.build_stages` / :meth:`ProxyDAG.build_stages_parametric`
  — deprecated per-edge staging; staged drivers consume
  ``ExecutionPlan.stages_parametric()`` (fused-stage granularity) instead.

The stacks (:mod:`repro.api.stack`) lower with the live fusion threshold
(``REPRO_FUSION_THRESHOLD``) and cache executables per plan structure key.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dwarfs import ComponentParams, get_component
from .dwarfs.base import fit_buffer


class StructureError(ValueError):
    """A DAG violates the structural invariants machine-generated
    structures must hold (see :meth:`ProxyDAG.validate_structure`)."""

#: dynamic fields passed as i32 (they become loop bounds); the rest are f32
_INT_DYNAMIC = {"weight", "rounds", "mix_rounds", "hops", "levels"}


def _json_scalar(v):
    """Coerce a param scalar to its JSON-native type (numpy ints/floats —
    a tuner-applied vector's dtype — are not json-serializable)."""
    if isinstance(v, bool) or isinstance(v, str):
        return v
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        return float(v)
    return v


@dataclasses.dataclass
class Edge:
    component: str                 # registry name of the dwarf component
    src: Sequence[str]             # input node names (>=1, concatenated)
    dst: str                       # output node name
    params: ComponentParams = dataclasses.field(default_factory=ComponentParams)

    def to_json(self) -> Dict:
        p = self.params.rounded()
        return {
            "component": self.component, "src": list(self.src), "dst": self.dst,
            "data_size": int(p.data_size), "chunk_size": int(p.chunk_size),
            "parallelism": int(p.parallelism), "weight": int(p.weight),
            # machine-generated params (tuner vectors, mutations) may carry
            # numpy scalars; normalize to JSON-native types so the spec
            # round-trip is lossless for any structure, not just the
            # hand-written proxies
            "extra": {k: _json_scalar(v) for k, v in p.extra.items()},
        }

    @classmethod
    def from_json(cls, d: Dict) -> "Edge":
        return cls(d["component"], list(d["src"]), d["dst"],
                   ComponentParams(int(d.get("data_size", 1 << 14)),
                                   int(d.get("chunk_size", 256)),
                                   int(d.get("parallelism", 1)),
                                   int(round(float(d.get("weight", 1)))),
                                   dict(d.get("extra", {}))))

    # -- static / dynamic split ---------------------------------------------

    def dynamic_fields(self) -> Tuple[str, ...]:
        """Tunables steppable without a retrace: ``weight`` + the
        component's declared shape-free extras present on this edge."""
        return get_component(self.component).dynamic_fields(
            self.params.rounded())

    def structure_key(self) -> Tuple:
        """Hashable key of everything that affects this edge's compiled
        shape/program: component, shape-affecting sizes, static extras,
        the *names* (not values) of its dynamic params, and — for
        components with a Pallas fast path — the *resolved* backend and
        interpret mode, so a ``REPRO_BACKEND`` / ``REPRO_PALLAS_INTERPRET``
        change never hits an executable compiled for the other setting."""
        p = self.params.rounded()
        comp = get_component(self.component)
        dyn = set(self.dynamic_fields())
        static_extra = tuple(sorted(
            (k, v) for k, v in p.extra.items() if k not in dyn))
        backend = None
        if comp.pallas_capable:
            from ..kernels.dispatch import default_interpret
            backend = ("pallas", default_interpret()) \
                if comp.uses_pallas(p) else "xla"
        return (self.component, p.data_size, p.chunk_size, p.parallelism,
                static_extra, tuple(sorted(dyn - {"weight"})), backend)

    def dynamic_values(self) -> Dict[str, jnp.ndarray]:
        """Current dynamic param values as jit-argument scalars."""
        p = self.params.rounded()
        out: Dict[str, jnp.ndarray] = {}
        for f in self.dynamic_fields():
            v = p.weight if f == "weight" else p.extra[f]
            if f in _INT_DYNAMIC:
                out[f] = jnp.asarray(int(round(float(v))), jnp.int32)
            else:
                out[f] = jnp.asarray(float(v), jnp.float32)
        return out


# -- shared edge semantics (build and build_stages must agree exactly) -------


def _init_sources(sources: Dict[str, int], rng: jax.Array
                  ) -> Dict[str, jnp.ndarray]:
    return {sname: jax.random.normal(jax.random.fold_in(rng, i),
                                     (int(n),), jnp.float32)
            for i, (sname, n) in enumerate(sorted(sources.items()))}


def _gather_inputs(e: Edge, xs: List[jnp.ndarray]) -> jnp.ndarray:
    return xs[0] if len(xs) == 1 else jnp.concatenate(
        [fit_buffer(v, e.params.data_size) for v in xs])


def _edge_out(e: Edge, ei: int, x: jnp.ndarray, rng: jax.Array,
              dyn: Optional[Dict[str, jnp.ndarray]] = None) -> jnp.ndarray:
    """Apply edge ``e`` — ``weight`` repeats as a ``fori_loop``.

    ``dyn`` (from :meth:`ProxyDAG.dynamic_params`) overrides the weight and
    shape-free extras with traced scalars; without it every value is baked
    in statically (the loop still has a constant ``known_trip_count``, so
    the HLO cost analyzer attributes repeats exactly while the jaxpr stays
    O(1) in the weight).
    """
    comp = get_component(e.component)
    p = e.params
    if dyn:
        extra_dyn = {k: v for k, v in dyn.items() if k != "weight"}
        if extra_dyn:
            p = p.replace(extra={**p.extra, **extra_dyn})
    w = dyn["weight"] if dyn and "weight" in dyn else p.weight
    x0 = fit_buffer(x, p.data_size)
    if isinstance(w, int) and w == 0:        # tuner pruned this edge
        return x0

    def body(i, out):
        r = jax.random.fold_in(rng, 10_000 + 131 * ei + i)
        return fit_buffer(comp(out, p, r), p.data_size)

    return jax.lax.fori_loop(0, w, body, x0)


def _accumulate(prev: Optional[jnp.ndarray], out: jnp.ndarray) -> jnp.ndarray:
    return out if prev is None else prev + fit_buffer(out, prev.shape[0])


def _terminals(edges: List[Edge]) -> List[str]:
    produced = {e.dst for e in edges}
    consumed = {s for e in edges for s in e.src}
    return sorted(produced - consumed) or sorted(produced)


@dataclasses.dataclass
class ProxyDAG:
    """Executable DAG of weighted dwarf components."""

    name: str
    sources: Dict[str, int]        # source node -> element count
    edges: List[Edge]
    sink: Optional[str] = None     # node reduced to the scalar output

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        known = set(self.sources)
        for e in self.edges:
            for s in e.src:
                if s not in known:
                    raise ValueError(
                        f"edge {e.component}: input node {s!r} not yet defined "
                        f"(DAG must be topologically ordered)")
            known.add(e.dst)
        if self.sink is not None and self.sink not in known:
            raise ValueError(f"sink {self.sink!r} not produced by any edge")

    def _rounded_edges(self) -> List[Edge]:
        return [dataclasses.replace(e, params=e.params.rounded())
                for e in self.edges]

    # -- static / dynamic split ---------------------------------------------

    def structure_key(self) -> Tuple:
        """Hashable key of the DAG's compiled structure: topology, sources,
        every edge's static structure.  Two DAGs with equal keys share one
        compiled executable — only their dynamic param vectors differ."""
        return (tuple(sorted(self.sources.items())),
                tuple((tuple(e.src), e.dst, e.structure_key())
                      for e in self.edges),
                self.sink)

    def dynamic_params(self) -> Tuple[Dict[str, jnp.ndarray], ...]:
        """Per-edge dynamic param pytree, the second argument of
        :meth:`build_parametric` — stepping any leaf value re-runs the
        cached executable without retracing."""
        return tuple(e.dynamic_values() for e in self.edges)

    def canonical_structure_key(self) -> Tuple:
        """:meth:`structure_key` made stable under isomorphic relabeling.

        Node names are replaced by canonical ids — sources by their
        sorted-name position (the same index :func:`_init_sources` folds
        into the rng), edge outputs by first-production order — so two
        DAGs that differ only in node names share one key.  Equal keys
        imply bit-identical computation: every rng fold is keyed on the
        edge index and the sorted source position, both of which the key
        preserves.  This is what the plan/executable caches key on, so
        machine-generated structures that are mere relabelings of an
        already-compiled structure never cost a second compile."""
        ids: Dict[str, Tuple] = {
            s: ("s", i) for i, s in enumerate(sorted(self.sources))}
        nxt = 0
        entries = []
        for e in self.edges:
            srcs = tuple(ids[s] for s in e.src)
            if e.dst not in ids:
                ids[e.dst] = ("n", nxt)
                nxt += 1
            entries.append((srcs, ids[e.dst], e.structure_key()))
        return (tuple(int(n) for _, n in sorted(self.sources.items())),
                tuple(entries),
                None if self.sink is None else ids.get(self.sink))

    # -- structural invariants (machine-generated structures) ---------------

    def contributing_mask(self) -> List[bool]:
        """``mask[i]`` — does edge ``i``'s output reach the DAG's output
        (the sink, or any terminal when no sink is set)?"""
        outputs = ({self.sink} if self.sink is not None
                   else set(_terminals(self.edges)))
        live = set(outputs)
        mask = [False] * len(self.edges)
        # walk edges in reverse topological (list) order: an edge is live
        # when its dst is, and then so are its inputs
        for i in range(len(self.edges) - 1, -1, -1):
            if self.edges[i].dst in live:
                mask[i] = True
                live.update(self.edges[i].src)
        return mask

    def validate_structure(self) -> None:
        """The invariants every machine-generated structure must satisfy,
        beyond :meth:`validate`'s topological ordering: at least one edge,
        an output to reduce, and every edge connected to it (a mutation
        must never leave dead compute the metric vector charges for but
        the workload semantics cannot justify)."""
        try:
            self.validate()
        except ValueError as e:
            raise StructureError(str(e)) from e
        if not self.edges:
            raise StructureError(f"{self.name}: structure has no edges")
        dead = [i for i, ok in enumerate(self.contributing_mask()) if not ok]
        if dead:
            names = [f"{i}:{self.edges[i].component}" for i in dead[:4]]
            raise StructureError(
                f"{self.name}: edges {names} do not reach the "
                f"{'sink' if self.sink is not None else 'terminals'}")

    # -- build (thin shims over the ExecutionPlan lowering pipeline) ---------

    def _legacy_plan(self):
        """Fresh *unfused* plan (one stage per edge, current params baked):
        the exact legacy execution semantics every ``build*`` shim keeps."""
        from .schedule import lower
        return lower(self, threshold=0.0, cache=False)

    def build(self) -> Callable[[jax.Array], jnp.ndarray]:
        """Returns a jit-able fn(rng) -> scalar executing the whole DAG."""
        return self._legacy_plan().build()

    def build_parametric(self) -> Callable:
        """Returns ``fn(rng, dyn) -> scalar`` where ``dyn`` is a
        :meth:`dynamic_params`-shaped pytree of traced scalars — the
        compile-once/run-many execution form."""
        return self._legacy_plan().build_parametric()

    def build_population(self) -> Callable:
        """Returns ``fn(rng, dyn_batched) -> (n,)`` evaluating a whole
        *population* of dynamic-param candidates in one call:
        ``dyn_batched`` is a :meth:`dynamic_params`-shaped pytree whose
        leaves carry a leading candidate axis (see
        ``ParamSpace.stack_candidates``), vmapped over so every candidate
        shares the rng, the generated sources, and — once jitted — a
        single compiled executable (zero retraces per candidate).  Stacks
        additionally stratify candidate batches into weight buckets (see
        :meth:`repro.core.schedule.ExecutionPlan.bucket_schedule`)."""
        return self._legacy_plan().build_population()

    def build_stages(self):
        """Deprecated per-edge staging (see :meth:`build_stages_parametric`
        for the protocol); staged drivers consume
        ``schedule.lower(dag).stages_parametric()`` — fused-stage
        granularity — instead."""
        init_fn, stages, finalize_fn = self.build_stages_parametric()
        return (init_fn,
                [(srcs, dst, (lambda s: lambda rng, xs, prev:
                              s(rng, xs, prev, None))(stage))
                 for srcs, dst, stage, _key in stages],
                finalize_fn)

    def build_stages_parametric(self):
        """Deprecated: use ``schedule.lower(dag).stages_parametric()``.

        Legacy protocol kept for old staged drivers: stages are
        ``(src_names, dst, stage_fn, stage_key)`` with
        ``stage_fn(rng, xs, prev, dyn_e)`` taking the *edge's* dynamic
        param dict (or ``None``) and ``stage_key`` the
        ``(edge_idx, Edge.structure_key())`` pair.  The ExecutionPlan form
        differs in granularity (fused stages) and passes the member dyn
        dicts as a tuple."""
        warnings.warn(
            "ProxyDAG.build_stages_parametric is deprecated; use "
            "repro.core.schedule.lower(dag).stages_parametric()",
            DeprecationWarning, stacklevel=2)
        init_fn, stages, finalize_fn = \
            self._legacy_plan().stages_parametric()
        legacy = []
        for srcs, dst, fn, key in stages:
            members, skeys = key
            legacy.append(
                (srcs, dst,
                 (lambda f: lambda rng, xs, prev, dyn_e:
                  f(rng, xs, prev, (dyn_e,)))(fn),
                 (members[0], skeys[0])))
        return init_fn, legacy, finalize_fn

    # -- serialization -------------------------------------------------------

    def to_json(self) -> Dict:
        return {
            "name": self.name,
            "sources": dict(self.sources),
            "edges": [e.to_json() for e in self.edges],
            "sink": self.sink,
        }

    @classmethod
    def from_json(cls, d: Dict) -> "ProxyDAG":
        return cls(name=d["name"],
                   sources={k: int(v) for k, v in d["sources"].items()},
                   edges=[Edge.from_json(e) for e in d["edges"]],
                   sink=d.get("sink"))

    # -- deprecated tuner plumbing ------------------------------------------
    # The auto-tuner now operates on repro.api.params.ParamSpace (a named
    # pytree with per-leaf bounds); these string handles remain as thin
    # shims for old callers.

    def get_param(self, edge_idx: int, field: str) -> float:
        warnings.warn("ProxyDAG.get_param is deprecated; use "
                      "repro.api.ParamSpace", DeprecationWarning, stacklevel=2)
        p = self.edges[edge_idx].params
        return float(p.extra[field] if field in p.extra else getattr(p, field))

    def set_param(self, edge_idx: int, field: str, value: float) -> None:
        warnings.warn("ProxyDAG.set_param is deprecated; use "
                      "repro.api.ParamSpace", DeprecationWarning, stacklevel=2)
        e = self.edges[edge_idx]
        if field in e.params.extra:
            e.params.extra[field] = value
        else:
            setattr(e.params, field, value)

    def param_space(self) -> List[tuple]:
        """Deprecated: legacy ``(edge_idx, field)`` handles.  Use
        :class:`repro.api.ParamSpace` for the named, bounded pytree view."""
        warnings.warn("ProxyDAG.param_space is deprecated; use "
                      "repro.api.ParamSpace", DeprecationWarning, stacklevel=2)
        from ..api.params import ParamSpace
        space = ParamSpace.from_dag(self)
        return [space.handle(i) for i in range(len(space))]


# ---------------------------------------------------------------------------
# structure mutation primitives (the Fig.-3 design-space moves)
# ---------------------------------------------------------------------------
#
# Each primitive is pure: it returns a NEW ProxyDAG (the input is never
# touched) that satisfies ``validate_structure`` whenever the input did,
# or raises StructureError when the requested move is illegal at that
# site.  The structural search (repro.core.structsearch) composes these
# into mutation proposals; the primitives themselves are deterministic so
# a mutation sequence replays identically from a seed.


def _copy_edges(edges: Sequence[Edge]) -> List[Edge]:
    return [Edge(e.component, list(e.src), e.dst,
                 dataclasses.replace(e.params, extra=dict(e.params.extra)))
            for e in edges]


def fresh_node(dag: ProxyDAG, prefix: str = "m") -> str:
    """First ``{prefix}{k}`` name unused by any node of ``dag`` —
    deterministic, so mutated structures serialize reproducibly."""
    used = set(dag.sources) | {e.dst for e in dag.edges}
    used.update(s for e in dag.edges for s in e.src)
    k = 0
    while f"{prefix}{k}" in used:
        k += 1
    return f"{prefix}{k}"


def _neighbor_params(e: Edge, component: str, weight: int) -> ComponentParams:
    """Params for a machine-inserted edge: the neighbouring edge's shape
    fields (the chain's carry size), no inherited extras — extras encode
    component-specific semantics the new component may not share."""
    p = e.params.rounded()
    return ComponentParams(data_size=p.data_size, chunk_size=p.chunk_size,
                           parallelism=p.parallelism, weight=int(weight))


def insert_edge(dag: ProxyDAG, idx: int, component: str,
                weight: int = 1) -> ProxyDAG:
    """Splice a new ``component`` edge into edge ``idx``'s input chain:
    the new edge reads edge ``idx``'s (single) input and edge ``idx`` is
    rewired to read the new intermediate node instead."""
    get_component(component)                 # unknown names fail fast
    e = dag.edges[idx]
    if len(e.src) != 1:
        raise StructureError(
            f"insert_edge: edge {idx} ({e.component}) has {len(e.src)} "
            f"inputs; splicing needs a single-input edge")
    mid = fresh_node(dag)
    edges = _copy_edges(dag.edges)
    edges[idx] = Edge(e.component, [mid], e.dst,
                      dataclasses.replace(e.params,
                                          extra=dict(e.params.extra)))
    new = Edge(component, list(e.src), mid,
               _neighbor_params(e, component, weight))
    edges.insert(idx, new)
    out = ProxyDAG(dag.name, dict(dag.sources), edges, dag.sink)
    out.validate_structure()
    return out


def insert_accumulating_edge(dag: ProxyDAG, src: str, dst_idx: int,
                             component: str, weight: int = 1) -> ProxyDAG:
    """Add a new ``component`` edge accumulating into edge ``dst_idx``'s
    output node (shared-dst addition, the DAG's join semantics), placed
    right after that producer so every downstream consumer sees the
    contribution.  ``src`` must be a node defined before the insertion
    point."""
    get_component(component)
    e = dag.edges[dst_idx]
    defined = set(dag.sources)
    for prior in dag.edges[: dst_idx + 1]:
        defined.add(prior.dst)
    if src not in defined:
        raise StructureError(
            f"insert_accumulating_edge: node {src!r} is not defined at "
            f"edge {dst_idx}")
    edges = _copy_edges(dag.edges)
    edges.insert(dst_idx + 1,
                 Edge(component, [src], e.dst,
                      _neighbor_params(e, component, weight)))
    out = ProxyDAG(dag.name, dict(dag.sources), edges, dag.sink)
    out.validate_structure()
    return out


def remove_edge(dag: ProxyDAG, idx: int) -> ProxyDAG:
    """Delete edge ``idx``.  An accumulating edge (its dst has another
    producer) simply drops; otherwise its consumers are bypassed onto the
    edge's first input (and the sink re-points likewise), so the DAG
    stays connected."""
    if len(dag.edges) <= 1:
        raise StructureError("remove_edge: structure has only one edge")
    e = dag.edges[idx]
    others = [o for j, o in enumerate(dag.edges) if j != idx]
    edges = _copy_edges(others)
    sink = dag.sink
    if not any(o.dst == e.dst for o in others):
        # sole producer: bypass consumers (and the sink) onto its input
        repl = e.src[0]
        for o in edges:
            o.src = [repl if s == e.dst else s for s in o.src]
        if sink == e.dst:
            sink = repl
        if sink is not None and sink not in set(dag.sources) | {
                o.dst for o in edges}:
            raise StructureError(
                f"remove_edge: removing edge {idx} orphans sink {sink!r}")
    out = ProxyDAG(dag.name, dict(dag.sources), edges, sink)
    out.validate_structure()
    return out


def swap_component(dag: ProxyDAG, idx: int, component: str) -> ProxyDAG:
    """Replace edge ``idx``'s dwarf component, keeping topology and shape
    params.  Extras are dropped: they parameterize the *old* component's
    semantics (hash rounds, histogram bins) and stale keys would leak
    into the new edge's static structure key."""
    get_component(component)
    e = dag.edges[idx]
    if component == e.component:
        raise StructureError(f"swap_component: edge {idx} already is "
                             f"{component!r}")
    edges = _copy_edges(dag.edges)
    edges[idx] = Edge(component, list(e.src), e.dst,
                      _neighbor_params(e, component,
                                       e.params.rounded().weight))
    out = ProxyDAG(dag.name, dict(dag.sources), edges, dag.sink)
    out.validate_structure()
    return out


def split_edge(dag: ProxyDAG, idx: int, first_weight: int) -> ProxyDAG:
    """Split edge ``idx`` (weight ``w >= 2``) into a chain of two
    same-component edges with weights ``first_weight`` and
    ``w - first_weight`` through a fresh intermediate node — the inverse
    of :func:`merge_chain`, and the move that exposes a chain position
    for a later :func:`swap_component`."""
    e = dag.edges[idx]
    w = e.params.rounded().weight
    first_weight = int(first_weight)
    if w < 2 or not 0 < first_weight < w:
        raise StructureError(
            f"split_edge: edge {idx} weight {w} cannot split at "
            f"{first_weight}")
    mid = fresh_node(dag)
    edges = _copy_edges(dag.edges)
    edges[idx] = Edge(e.component, [mid], e.dst,
                      dataclasses.replace(
                          e.params, weight=w - first_weight,
                          extra=dict(e.params.extra)))
    edges.insert(idx, Edge(e.component, list(e.src), mid,
                           dataclasses.replace(
                               e.params, weight=first_weight,
                               extra=dict(e.params.extra))))
    out = ProxyDAG(dag.name, dict(dag.sources), edges, dag.sink)
    out.validate_structure()
    return out


def merge_chain(dag: ProxyDAG, idx: int) -> ProxyDAG:
    """Merge edges ``idx`` and ``idx + 1`` — a private same-component
    chain — into one edge with the summed weight."""
    if idx + 1 >= len(dag.edges):
        raise StructureError(f"merge_chain: no edge after {idx}")
    a, b = dag.edges[idx], dag.edges[idx + 1]
    consumers = [j for j, o in enumerate(dag.edges)
                 for s in o.src if s == a.dst]
    mergeable = (a.component == b.component
                 and list(b.src) == [a.dst]
                 and consumers == [idx + 1]
                 and sum(1 for o in dag.edges if o.dst == a.dst) == 1
                 and a.dst not in dag.sources and a.dst != dag.sink
                 and a.structure_key() == b.structure_key())
    if not mergeable:
        raise StructureError(
            f"merge_chain: edges {idx},{idx + 1} are not a private "
            f"same-structure chain")
    edges = _copy_edges(dag.edges)
    merged = Edge(a.component, list(a.src), b.dst,
                  dataclasses.replace(
                      b.params,
                      weight=a.params.rounded().weight
                      + b.params.rounded().weight,
                      extra=dict(b.params.extra)))
    edges[idx: idx + 2] = [merged]
    out = ProxyDAG(dag.name, dict(dag.sources), edges, dag.sink)
    out.validate_structure()
    return out
