"""DAG-like combination of dwarf components (paper §2.1/§2.3).

A node represents an original or intermediate data set; an edge represents a
dwarf component applied with its own tunable parameters.  ``weight`` is the
component's contribution — realized as a repeat count, so doubling a weight
doubles that component's share of the proxy's cost channels (which is exactly
what the auto-tuner exploits).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .dwarfs import ComponentParams, get_component
from .dwarfs.base import fit_buffer


@dataclasses.dataclass
class Edge:
    component: str                 # registry name of the dwarf component
    src: Sequence[str]             # input node names (>=1, concatenated)
    dst: str                       # output node name
    params: ComponentParams = dataclasses.field(default_factory=ComponentParams)

    def to_json(self) -> Dict:
        p = self.params.rounded()
        return {
            "component": self.component, "src": list(self.src), "dst": self.dst,
            "data_size": p.data_size, "chunk_size": p.chunk_size,
            "parallelism": p.parallelism, "weight": p.weight,
            "extra": dict(p.extra),
        }


@dataclasses.dataclass
class ProxyDAG:
    """Executable DAG of weighted dwarf components."""

    name: str
    sources: Dict[str, int]        # source node -> element count
    edges: List[Edge]
    sink: Optional[str] = None     # node reduced to the scalar output

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        known = set(self.sources)
        for e in self.edges:
            for s in e.src:
                if s not in known:
                    raise ValueError(
                        f"edge {e.component}: input node {s!r} not yet defined "
                        f"(DAG must be topologically ordered)")
            known.add(e.dst)
        if self.sink is not None and self.sink not in known:
            raise ValueError(f"sink {self.sink!r} not produced by any edge")

    # -- build ---------------------------------------------------------------

    def build(self) -> Callable[[jax.Array], jnp.ndarray]:
        """Returns a jit-able fn(rng) -> scalar executing the whole DAG."""
        self.validate()
        edges = [dataclasses.replace(e, params=e.params.rounded())
                 for e in self.edges]
        sources = dict(self.sources)
        sink = self.sink

        def run(rng: jax.Array) -> jnp.ndarray:
            nodes: Dict[str, jnp.ndarray] = {}
            for i, (sname, n) in enumerate(sorted(sources.items())):
                nodes[sname] = jax.random.normal(
                    jax.random.fold_in(rng, i), (int(n),), jnp.float32)
            for ei, e in enumerate(edges):
                comp = get_component(e.component)
                xs = [nodes[s] for s in e.src]
                x = xs[0] if len(xs) == 1 else jnp.concatenate(
                    [fit_buffer(v, e.params.data_size) for v in xs])
                if e.params.weight == 0:             # tuner pruned this edge
                    out = fit_buffer(x, e.params.data_size)
                else:
                    out = x
                    for w in range(e.params.weight):  # weight = repeat count
                        r = jax.random.fold_in(rng, 10_000 + 131 * ei + w)
                        out = comp(fit_buffer(out, e.params.data_size),
                                   e.params, r)
                if e.dst in nodes:
                    prev = nodes[e.dst]
                    nodes[e.dst] = prev + fit_buffer(out, prev.shape[0])
                else:
                    nodes[e.dst] = out
            if sink is not None:
                return jnp.sum(nodes[sink])
            # default: reduce every terminal node
            produced = {e.dst for e in edges}
            consumed = {s for e in edges for s in e.src}
            terminals = sorted(produced - consumed) or sorted(produced)
            return sum(jnp.sum(nodes[t]) for t in terminals)

        return run

    # -- tuner plumbing --------------------------------------------------------

    def get_param(self, edge_idx: int, field: str) -> float:
        p = self.edges[edge_idx].params
        return float(p.extra[field] if field in p.extra else getattr(p, field))

    def set_param(self, edge_idx: int, field: str, value: float) -> None:
        e = self.edges[edge_idx]
        if field in e.params.extra:
            e.params.extra[field] = value
        else:
            setattr(e.params, field, value)

    def param_space(self) -> List[tuple]:
        """(edge_idx, field) handles the auto-tuner may adjust (Table 2).

        Numeric ``extra`` entries (centers, vertices, bins, ...) are exposed
        too — they are per-component input-data-size parameters in the
        paper's sense (e.g. the size of the centroid set).
        """
        out = []
        for i, e in enumerate(self.edges):
            for f in ("data_size", "chunk_size", "parallelism", "weight"):
                out.append((i, f))
            for k, v in e.params.extra.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    out.append((i, k))
        return out

    def to_json(self) -> Dict:
        return {
            "name": self.name,
            "sources": dict(self.sources),
            "edges": [e.to_json() for e in self.edges],
            "sink": self.sink,
        }
