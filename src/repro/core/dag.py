"""DAG-like combination of dwarf components (paper §2.1/§2.3).

A node represents an original or intermediate data set; an edge represents a
dwarf component applied with its own tunable parameters.  ``weight`` is the
component's contribution — realized as a repeat count, so doubling a weight
doubles that component's share of the proxy's cost channels (which is exactly
what the auto-tuner exploits).

Two execution forms share one semantics:

* :meth:`ProxyDAG.build` — one fused jit-able ``fn(rng) -> scalar``
  (the openmp / mpi / spark execution shape).
* :meth:`ProxyDAG.build_stages` — per-edge stages a driver may materialize
  between (the hadoop execution shape: host-spilled intermediates).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .dwarfs import ComponentParams, get_component
from .dwarfs.base import fit_buffer


@dataclasses.dataclass
class Edge:
    component: str                 # registry name of the dwarf component
    src: Sequence[str]             # input node names (>=1, concatenated)
    dst: str                       # output node name
    params: ComponentParams = dataclasses.field(default_factory=ComponentParams)

    def to_json(self) -> Dict:
        p = self.params.rounded()
        return {
            "component": self.component, "src": list(self.src), "dst": self.dst,
            "data_size": p.data_size, "chunk_size": p.chunk_size,
            "parallelism": p.parallelism, "weight": p.weight,
            "extra": dict(p.extra),
        }

    @classmethod
    def from_json(cls, d: Dict) -> "Edge":
        return cls(d["component"], list(d["src"]), d["dst"],
                   ComponentParams(int(d.get("data_size", 1 << 14)),
                                   int(d.get("chunk_size", 256)),
                                   int(d.get("parallelism", 1)),
                                   int(d.get("weight", 1)),
                                   dict(d.get("extra", {}))))


# -- shared edge semantics (build and build_stages must agree exactly) -------


def _init_sources(sources: Dict[str, int], rng: jax.Array
                  ) -> Dict[str, jnp.ndarray]:
    return {sname: jax.random.normal(jax.random.fold_in(rng, i),
                                     (int(n),), jnp.float32)
            for i, (sname, n) in enumerate(sorted(sources.items()))}


def _gather_inputs(e: Edge, xs: List[jnp.ndarray]) -> jnp.ndarray:
    return xs[0] if len(xs) == 1 else jnp.concatenate(
        [fit_buffer(v, e.params.data_size) for v in xs])


def _edge_out(e: Edge, ei: int, x: jnp.ndarray, rng: jax.Array
              ) -> jnp.ndarray:
    comp = get_component(e.component)
    if e.params.weight == 0:                 # tuner pruned this edge
        return fit_buffer(x, e.params.data_size)
    out = x
    for w in range(e.params.weight):         # weight = repeat count
        r = jax.random.fold_in(rng, 10_000 + 131 * ei + w)
        out = comp(fit_buffer(out, e.params.data_size), e.params, r)
    return out


def _accumulate(prev: Optional[jnp.ndarray], out: jnp.ndarray) -> jnp.ndarray:
    return out if prev is None else prev + fit_buffer(out, prev.shape[0])


def _terminals(edges: List[Edge]) -> List[str]:
    produced = {e.dst for e in edges}
    consumed = {s for e in edges for s in e.src}
    return sorted(produced - consumed) or sorted(produced)


@dataclasses.dataclass
class ProxyDAG:
    """Executable DAG of weighted dwarf components."""

    name: str
    sources: Dict[str, int]        # source node -> element count
    edges: List[Edge]
    sink: Optional[str] = None     # node reduced to the scalar output

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        known = set(self.sources)
        for e in self.edges:
            for s in e.src:
                if s not in known:
                    raise ValueError(
                        f"edge {e.component}: input node {s!r} not yet defined "
                        f"(DAG must be topologically ordered)")
            known.add(e.dst)
        if self.sink is not None and self.sink not in known:
            raise ValueError(f"sink {self.sink!r} not produced by any edge")

    def _rounded_edges(self) -> List[Edge]:
        return [dataclasses.replace(e, params=e.params.rounded())
                for e in self.edges]

    # -- build ---------------------------------------------------------------

    def build(self) -> Callable[[jax.Array], jnp.ndarray]:
        """Returns a jit-able fn(rng) -> scalar executing the whole DAG."""
        self.validate()
        edges = self._rounded_edges()
        sources = dict(self.sources)
        sink = self.sink

        def run(rng: jax.Array) -> jnp.ndarray:
            nodes = _init_sources(sources, rng)
            for ei, e in enumerate(edges):
                x = _gather_inputs(e, [nodes[s] for s in e.src])
                out = _edge_out(e, ei, x, rng)
                nodes[e.dst] = _accumulate(nodes.get(e.dst), out)
            if sink is not None:
                return jnp.sum(nodes[sink])
            return sum(jnp.sum(nodes[t]) for t in _terminals(edges))

        return run

    def build_stages(self):
        """Per-edge execution stages with semantics identical to ``build``.

        Returns ``(init_fn, stages, finalize_fn)`` where

        * ``init_fn(rng) -> {source: array}`` generates the input data sets,
        * ``stages`` is a list of ``(src_names, dst, stage_fn)`` in edge
          order with ``stage_fn(rng, xs, prev) -> new dst value``
          (``prev`` is the dst node's prior value for accumulation, or
          ``None``), and
        * ``finalize_fn(nodes) -> scalar`` performs the sink reduction.

        A driver may materialize every intermediate between stages — the
        Hadoop execution model.  The computed result matches ``build`` up
        to float32 re-association from per-stage compilation (XLA fuses
        differently when each edge is jitted alone).
        """
        self.validate()
        edges = self._rounded_edges()
        sources = dict(self.sources)
        sink = self.sink

        def init_fn(rng: jax.Array) -> Dict[str, jnp.ndarray]:
            return _init_sources(sources, rng)

        def make_stage(e: Edge, ei: int):
            def stage(rng, xs, prev):
                out = _edge_out(e, ei, _gather_inputs(e, list(xs)), rng)
                return _accumulate(prev, out)
            return stage

        stages = [(list(e.src), e.dst, make_stage(e, ei))
                  for ei, e in enumerate(edges)]

        def finalize_fn(nodes: Dict[str, jnp.ndarray]) -> jnp.ndarray:
            if sink is not None:
                return jnp.sum(nodes[sink])
            return sum(jnp.sum(nodes[t]) for t in _terminals(edges))

        return init_fn, stages, finalize_fn

    # -- serialization -------------------------------------------------------

    def to_json(self) -> Dict:
        return {
            "name": self.name,
            "sources": dict(self.sources),
            "edges": [e.to_json() for e in self.edges],
            "sink": self.sink,
        }

    @classmethod
    def from_json(cls, d: Dict) -> "ProxyDAG":
        return cls(name=d["name"],
                   sources={k: int(v) for k, v in d["sources"].items()},
                   edges=[Edge.from_json(e) for e in d["edges"]],
                   sink=d.get("sink"))

    # -- deprecated tuner plumbing ------------------------------------------
    # The auto-tuner now operates on repro.api.params.ParamSpace (a named
    # pytree with per-leaf bounds); these string handles remain as thin
    # shims for old callers.

    def get_param(self, edge_idx: int, field: str) -> float:
        warnings.warn("ProxyDAG.get_param is deprecated; use "
                      "repro.api.ParamSpace", DeprecationWarning, stacklevel=2)
        p = self.edges[edge_idx].params
        return float(p.extra[field] if field in p.extra else getattr(p, field))

    def set_param(self, edge_idx: int, field: str, value: float) -> None:
        warnings.warn("ProxyDAG.set_param is deprecated; use "
                      "repro.api.ParamSpace", DeprecationWarning, stacklevel=2)
        e = self.edges[edge_idx]
        if field in e.params.extra:
            e.params.extra[field] = value
        else:
            setattr(e.params, field, value)

    def param_space(self) -> List[tuple]:
        """Deprecated: legacy ``(edge_idx, field)`` handles.  Use
        :class:`repro.api.ParamSpace` for the named, bounded pytree view."""
        warnings.warn("ProxyDAG.param_space is deprecated; use "
                      "repro.api.ParamSpace", DeprecationWarning, stacklevel=2)
        from ..api.params import ParamSpace
        space = ParamSpace.from_dag(self)
        return [space.handle(i) for i in range(len(space))]
