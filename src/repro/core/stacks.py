"""Deprecated software-stack entry points (paper §2.2.2).

The four ad-hoc functions this module used to define have been redesigned
into the unified Stack protocol in :mod:`repro.api.stack`::

    from repro.api import get_stack
    report = get_stack("hadoop").run(proxy)      # -> RunReport

These shims keep the old ``(result, io_bytes)`` signatures working and
delegate to the new implementations.
"""

from __future__ import annotations

import warnings
from typing import Callable, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..api.stack import (HadoopStack, MPIStack, OpenMPStack,  # noqa: F401
                         SparkStack, get_stack)


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.core.stacks.{name}() is deprecated; use "
        f"repro.api.get_stack({name!r}).run(...)",
        DeprecationWarning, stacklevel=3)


def openmp(fn: Callable, *args) -> Tuple[jax.Array, float]:
    _warn("openmp")
    r = get_stack("openmp").run(fn, *args)
    return r.result, r.io_bytes


def mpi(fn: Callable, mesh: jax.sharding.Mesh, axis: str, *args,
        out_specs=P()) -> Tuple[jax.Array, float]:
    """SPMD execution with the original semantics: per-shard inputs over
    ``axis`` and caller-controlled ``out_specs``.  (``MPIStack.run`` instead
    replicates inputs and all-reduces outputs — a different contract.)"""
    _warn("mpi")
    from ..api.stack import _shard_map
    if _shard_map is None:  # pragma: no cover - jax without shard_map
        raise NotImplementedError("legacy mpi() needs jax shard_map; use "
                                  "repro.api.MPIStack instead")
    sharded = _shard_map(fn, mesh=mesh, in_specs=P(axis),
                         out_specs=out_specs, check_rep=False)
    out = jax.jit(sharded)(*args)
    jax.block_until_ready(out)
    return out, 0.0


def spark(fn: Callable, mesh: jax.sharding.Mesh, axis: str, *args
          ) -> Tuple[jax.Array, float]:
    _warn("spark")
    r = SparkStack(mesh=mesh, axis=axis).run(fn, *args)
    return r.result, r.io_bytes


def hadoop(map_fn: Callable, reduce_fn: Callable, data: jax.Array,
           n_chunks: int = 8) -> Tuple[jax.Array, float]:
    _warn("hadoop")
    r = HadoopStack(n_chunks=n_chunks).map_reduce(map_fn, reduce_fn, data)
    return r.result, r.io_bytes
