"""Software-stack substrates (paper §2.2.2).

The paper implements every dwarf component on OpenMP / MPI / Hadoop / Spark
because "software stack has great influences on workload behaviors".  The
JAX-native analogues keep that axis of the methodology:

  * ``openmp``  — single-process jit; XLA intra-op threading = OpenMP threads.
  * ``mpi``     — explicit SPMD via ``jax.shard_map`` over a device mesh,
                  collectives spelled out (the MPI execution model).
  * ``hadoop``  — staged map -> materialize -> shuffle -> reduce with the
                  intermediate round-tripped through *host* memory, which is
                  the disk-I/O behaviour the paper measures for Hadoop jobs.
  * ``spark``   — global-view pjit with sharding constraints; intermediates
                  stay resident ("in-memory RDD").

Each runner returns (result, io_bytes): io_bytes is the host<->device traffic
(the paper's disk-I/O bandwidth analog; zero for all-device stacks).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def openmp(fn: Callable, *args) -> Tuple[jax.Array, float]:
    out = jax.jit(fn)(*args)
    jax.block_until_ready(out)
    return out, 0.0


def mpi(fn: Callable, mesh: jax.sharding.Mesh, axis: str, *args,
        out_specs=P()) -> Tuple[jax.Array, float]:
    """SPMD execution: fn sees per-shard arrays + lax collectives over axis."""
    sharded = jax.shard_map(fn, mesh=mesh,
                            in_specs=P(axis), out_specs=out_specs)
    out = jax.jit(sharded)(*args)
    jax.block_until_ready(out)
    return out, 0.0


def spark(fn: Callable, mesh: jax.sharding.Mesh, axis: str, *args
          ) -> Tuple[jax.Array, float]:
    """Global-view execution with input sharding constraints (pjit)."""
    shardings = tuple(NamedSharding(mesh, P(axis)) for _ in args)
    with mesh:
        placed = tuple(jax.device_put(a, s) for a, s in zip(args, shardings))
        out = jax.jit(fn)(*placed)
        jax.block_until_ready(out)
    return out, 0.0


def hadoop(map_fn: Callable, reduce_fn: Callable, data: jax.Array,
           n_chunks: int = 8) -> Tuple[jax.Array, float]:
    """Map -> host-materialized intermediate ("HDFS spill") -> reduce.

    Returns (result, io_bytes): every map output is copied to host and back,
    emulating the intermediate-data disk round trip of the Hadoop execution
    model; io_bytes counts both directions.
    """
    n = data.shape[0] // n_chunks * n_chunks
    chunks = np.asarray(data[:n]).reshape(n_chunks, -1, *data.shape[1:])
    jmap = jax.jit(map_fn)
    io_bytes = 0.0
    intermediates = []
    for c in chunks:                      # map tasks
        out = jmap(jnp.asarray(c))
        host = np.asarray(out)            # spill to "disk"
        io_bytes += host.nbytes * 2.0     # write + read back
        intermediates.append(host)
    shuffled = jnp.asarray(np.concatenate([i.reshape(-1) for i in intermediates]))
    result = jax.jit(reduce_fn)(shuffled)  # reduce task
    jax.block_until_ready(result)
    return result, io_bytes
