"""Workload subsetting over fingerprint space (PAPERS.md, arXiv 1409.0792).

"Characterizing and Subsetting Big Data Workloads" keeps a benchmark suite
small as scenarios multiply: normalize each workload's measured
characteristics, cluster, and keep one representative per cluster.  This
module runs that pipeline over :class:`~repro.core.engine.WorkloadFingerprint`
vectors:

1. **Normalize** — each fingerprint's channel vector is scaled to *shares*
   (``v / sum(v)``, making workloads of different absolute size
   comparable) and then z-scored per channel across the suite, so no
   single high-magnitude channel (e.g. ``bytes_accessed``) dominates the
   distance metric.
2. **Cluster** — deterministic seeded Lloyd k-means in the normalized
   space (numpy only; an empty cluster is reseeded to the point farthest
   from its representative, so requesting ``k == n`` degenerates cleanly
   to one-singleton-per-workload).
3. **Represent** — each cluster's representative is the *member closest
   to the centroid* (a real workload, not a synthetic mean), and the
   :class:`SubsetReport` records per-cluster coverage: the max
   member-to-representative distance.

``subset_fingerprints(fps, max_distance=...)`` instead grows ``k`` until
every member sits within the distance bound of its representative — the
"how few proxies can I keep?" question answered directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .engine import WorkloadFingerprint

#: version stamped into serialized subset reports
SUBSET_VERSION = 1


def normalize_fingerprints(fps: Sequence[WorkloadFingerprint]
                           ) -> np.ndarray:
    """Stack fingerprints into the normalized ``(n, channels)`` matrix the
    clustering runs on: per-fingerprint share scaling, then per-channel
    z-scoring across the suite (constant channels map to 0)."""
    if not fps:
        raise ValueError("need at least one fingerprint")
    mat = np.stack([fp.vector() for fp in fps])
    totals = np.maximum(mat.sum(axis=1, keepdims=True), 1e-12)
    shares = mat / totals
    mean = shares.mean(axis=0)
    std = shares.std(axis=0)
    std = np.where(std > 1e-12, std, 1.0)
    return (shares - mean) / std


def _kmeans(x: np.ndarray, k: int, seed: int,
            iters: int = 64) -> np.ndarray:
    """Seeded Lloyd k-means; returns the ``(n,)`` label vector.

    Initialization is k-means++-style (greedy farthest-point after a
    seeded first pick) and empty clusters reseed to the point farthest
    from its current centroid, so every one of the ``k`` clusters ends
    non-empty whenever ``k <= n``."""
    n = x.shape[0]
    rng = np.random.RandomState(seed)
    centers = np.empty((k, x.shape[1]))
    first = int(rng.randint(n))
    centers[0] = x[first]
    d2 = ((x - centers[0]) ** 2).sum(axis=1)
    for j in range(1, k):
        centers[j] = x[int(np.argmax(d2))]
        d2 = np.minimum(d2, ((x - centers[j]) ** 2).sum(axis=1))
    labels = np.zeros(n, dtype=int)
    for _ in range(iters):
        dists = ((x[:, None, :] - centers[None, :, :]) ** 2).sum(axis=2)
        new_labels = dists.argmin(axis=1)
        for j in range(k):
            members = x[new_labels == j]
            if len(members):
                centers[j] = members.mean(axis=0)
            else:  # reseed an emptied cluster to the worst-covered point
                worst = int(np.argmax(dists.min(axis=1)))
                centers[j] = x[worst]
                new_labels[worst] = j
        if np.array_equal(new_labels, labels):
            break
        labels = new_labels
    return labels


@dataclasses.dataclass
class SubsetReport:
    """Result of clustering a fingerprint suite down to representatives.

    Attributes:
        names: every input fingerprint's name, in input order.
        representatives: the kept workload names, one per cluster.
        clusters: representative name -> member names (members include
            the representative itself).
        distances: member name -> distance to its representative in the
            normalized space.
        max_distance: per-cluster coverage — representative name -> max
            member distance.
        coverage: overall max member-to-representative distance (0 when
            every cluster is a singleton).
        compression_x: ``len(names) / len(representatives)``.
    """

    names: List[str]
    representatives: List[str]
    clusters: Dict[str, List[str]]
    distances: Dict[str, float]
    max_distance: Dict[str, float]
    coverage: float
    compression_x: float
    version: int = SUBSET_VERSION

    def covered(self, bound: float) -> bool:
        """True when every member lies within ``bound`` of its
        representative."""
        return self.coverage <= bound

    def to_json(self) -> Dict[str, Any]:
        """JSON-serializable dict (round-trips via :meth:`from_json`)."""
        return {
            "subset_version": self.version,
            "names": list(self.names),
            "representatives": list(self.representatives),
            "clusters": {k: list(v) for k, v in self.clusters.items()},
            "distances": {k: float(v) for k, v in self.distances.items()},
            "max_distance": {k: float(v)
                             for k, v in self.max_distance.items()},
            "coverage": float(self.coverage),
            "compression_x": float(self.compression_x),
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "SubsetReport":
        """Rebuild a report serialized by :meth:`to_json`."""
        return cls(
            names=list(d["names"]),
            representatives=list(d["representatives"]),
            clusters={k: list(v) for k, v in d["clusters"].items()},
            distances={k: float(v) for k, v in d["distances"].items()},
            max_distance={k: float(v)
                          for k, v in d["max_distance"].items()},
            coverage=float(d["coverage"]),
            compression_x=float(d["compression_x"]),
            version=int(d.get("subset_version", SUBSET_VERSION)),
        )


def _cluster_once(fps: Sequence[WorkloadFingerprint], x: np.ndarray,
                  k: int, seed: int) -> SubsetReport:
    names = [fp.name for fp in fps]
    labels = _kmeans(x, k, seed)
    representatives: List[str] = []
    clusters: Dict[str, List[str]] = {}
    distances: Dict[str, float] = {}
    max_dist: Dict[str, float] = {}
    for j in range(k):
        idx = np.flatnonzero(labels == j)
        if not len(idx):          # unreachable: _kmeans reseeds empties
            continue
        centroid = x[idx].mean(axis=0)
        rep_i = idx[int(np.argmin(
            ((x[idx] - centroid) ** 2).sum(axis=1)))]
        rep = names[rep_i]
        members = [names[i] for i in idx]
        dists = np.sqrt(((x[idx] - x[rep_i]) ** 2).sum(axis=1))
        representatives.append(rep)
        clusters[rep] = members
        for name, d in zip(members, dists):
            distances[name] = float(d)
        max_dist[rep] = float(dists.max())
    representatives.sort()
    return SubsetReport(
        names=names,
        representatives=representatives,
        clusters={r: clusters[r] for r in representatives},
        distances=distances,
        max_distance={r: max_dist[r] for r in representatives},
        coverage=max(max_dist.values(), default=0.0),
        compression_x=len(names) / max(len(representatives), 1),
    )


def subset_fingerprints(fps: Sequence[WorkloadFingerprint],
                        k: Optional[int] = None,
                        max_distance: Optional[float] = None,
                        seed: int = 0) -> SubsetReport:
    """Cluster a fingerprint suite and keep one representative per cluster.

    Args:
        fps: the fingerprint suite (names must be unique).
        k: number of clusters.  Omitted with ``max_distance`` set, the
            smallest ``k`` whose coverage meets the bound is found by
            scanning up from 1; omitted entirely, defaults to
            ``ceil(sqrt(n))``.
        max_distance: optional coverage bound in the normalized space;
            with ``k`` also given, it is only recorded via
            :meth:`SubsetReport.covered`, not enforced.
        seed: clustering seed (deterministic for fixed inputs + seed).

    Returns:
        A :class:`SubsetReport` mapping representatives to members with
        per-cluster and overall coverage plus the compression ratio.
    """
    fps = list(fps)
    names = [fp.name for fp in fps]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"fingerprint names must be unique; duplicated: "
                         f"{dupes}")
    x = normalize_fingerprints(fps)
    n = len(fps)
    if k is not None:
        k = int(k)
        if not 1 <= k <= n:
            raise ValueError(f"k must be in [1, {n}], got {k}")
        return _cluster_once(fps, x, k, seed)
    if max_distance is not None:
        for kk in range(1, n + 1):
            report = _cluster_once(fps, x, kk, seed)
            if report.coverage <= max_distance:
                return report
        return report  # kk == n: all singletons, coverage 0
    return _cluster_once(fps, x, int(np.ceil(np.sqrt(n))), seed)
