"""ProxyBenchmark — a tunable, DAG-structured stand-in for a workload."""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional

import jax

from .dag import Edge, ProxyDAG
from .dwarfs import ComponentParams, components_of_dwarf
from .profiler import WorkloadProfile, characterize


@dataclasses.dataclass
class ProxyBenchmark:
    dag: ProxyDAG
    description: str = ""

    @property
    def name(self) -> str:
        return self.dag.name

    def profile(self, execute: bool = True, exec_iters: int = 3,
                host_bytes: float = 0.0) -> WorkloadProfile:
        fn = self.dag.build()
        rng = jax.random.PRNGKey(0)
        return characterize(fn, (rng,), name=self.name, execute=execute,
                            exec_iters=exec_iters, host_bytes=host_bytes)

    # -- serialization (versioned ProxySpec round-trip) ----------------------

    def to_spec(self, stack: str = "openmp", scale=None):
        from ..api.spec import ProxySpec
        return ProxySpec.from_benchmark(self, stack=stack, scale=scale)

    @classmethod
    def from_spec(cls, spec) -> "ProxyBenchmark":
        return cls(dag=spec.to_dag(), description=spec.description)

    def save(self, path: str, stack: str = "openmp", scale=None) -> None:
        """Write a versioned spec (see :mod:`repro.api.spec`)."""
        self.to_spec(stack=stack, scale=scale).save(path)

    @classmethod
    def load(cls, path: str) -> "ProxyBenchmark":
        """Reconstruct a saved proxy (current spec_version or the seed's
        legacy bare-DAG JSON) — profiles identically to the original."""
        from ..api.spec import ProxySpec
        return cls.from_spec(ProxySpec.load(path))

    def clone(self) -> "ProxyBenchmark":
        dag = ProxyDAG(
            name=self.dag.name,
            sources=dict(self.dag.sources),
            edges=[Edge(e.component, list(e.src), e.dst,
                        dataclasses.replace(e.params,
                                            extra=dict(e.params.extra)))
                   for e in self.dag.edges],
            sink=self.dag.sink)
        return ProxyBenchmark(dag=dag, description=self.description)


def proxy_from_dwarf_weights(name: str,
                             weights: Dict[str, float],
                             base_size: int = 1 << 16,
                             chunk: int = 256,
                             parallelism: int = 1,
                             components_per_dwarf: Optional[Dict[str, List[str]]] = None,
                             ) -> ProxyBenchmark:
    """Parameter-initialization stage (§2.3): build a linear-chain DAG whose
    per-dwarf repeat weights are proportional to the profiled execution ratios.

    ``weights`` come from :func:`repro.core.profiler.decompose_to_dwarfs` or
    from a hand analysis (e.g. paper's TeraSort = 70% sort / 10% sampling /
    20% graph).  Dwarfs with no registered components cannot be realized;
    they are dropped with a warning and recorded in the returned proxy's
    ``description``.
    """
    total = sum(weights.values()) or 1.0
    edges: List[Edge] = []
    dropped: List[str] = []
    prev = "src"
    idx = 0
    for dwarf, w in sorted(weights.items(), key=lambda kv: -kv[1]):
        if w <= 0:
            continue
        names = (components_per_dwarf or {}).get(dwarf)
        comps = ([c.name for c in components_of_dwarf(dwarf)]
                 if not names else names)
        if not comps:
            dropped.append(dwarf)
            continue
        # weight: ~8 repeats at 100% share, >=1 if present at all
        rep = max(1, round(8.0 * w / total))
        comp = comps[idx % len(comps)]
        node = f"d{idx}_{dwarf}"
        edges.append(Edge(
            component=comp, src=[prev], dst=node,
            params=ComponentParams(data_size=base_size, chunk_size=chunk,
                                   parallelism=parallelism, weight=rep)))
        prev = node
        idx += 1
    description = f"auto-initialized from {weights}"
    if dropped:
        warnings.warn(
            f"proxy_from_dwarf_weights({name!r}): no registered components "
            f"for dwarf(s) {', '.join(sorted(dropped))}; omitted from the "
            f"proxy DAG", UserWarning, stacklevel=2)
        description += (" (dropped dwarfs with no registered components: "
                        f"{', '.join(sorted(dropped))})")
    dag = ProxyDAG(name=name, sources={"src": base_size}, edges=edges, sink=prev)
    return ProxyBenchmark(dag=dag, description=description)
