"""ExecutionPlan lowering layer: fused stages + weight-stratified buckets.

The paper's proxies are DAG-like combinations of dwarf components whose
whole point is preserving workload characteristics while shortening
execution 100s of times (§2.1).  The execution layer therefore needs an
explicit, cost-aware plan between a :class:`~repro.core.dag.ProxyDAG` and
the stacks that run it — the same argument Jia et al. and Gao et al. make
for scheduling representative units by *cost* rather than enumerating
them uniformly.  :func:`lower` turns a DAG into an
:class:`ExecutionPlan` exactly once per structure:

* **Fused stages** — adjacent low-cost edges on a private linear chain
  merge into one :class:`FusedStage` executed as a *single*
  ``fori_loop`` whose trip space concatenates every member's weight
  range; a ``lax.switch`` on the segment index applies the owning edge's
  body.  The computation is bit-identical to running each edge's own
  loop in sequence (same bodies, same per-repeat rng folds, same order)
  while the jaxpr carries one ``while`` op per stage instead of one per
  edge, and staged drivers (the hadoop stack) spill per *stage* instead
  of per edge — cutting host-spill volume.  The fusion decision is fed
  by the :mod:`repro.core.engine` compositional cost model (cached
  per-edge body reports) under ``REPRO_FUSION_THRESHOLD``; ``0``
  disables fusion (the legacy one-stage-per-edge path).  A fused stage
  whose members all carry registered Pallas kernel bodies is a
  **MegaStage** (``FusedStage.mega``): when the live dispatch resolves
  every member to the ``"pallas"`` backend it executes as *one*
  :mod:`repro.kernels.megakernel` kernel — grid over the segments,
  carry resident in VMEM scratch, per-segment operand loads pipelined —
  bit-identical to (and demotable per trace to) the switch path.
* **Bucket schedules** — a population of dynamic-param candidates
  executed as one vmapped batched ``while`` runs max-over-candidates
  trips, so one straggler inflates the whole batch (the
  ``exec_speedup_x < 1`` regression in ``BENCH_engine.json``).
  :meth:`ExecutionPlan.bucket_schedule` stratifies candidates by total
  weighted cost into equal-size buckets; each bucket's vmapped ``while``
  then runs its own (much tighter) trip bound, recovering the
  sequential-sum cost model.  Buckets share one compiled executable —
  every bucket has the same size, so the cache key
  ``(plan.structure_key(), bucket_size)`` stays constant across sweeps:
  zero retraces, at most one executable per bucket signature.

The plan cache is keyed on ``(dag.canonical_structure_key(), threshold)``
(stable under isomorphic node relabeling — machine-generated structures
that only rename nodes share plans and executables): fusion
grouping is decided from the weights seen at first lowering and then
*reused* for every dynamic-param setting of the structure (grouping is
correctness-neutral; re-lowering per weight step would break the
compile-once contract).  The *static* :meth:`ExecutionPlan.build` form
bakes lowering-time params in, so callers that need current values baked
(the profiler path) lower fresh with ``cache=False``.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dag import (Edge, ProxyDAG, _accumulate, _edge_out, _gather_inputs,
                  _init_sources, _terminals)
from .pool import get_pool
from .dwarfs import get_component
from .dwarfs.base import fit_buffer

#: default fusion budget (flops + vpu ops + bytes of one stage, weights
#: included) — sized so that the Table-3 proxies' cheap glue chains fuse
#: (terasort's graph tail ~1.4e8, kmeans' sort/count tail ~1.2e7) while
#: their dominant stages (terasort merge_sort ~1.3e10, pagerank spmv
#: ~1.6e9) stay standalone loops
DEFAULT_FUSION_THRESHOLD = 2.0e8


def fusion_threshold() -> float:
    """Resolve the fusion cost threshold (``REPRO_FUSION_THRESHOLD`` env
    var, empty/unset -> the default; ``0`` disables fusion)."""
    raw = os.environ.get("REPRO_FUSION_THRESHOLD")
    if raw is None or raw.strip() == "":
        return DEFAULT_FUSION_THRESHOLD
    return float(raw)


def population_buckets() -> Optional[int]:
    """Resolve the population bucket *count* override
    (``REPRO_POP_BUCKETS`` env var; ``None`` when unset — the per-device
    bucket-size policy applies; ``1`` disables stratification)."""
    raw = os.environ.get("REPRO_POP_BUCKETS")
    if raw is None or raw.strip() == "":
        return None
    return max(1, int(raw))


def population_workers() -> int:
    """Host threads dispatching population strata concurrently
    (``REPRO_POP_WORKERS`` env var; default ``min(4, cpu_count)``).

    The dwarf bodies (sort, gather, hash) barely engage XLA's intra-op
    pool at proxy sizes, so a sequential candidate sweep leaves cores
    idle; jitted executions release the GIL, making a small thread pool
    over the per-candidate calls the CPU analogue of sharding the
    candidate axis over a mesh.  ``1`` restores serial dispatch."""
    raw = os.environ.get("REPRO_POP_WORKERS")
    if raw is not None and raw.strip() != "":
        return max(1, int(raw))
    return max(1, min(4, os.cpu_count() or 1))


def resolve_bucket_size(n: int) -> int:
    """Default bucket size for an ``n``-candidate population.

    Unless ``REPRO_POP_BUCKETS`` pins a bucket count, each bucket holds
    exactly one candidate lane per device: on a single-device CPU that is
    the *fully* stratified schedule (every candidate trips exactly its own
    weights — the sequential-sum cost model with compiled-call dispatch,
    measured >1.5x over the per-candidate clone/apply/run loop on
    straggler-heavy populations), while on a mesh each bucket fills the
    device axis so the candidate dimension still shards.  CPU vmapped
    ``while`` lanes do not vectorize for the sort/gather-heavy dwarf
    bodies, so wider host buckets only multiply masked work.
    """
    buckets = population_buckets()
    if buckets is not None:
        return max(1, math.ceil(n / buckets))
    return max(1, min(n, jax.device_count()))


# ---------------------------------------------------------------------------
# lowering: edge costs + fusion partition
# ---------------------------------------------------------------------------


def _edge_body_cost(e: Edge) -> float:
    """Scalar per-repeat cost of one edge body (flops + vpu ops + bytes),
    from the engine's cached compositional report; falls back to a
    bytes-proportional estimate if HLO analysis is unavailable."""
    try:
        from .engine import _body_report
        rep = _body_report(e)
        cost = float(rep.flops + rep.vpu_ops + rep.bytes_accessed)
        if cost > 0.0:
            return cost
    except Exception:  # pragma: no cover - analysis backend unavailable
        pass
    return float(8 * e.params.rounded().data_size)


def _fusable_links(dag: ProxyDAG, edges: Sequence[Edge]) -> List[bool]:
    """``links[i]`` — may edge ``i+1`` join edge ``i``'s stage?  True only
    for a private linear chain: edge ``i+1`` reads exactly edge ``i``'s
    output, nothing else reads or re-writes that intermediate node, it is
    neither a source nor the sink, and both edges share one buffer size
    (the fused loop's carry shape)."""
    produced: Dict[str, int] = {}
    consumers: Dict[str, List[int]] = {}
    for j, e in enumerate(edges):
        produced[e.dst] = produced.get(e.dst, 0) + 1
        for s in e.src:
            consumers.setdefault(s, []).append(j)
    links = []
    for i in range(len(edges) - 1):
        a, b = edges[i], edges[i + 1]
        mid = a.dst
        links.append(
            list(b.src) == [mid]
            and produced.get(mid, 0) == 1
            and consumers.get(mid, []) == [i + 1]
            and mid not in dag.sources
            and mid != dag.sink
            and a.params.data_size == b.params.data_size)
    return links


@dataclasses.dataclass(frozen=True)
class FusedStage:
    """One execution stage: a run of >=1 consecutive DAG edges."""

    members: Tuple[int, ...]       # original edge indices, consecutive
    src: Tuple[str, ...]           # stage inputs (first member's sources)
    dst: str                       # stage output (last member's dst)
    data_size: int                 # carry buffer size of the fused loop
    cost: float                    # Σ weight × body cost at lowering time
    #: megakernel *capability* (a MegaStage): every member is
    #: pallas_capable with a registered bit-identical kernel body and the
    #: carry fits VMEM.  Structure-only — whether a trace actually takes
    #: the one-kernel form is decided per dispatch (see ``_mega_out``)
    mega: bool = False

    @property
    def fused(self) -> bool:
        return len(self.members) > 1


def _mega_eligible(group: Sequence[Edge]) -> bool:
    """May this fused group lower to the one-kernel megakernel?  Every
    member must be ``pallas_capable`` *and* have a registered segment
    body under its params, and the shared carry must fit the VMEM
    budget.  Pure structure — no env/backend reads — so the flag caches
    with the plan."""
    from ..kernels.megakernel import CARRY_VMEM_BYTES, mega_capable
    if len(group) < 2:
        return False
    if 4 * group[-1].params.rounded().data_size > CARRY_VMEM_BYTES:
        return False
    return all(get_component(e.component).pallas_capable
               and mega_capable(e.component, e.params) for e in group)


def _partition(dag: ProxyDAG, edges: Sequence[Edge],
               threshold: float) -> List[FusedStage]:
    links = _fusable_links(dag, edges)
    fuse_any = threshold > 0.0 and any(links)
    costs = [float(e.params.weight) * (_edge_body_cost(e) if fuse_any
                                       else float(8 * e.params.data_size))
             for e in edges]
    groups: List[List[int]] = [[0]] if edges else []
    acc = costs[0] if edges else 0.0
    for i in range(1, len(edges)):
        if fuse_any and links[i - 1] and acc + costs[i] <= threshold:
            groups[-1].append(i)
            acc += costs[i]
        else:
            groups.append([i])
            acc = costs[i]
    return [FusedStage(members=tuple(g),
                       src=tuple(edges[g[0]].src),
                       dst=edges[g[-1]].dst,
                       data_size=edges[g[-1]].params.data_size,
                       cost=sum(costs[i] for i in g),
                       mega=_mega_eligible([edges[i] for i in g]))
            for g in groups]


# ---------------------------------------------------------------------------
# fused-stage execution (must agree exactly with dag._edge_out semantics)
# ---------------------------------------------------------------------------


def _fused_out(members: Sequence[Tuple[int, Edge]], x: jnp.ndarray,
               rng: jax.Array, dyn_stage: Optional[Tuple]) -> jnp.ndarray:
    """Apply a private chain of edges as ONE ``fori_loop``.

    Trip ``t`` belongs to the segment of the edge whose cumulative weight
    range contains it; a ``lax.switch`` applies that edge's single-repeat
    body with the *same* rng fold the unfused per-edge loop would use
    (``10_000 + 131*edge_index + local_repeat``), so the value sequence is
    identical to running each member's own loop back to back — while the
    jaxpr holds a single ``while`` op for the whole chain.
    """
    k = len(members)
    ps, ws = [], []
    for m, (ei, e) in enumerate(members):
        p = e.params
        dyn = dyn_stage[m] if dyn_stage is not None else None
        if dyn:
            extra_dyn = {kk: v for kk, v in dyn.items() if kk != "weight"}
            if extra_dyn:
                p = p.replace(extra={**p.extra, **extra_dyn})
        w = dyn["weight"] if dyn and "weight" in dyn else p.weight
        ps.append(p)
        ws.append(w)
    size = ps[0].data_size
    x0 = fit_buffer(x, size)

    if all(isinstance(w, int) for w in ws):
        # static weights: keep the trip count a Python int so the loop
        # lowers with known_trip_count (exact profiler attribution)
        ends_np = np.cumsum(np.asarray(ws, np.int64))
        total: Any = int(ends_np[-1])
        if total == 0:
            return x0
        ends = jnp.asarray(ends_np, jnp.int32)
        starts = jnp.asarray(ends_np - np.asarray(ws, np.int64), jnp.int32)
    else:
        # unrolled running sum (k is small and static): no scan op in the
        # jaxpr, the fused loop is the only loop this stage contributes
        acc = jnp.asarray(0, jnp.int32)
        starts_l, ends_l = [], []
        for w in ws:
            starts_l.append(acc)
            acc = acc + jnp.asarray(w, jnp.int32)
            ends_l.append(acc)
        ends = jnp.stack(ends_l)
        starts = jnp.stack(starts_l)
        total = acc

    branches = []
    for m, (ei, e) in enumerate(members):
        comp = get_component(e.component)

        def branch(operand, _comp=comp, _p=ps[m], _ei=ei):
            carry, local = operand
            r = jax.random.fold_in(rng, 10_000 + 131 * _ei + local)
            return fit_buffer(_comp(carry, _p, r), size)

        branches.append(branch)

    def body(t, carry):
        # segment of trip t = #cumulative-ends <= t (vectorized compare —
        # no scan/sort op); clip guards the masked tail trips a batched
        # while runs for already-finished lanes
        seg = jnp.clip(jnp.sum((ends <= t).astype(jnp.int32)), 0, k - 1)
        local = t - starts[seg]
        return jax.lax.switch(seg, branches, (carry, local))

    return jax.lax.fori_loop(0, total, body, x0)


#: per-trace megakernel dispatch counters: "mega" — a MegaStage traced
#: through the one-kernel path; "fallback" — a MegaStage demoted to the
#: switch path at trace time (degraded/forced backend, REPRO_MEGAKERNEL
#: off, a traced kernel-static extra).  Non-eligible stages don't count.
MEGA_STATS = {"mega": 0, "fallback": 0}


def mega_stats() -> Dict[str, int]:
    return dict(MEGA_STATS)


def reset_mega_stats() -> None:
    for k in MEGA_STATS:
        MEGA_STATS[k] = 0


def _mega_out(members: Sequence[Tuple[int, Edge]], x: jnp.ndarray,
              rng: jax.Array, dyn_stage: Optional[Tuple]
              ) -> Optional[jnp.ndarray]:
    """One-kernel form of :func:`_fused_out` — same member order, same
    per-member trip counts, bodies value-identical per repeat (and
    rng-free, which registration enforces), so the result is
    bit-identical to the switch path.

    Returns ``None`` when the *live* dispatch resolves away from the
    megakernel — ``REPRO_MEGAKERNEL`` off, any member's backend (env,
    per-edge pin, or the circuit breaker's :func:`forced_backend`
    degrade) resolving to ``"xla"``, a kernel-static extra arriving as a
    traced scalar, or a non-f32 carry — and the caller falls back to
    :func:`_fused_out`.  The decision happens at trace time; every
    executable cache key carries the backend override and the megakernel
    flag, so demoted and promoted traces never share an executable."""
    from ..kernels.dispatch import default_interpret, megakernel_enabled
    from ..kernels.megakernel import mega_body, mega_stage_kernel
    if not megakernel_enabled():
        return None
    ws, bodies = [], []
    for m, (ei, e) in enumerate(members):
        p = e.params.rounded()
        dyn = dyn_stage[m] if dyn_stage is not None else None
        if dyn and any(kk != "weight" for kk in dyn):
            return None          # traced extras can't be kernel statics
        comp = get_component(e.component)
        if not comp.uses_pallas(p):
            return None
        body = mega_body(e.component, p)
        if body is None:
            return None
        ws.append(dyn["weight"] if dyn and "weight" in dyn else p.weight)
        bodies.append(body)
    x0 = fit_buffer(x, members[0][1].params.rounded().data_size)
    if x0.dtype != jnp.float32:
        return None
    weights = jnp.stack([jnp.asarray(w, jnp.int32) for w in ws])
    out = mega_stage_kernel(x0, weights, bodies,
                            interpret=default_interpret())
    # The kernel's buffer is bit-identical to the switch path, but XLA may
    # fuse a downstream reduce *into* the interpret-mode lowering with a
    # different accumulation order than it picks against the switch path's
    # opaque while-loop output.  Pin the boundary so consumers see the same
    # opaque producer either way and the whole program stays bit-identical.
    return jax.lax.optimization_barrier(out)


# ---------------------------------------------------------------------------
# bucket schedules (weight-stratified population execution)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One stratum of a candidate population, padded to the shared size."""

    indices: np.ndarray        # candidate positions (trailing entries padded)
    valid: int                 # leading entries that are real candidates
    trip_bound: int            # max total weight (trips) within the bucket
    cost_bound: float          # max stratification cost within the bucket


@dataclasses.dataclass(frozen=True)
class BucketSchedule:
    """Deterministic stratified execution order for one population."""

    buckets: Tuple[Bucket, ...]
    bucket_size: int           # shared size (the executable's batch axis)
    n: int                     # real population size

    @property
    def signature(self) -> Tuple[int, int]:
        """The cache-relevant shape: ``(n_buckets, bucket_size)``."""
        return (len(self.buckets), self.bucket_size)

    def trip_bounds(self) -> List[int]:
        return [b.trip_bound for b in self.buckets]

    def bucket_masses(self) -> np.ndarray:
        """Per-bucket share of the population's total weighted cost —
        where the execution (and tuning-budget) mass actually sits."""
        masses = np.array([b.cost_bound * b.valid for b in self.buckets],
                          dtype=np.float64)
        total = masses.sum()
        return masses / total if total > 0 else masses


def make_bucket_schedule(costs: np.ndarray, trips: np.ndarray,
                         bucket_size: int) -> BucketSchedule:
    """Stratify candidates by ``costs`` into contiguous equal-size buckets
    (stable argsort — deterministic across processes); the last bucket
    pads by repeating its final candidate so every bucket shares one
    executable batch size."""
    costs = np.asarray(costs, np.float64)
    trips = np.asarray(trips, np.float64)
    n = int(costs.shape[0])
    bucket_size = max(1, min(int(bucket_size), n))
    order = np.argsort(costs, kind="stable")
    buckets = []
    for b in range(math.ceil(n / bucket_size)):
        idx = order[b * bucket_size:(b + 1) * bucket_size]
        valid = int(idx.shape[0])
        if valid < bucket_size:
            idx = np.concatenate(
                [idx, np.repeat(idx[-1], bucket_size - valid)])
        buckets.append(Bucket(indices=idx, valid=valid,
                              trip_bound=int(trips[idx].max()),
                              cost_bound=float(costs[idx].max())))
    return BucketSchedule(buckets=tuple(buckets), bucket_size=bucket_size,
                          n=n)


# ---------------------------------------------------------------------------
# ExecutionPlan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExecutionPlan:
    """A lowered ProxyDAG: ordered fused stages + population scheduling.

    The plan is the single execution IR every stack consumes — the four
    parallel ``ProxyDAG.build*`` paths lower through here.  ``dyn``
    pytrees keep the per-*edge* layout of ``ProxyDAG.dynamic_params()``
    (stages index into it by member edge), so plan executables are
    drop-in replacements for the legacy parametric fns.
    """

    dag_key: Tuple                 # ProxyDAG.canonical_structure_key() at
                                   # lowering: stable under isomorphic node
                                   # relabeling, so a mutated structure that
                                   # merely renames nodes re-uses every plan
                                   # and downstream stack executable
    sources: Dict[str, int]
    sink: Optional[str]
    edges: List[Edge]              # rounded edge copies (lowering-time params)
    stages: List[FusedStage]
    threshold: float

    # -- identity ------------------------------------------------------------

    def structure_key(self) -> Tuple:
        """Hashable key of the compiled plan: the DAG structure plus the
        stage partition, so a threshold change can never hit an executable
        compiled for a different fusion grouping."""
        return (self.dag_key, self.partition())

    def partition(self) -> Tuple[Tuple[int, ...], ...]:
        return tuple(s.members for s in self.stages)

    @property
    def fused_stage_count(self) -> int:
        return sum(1 for s in self.stages if s.fused)

    @property
    def mega_stage_count(self) -> int:
        return sum(1 for s in self.stages if s.mega)

    def report(self) -> Dict[str, Any]:
        """Lowering diagnostics (the ``plan_sweep`` bench section)."""
        return {
            "edges": len(self.edges),
            "stages": len(self.stages),
            "fused_stages": self.fused_stage_count,
            "mega_stages": self.mega_stage_count,
            "threshold": self.threshold,
            "partition": [list(s.members) for s in self.stages],
            "stage_costs": [s.cost for s in self.stages],
        }

    # -- stage callables -----------------------------------------------------

    def _stage_callable(self, stage: FusedStage) -> Callable:
        """``stage_fn(rng, xs, prev, dyn_stage) -> new dst value`` where
        ``dyn_stage`` is a tuple of the member edges' dyn dicts (or None
        for the baked-in static form).  Single-edge stages execute the
        exact legacy ``_edge_out`` path; fused stages the merged loop."""
        if not stage.fused:
            ei = stage.members[0]
            e = self.edges[ei]

            def single(rng, xs, prev, dyn_stage):
                dyn = dyn_stage[0] if dyn_stage is not None else None
                out = _edge_out(e, ei, _gather_inputs(e, list(xs)), rng,
                                dyn=dyn)
                return _accumulate(prev, out)

            return single

        members = [(ei, self.edges[ei]) for ei in stage.members]
        first = members[0][1]
        mega = stage.mega

        def fused(rng, xs, prev, dyn_stage):
            x = _gather_inputs(first, list(xs))
            out = _mega_out(members, x, rng, dyn_stage) if mega else None
            if out is not None:
                MEGA_STATS["mega"] += 1          # per trace, not per call
            else:
                if mega:
                    MEGA_STATS["fallback"] += 1
                out = _fused_out(members, x, rng, dyn_stage)
            return _accumulate(prev, out)

        return fused

    def _stage_dyn(self, stage: FusedStage, dyn) -> Optional[Tuple]:
        return (None if dyn is None
                else tuple(dyn[ei] for ei in stage.members))

    # -- whole-plan executables ----------------------------------------------

    def build_parametric(self) -> Callable:
        """``fn(rng, dyn) -> scalar`` — ``dyn`` is a
        ``ProxyDAG.dynamic_params()``-shaped pytree of traced scalars (the
        compile-once/run-many form every stack caches)."""
        stage_fns = [self._stage_callable(s) for s in self.stages]
        sources, sink, edges = dict(self.sources), self.sink, self.edges
        stages = self.stages

        def execute(rng: jax.Array, dyn) -> jnp.ndarray:
            nodes = _init_sources(sources, rng)
            for stage, fn in zip(stages, stage_fns):
                xs = [nodes[s] for s in stage.src]
                nodes[stage.dst] = fn(rng, xs, nodes.get(stage.dst),
                                      self._stage_dyn(stage, dyn))
            if sink is not None:
                return jnp.sum(nodes[sink])
            return sum(jnp.sum(nodes[t]) for t in _terminals(edges))

        return execute

    def build(self) -> Callable[[jax.Array], jnp.ndarray]:
        """Static form: the plan's lowering-time params baked in.  Lower
        with ``cache=False`` when the *current* DAG values must be baked
        (the profiler path) — a cached plan keeps first-lowering params."""
        pfn = self.build_parametric()
        return lambda rng: pfn(rng, None)

    def build_population(self) -> Callable:
        """``fn(rng, dyn_batched) -> (n,)`` — the canonical vmapped
        population form; per-lane computation is the exact
        :meth:`build_parametric` program (bucketed drivers call this once
        per bucket with the bucket's slice)."""
        pfn = self.build_parametric()

        def population(rng: jax.Array, dyn_batched) -> jnp.ndarray:
            return jax.vmap(lambda dyn: pfn(rng, dyn))(dyn_batched)

        return population

    def stages_parametric(self):
        """Staged form at fused-stage granularity (the hadoop execution
        shape: one host spill per *stage*, not per edge).

        Returns ``(init_fn, stages, finalize_fn)`` with ``stages`` a list
        of ``(src_names, dst, stage_fn, stage_key)``;
        ``stage_fn(rng, xs, prev, dyn_stage)`` takes the member edges' dyn
        dicts as a tuple (or ``None``) and ``stage_key`` identifies the
        compiled stage (member indices seed the rng folds, so they are
        part of the identity alongside the structural keys)."""
        sources, sink, edges = dict(self.sources), self.sink, self.edges

        def init_fn(rng: jax.Array) -> Dict[str, jnp.ndarray]:
            return _init_sources(sources, rng)

        stages = [(list(s.src), s.dst, self._stage_callable(s),
                   (s.members, tuple(edges[ei].structure_key()
                                     for ei in s.members)))
                  for s in self.stages]

        def finalize_fn(nodes: Dict[str, jnp.ndarray]) -> jnp.ndarray:
            if sink is not None:
                return jnp.sum(nodes[sink])
            return sum(jnp.sum(nodes[t]) for t in _terminals(edges))

        return init_fn, stages, finalize_fn

    # -- population scheduling ----------------------------------------------

    def stage_dyn_tuples(self, dyn) -> List[Optional[Tuple]]:
        """Per-stage dyn tuples in stage order (staged-driver plumbing)."""
        return [self._stage_dyn(s, dyn) for s in self.stages]

    def candidate_costs(self, dynb) -> Tuple[np.ndarray, np.ndarray]:
        """Per-candidate ``(weighted_cost, total_trips)`` from a stacked
        dynamic-param pytree — the stratification key.  Cost weights each
        edge's repeat count by its lowering-time body cost so a candidate
        heavy on an expensive edge lands in a later bucket than one heavy
        on glue."""
        sizes = {int(v.shape[0]) for d in dynb for v in d.values()
                 if getattr(v, "shape", ())}
        n = sizes.pop() if len(sizes) == 1 else 1
        costs = np.zeros(n, np.float64)
        trips = np.zeros(n, np.float64)
        for ei, e in enumerate(self.edges):
            d = dynb[ei] if ei < len(dynb) else {}
            w = (np.asarray(d["weight"], np.float64) if "weight" in d
                 else np.full(n, float(e.params.weight)))
            costs += np.round(np.maximum(w, 0.0)) \
                * max(_edge_body_cost(e), 1.0)
            trips += w
        return costs, trips

    def bucket_schedule(self, dynb, bucket_size: Optional[int] = None
                        ) -> BucketSchedule:
        """Weight-stratified :class:`BucketSchedule` for a stacked dyn
        pytree.  ``bucket_size`` defaults to :func:`resolve_bucket_size`
        (one lane per device, ``REPRO_POP_BUCKETS`` override); the
        schedule is a pure function of the candidate values —
        deterministic across processes (stable argsort over float64
        costs)."""
        costs, trips = self.candidate_costs(dynb)
        n = int(costs.shape[0])
        if bucket_size is None:
            bucket_size = resolve_bucket_size(n)
        return make_bucket_schedule(costs, trips, bucket_size)


# ---------------------------------------------------------------------------
# lower() + plan cache
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict[Tuple, ExecutionPlan] = {}
_PLAN_CACHE_CAP = 512
_PLAN_STATS = {"hits": 0, "misses": 0}

#: the plan cache is a pool domain like every other compiled-artifact
#: cache; lookups mirror into _PLAN_STATS so plan_stats() keeps working
_PLAN_DOM = get_pool().register("plans", _PLAN_CACHE, kind="plan",
                                cap=_PLAN_CACHE_CAP, mirror=_PLAN_STATS)


def plan_stats() -> Dict[str, int]:
    return dict(_PLAN_STATS)


def reset_plan_stats() -> None:
    for k in _PLAN_STATS:
        _PLAN_STATS[k] = 0


def clear_plan_cache() -> None:
    get_pool().clear("plans")


def _lower(dag: ProxyDAG, threshold: float) -> ExecutionPlan:
    dag.validate()
    edges = dag._rounded_edges()
    return ExecutionPlan(dag_key=dag.canonical_structure_key(),
                         sources=dict(dag.sources),
                         sink=dag.sink,
                         edges=edges,
                         stages=_partition(dag, edges, threshold),
                         threshold=threshold)


def lower_population(dag: ProxyDAG) -> ExecutionPlan:
    """Plan for *population* (candidate-batched) execution on the in-memory
    stacks: always unfused.  Under a batched candidate axis a fused
    stage's ``lax.switch`` must execute every branch per trip (vmap
    semantics), and per-edge loops give the bucket schedule exactly the
    per-edge trip bounds it stratifies — stage fusion only multiplies
    masked work there.  The hadoop staged driver still consumes the fused
    :func:`lower` plan for populations: its modeled cost is spill volume,
    which shrinks with the stage count."""
    return lower(dag, threshold=0.0)


def lower(dag: ProxyDAG, threshold: Optional[float] = None,
          cache: bool = True) -> ExecutionPlan:
    """Lower a ProxyDAG into an :class:`ExecutionPlan` — once per
    ``(structure, threshold)``.

    The cached plan is shared by every same-structure DAG regardless of
    its current dynamic params (they enter the parametric executables as
    arguments); pass ``cache=False`` to force a fresh lowering whose
    *static* ``build()`` form bakes the caller's current values.
    """
    thr = fusion_threshold() if threshold is None else float(threshold)
    if not cache:
        return _lower(dag, thr)
    key = (dag.canonical_structure_key(), thr)
    return get_pool().get(_PLAN_DOM, key, lambda: _lower(dag, thr))
