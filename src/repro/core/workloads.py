"""The four BigDataBench originals (paper §2.4, Table 3) re-built in JAX,
plus their dwarf-DAG proxy benchmarks.

Each original follows the Hadoop job structure the paper profiles (input
partition → per-chunk map → intermediate materialization → shuffle/reduce);
the proxies are *declarative specs* (``PROXY_SPECS``, see
:mod:`repro.api.spec`) — DAG-like combinations of the Table-3 dwarf
components with initial weights from the paper (e.g. TeraSort = 70% sort,
10% sampling, 20% graph) — loaded through the versioned ProxySpec
round-trip rather than constructed inline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..api.spec import SPEC_VERSION, ProxySpec
from ..data import generators as gen
from .proxy import ProxyBenchmark

# ---------------------------------------------------------------------------
# Scales: "full" sizes the original to seconds on one CPU core (the cluster
# analog), "tiny" is for tests.
# ---------------------------------------------------------------------------

SCALES = {
    "tiny": dict(terasort_n=1 << 12, kmeans_n=1 << 10, kmeans_d=16,
                 kmeans_k=4, kmeans_iters=2, pagerank_e=1 << 12,
                 pagerank_v=1 << 8, pagerank_iters=2, sift_b=2, sift_hw=32),
    "small": dict(terasort_n=1 << 18, kmeans_n=1 << 15, kmeans_d=32,
                  kmeans_k=16, kmeans_iters=3, pagerank_e=1 << 18,
                  pagerank_v=1 << 14, pagerank_iters=3, sift_b=4, sift_hw=128),
    "full": dict(terasort_n=1 << 23, kmeans_n=1 << 21, kmeans_d=64,
                 kmeans_k=64, kmeans_iters=10, pagerank_e=1 << 23,
                 pagerank_v=1 << 19, pagerank_iters=10, sift_b=16,
                 sift_hw=512),
}


@dataclasses.dataclass
class Workload:
    name: str
    pattern: str                           # paper's workload-pattern label
    make_inputs: Callable[[jax.Array, str], Tuple]
    step: Callable                         # jit-able job over the inputs
    table3_weights: Dict[str, float]       # paper's dwarf decomposition
    proxy_spec: Dict[str, Any]             # declarative Table-3 proxy spec

    def make_proxy(self) -> ProxyBenchmark:
        """Load the Table-3 proxy through the ProxySpec round-trip."""
        return ProxySpec.from_json(self.proxy_spec).to_benchmark()


def _edge(component: str, src, dst: str, *, weight: int = 1,
          data_size: int = 1 << 15, chunk_size: int = 256,
          parallelism: int = 1, **extra) -> Dict[str, Any]:
    """One declarative proxy-spec edge (plain JSON data)."""
    return {"component": component, "src": list(src), "dst": dst,
            "data_size": data_size, "chunk_size": chunk_size,
            "parallelism": parallelism, "weight": weight, "extra": extra}


# ---------------------------------------------------------------------------
# TeraSort — I/O intensive; dwarfs: sort, sampling, graph
# ---------------------------------------------------------------------------


def _terasort_inputs(rng: jax.Array, scale: str):
    n = SCALES[scale]["terasort_n"]
    keys, payload = gen.gen_records(rng, n)
    return keys, payload


def terasort_step(keys: jnp.ndarray, payload: jnp.ndarray):
    """sample -> range-partition -> shuffle -> per-partition sort."""
    n = keys.shape[0]
    n_part = 16
    # 1. interval sampling of keys (the TeraSort partitioner)
    sample = keys[:: max(1, n // 1024)]
    splitters = jnp.sort(sample)[:: max(1, sample.shape[0] // n_part)][1:n_part]
    # 2. partition id per record (range partitioner)
    pid = jnp.searchsorted(splitters, keys).astype(jnp.uint32)
    # 3. shuffle + sort: lexicographic (partition, key) — models the reduce
    #    phase where each reducer sorts its own range
    sorted_pid, sorted_keys, sorted_payload = jax.lax.sort(
        (pid, keys, payload), num_keys=2)
    # 4. per-partition boundary graph: offsets of each partition (degree count)
    counts = jnp.zeros((n_part,), jnp.int32).at[sorted_pid.astype(jnp.int32)].add(1)
    return sorted_keys, sorted_payload, counts


TERASORT_PROXY_SPEC: Dict[str, Any] = {
    "spec_version": SPEC_VERSION,
    "name": "proxy_terasort",
    "description": "Proxy TeraSort (Table 3: 70% sort / 10% sampling / "
                   "20% graph)",
    "stack": "hadoop",            # I/O intensive: host-spilled intermediates
    "scale": None,
    "sources": {"src": 1 << 15},
    "edges": [
        # sampling: 10%
        _edge("interval_sampling", ["src"], "sampled", chunk_size=2048,
              stride=4),
        _edge("random_sampling", ["src"], "sampled", chunk_size=2048,
              fraction=0.25),
        # sort: 70%
        _edge("quick_sort", ["sampled"], "sorted", weight=4, chunk_size=2048),
        _edge("merge_sort", ["sorted"], "merged", weight=2, chunk_size=2048),
        # graph: 20%
        _edge("graph_construction", ["merged"], "parts", chunk_size=2048,
              vertices=512),
        _edge("graph_traversal", ["parts"], "out", chunk_size=2048,
              vertices=512, hops=2),
    ],
    "sink": "out",
}


def terasort_proxy() -> ProxyBenchmark:
    return ProxySpec.from_json(TERASORT_PROXY_SPEC).to_benchmark()


# ---------------------------------------------------------------------------
# Kmeans — CPU intensive; dwarfs: matrix, sort, statistic
# ---------------------------------------------------------------------------


def _kmeans_inputs(rng: jax.Array, scale: str, sparsity: float = 0.0):
    s = SCALES[scale]
    k1, k2 = jax.random.split(rng)
    if sparsity > 0.0:
        idx, vals = gen.gen_sparse_csr(k1, s["kmeans_n"], s["kmeans_d"], sparsity)
        centers = gen.gen_matrix(k2, s["kmeans_k"], s["kmeans_d"])
        return idx, vals, centers
    x = gen.gen_matrix(k1, s["kmeans_n"], s["kmeans_d"])
    centers = gen.gen_matrix(k2, s["kmeans_k"], s["kmeans_d"])
    return x, centers


def kmeans_step(x: jnp.ndarray, centers: jnp.ndarray, iters: int = 3):
    """Lloyd iterations: distance matrix -> argmin -> grouped means."""

    def body(c, _):
        d2 = (jnp.sum(x * x, 1, keepdims=True) - 2.0 * x @ c.T
              + jnp.sum(c * c, 1))
        assign = jnp.argmin(d2, axis=1)                       # sort dwarf
        sums = jax.ops.segment_sum(x, assign, num_segments=c.shape[0])
        cnt = jax.ops.segment_sum(jnp.ones((x.shape[0],)), assign,
                                  num_segments=c.shape[0])
        newc = (sums / jnp.maximum(cnt, 1.0)[:, None]).astype(c.dtype)
        inertia = jnp.sum(jnp.min(d2, axis=1))
        return newc, inertia

    centers, inertia = jax.lax.scan(body, centers, None, length=iters)
    return centers, inertia


def kmeans_sparse_step(idx: jnp.ndarray, vals: jnp.ndarray,
                       centers: jnp.ndarray, iters: int = 3):
    """CSR Kmeans: gathered-dot distances (sparsity changes every shape)."""

    def body(c, _):
        # x.c^T for CSR rows: gather center cols then weighted sum
        gathered = c.T[idx]                       # (n, nnz, k)
        dots = jnp.einsum("ne,nek->nk", vals, gathered)
        d2 = jnp.sum(vals * vals, 1, keepdims=True) - 2.0 * dots \
            + jnp.sum(c * c, 1)
        assign = jnp.argmin(d2, axis=1)
        # grouped mean in the sparse pattern's dense footprint
        dense = jnp.zeros((vals.shape[0], c.shape[1])
                          ).at[jnp.arange(vals.shape[0])[:, None], idx].add(vals)
        sums = jax.ops.segment_sum(dense, assign, num_segments=c.shape[0])
        cnt = jax.ops.segment_sum(jnp.ones((vals.shape[0],)), assign,
                                  num_segments=c.shape[0])
        newc = sums / jnp.maximum(cnt, 1.0)[:, None]
        return newc, jnp.sum(jnp.min(d2, axis=1))

    centers, inertia = jax.lax.scan(body, centers, None, length=iters)
    return centers, inertia


KMEANS_PROXY_SPEC: Dict[str, Any] = {
    "spec_version": SPEC_VERSION,
    "name": "proxy_kmeans",
    "description": "Proxy Kmeans (Table 3: matrix / sort / basic statistic)",
    "stack": "openmp",            # CPU intensive: single-process jit
    "scale": None,
    "sources": {"src": 1 << 15},
    "edges": [
        _edge("euclidean_distance", ["src"], "dist", weight=4, chunk_size=64,
              centers=16),
        _edge("cosine_distance", ["src"], "dist", chunk_size=64, centers=16),
        _edge("quick_sort", ["dist"], "assign", chunk_size=64),
        _edge("count_average", ["assign"], "stats", weight=2, chunk_size=64),
        _edge("grouped_count", ["stats"], "out", chunk_size=64, groups=16),
    ],
    "sink": "out",
}


def kmeans_proxy() -> ProxyBenchmark:
    return ProxySpec.from_json(KMEANS_PROXY_SPEC).to_benchmark()


# ---------------------------------------------------------------------------
# PageRank — hybrid; dwarfs: matrix, sort, statistic (paper Table 3)
# ---------------------------------------------------------------------------


def _pagerank_inputs(rng: jax.Array, scale: str):
    s = SCALES[scale]
    src, dst = gen.gen_graph(rng, s["pagerank_e"], s["pagerank_v"])
    return src, dst


def pagerank_step(src: jnp.ndarray, dst: jnp.ndarray, n_vertices: int,
                  iters: int = 5):
    deg = jnp.zeros((n_vertices,), jnp.float32).at[src].add(1.0)

    def body(rank, _):
        contrib = rank[src] / jnp.maximum(deg[src], 1.0)      # matrix row-norm
        nxt = jnp.zeros((n_vertices,), jnp.float32).at[dst].add(contrib)
        nxt = 0.15 / n_vertices + 0.85 * nxt
        return nxt, jnp.max(jnp.abs(nxt - rank))              # min/max calc

    rank0 = jnp.full((n_vertices,), 1.0 / n_vertices)
    rank, deltas = jax.lax.scan(body, rank0, None, length=iters)
    top_vals, top_idx = jax.lax.top_k(rank, 16)               # sort dwarf
    return rank, top_vals, deltas


PAGERANK_PROXY_SPEC: Dict[str, Any] = {
    "spec_version": SPEC_VERSION,
    "name": "proxy_pagerank",
    "description": "Proxy PageRank (Table 3: matrix / sort / basic "
                   "statistic)",
    "stack": "spark",             # hybrid: global-view, memory-resident
    "scale": None,
    "sources": {"src": 1 << 15},
    "edges": [
        _edge("matrix_construction", ["src"], "mat"),
        _edge("matrix_multiplication", ["mat"], "mm"),
        _edge("spmv", ["src"], "mm", weight=3, vertices=4096),
        _edge("graph_construction", ["mm"], "deg", vertices=4096),
        _edge("quick_sort", ["deg"], "ranked"),
        _edge("min_max", ["ranked"], "norm"),
        _edge("grouped_count", ["norm"], "out", groups=256),
    ],
    "sink": "out",
}


def pagerank_proxy() -> ProxyBenchmark:
    return ProxySpec.from_json(PAGERANK_PROXY_SPEC).to_benchmark()


# ---------------------------------------------------------------------------
# SIFT — CPU+memory intensive; dwarfs: matrix, sort, sampling, transform, stat
# ---------------------------------------------------------------------------


def _sift_inputs(rng: jax.Array, scale: str):
    s = SCALES[scale]
    return (gen.gen_images(rng, s["sift_b"], s["sift_hw"], s["sift_hw"]),)


def sift_step(images: jnp.ndarray):
    """FFT gaussian pyramid -> DoG -> extrema -> orientation histograms."""
    b, h, w = images.shape
    spec = jnp.fft.rfft2(images)                              # transform
    fy = jnp.fft.fftfreq(h)[:, None]
    fx = jnp.fft.rfftfreq(w)[None, :]
    freq2 = fy * fy + fx * fx
    octaves = []
    for sigma in (1.0, 2.0, 4.0, 8.0):
        g = jnp.exp(-2.0 * (jnp.pi ** 2) * freq2 * sigma ** 2)
        octaves.append(jnp.fft.irfft2(spec * g, s=(h, w)))
    pyr = jnp.stack(octaves, 1)                               # (b, 4, h, w)
    dog = pyr[:, 1:] - pyr[:, :-1]                            # (b, 3, h, w)
    # local extrema: 3x3 max-pool compare
    mx = jax.lax.reduce_window(dog, -jnp.inf, jax.lax.max, (1, 1, 3, 3),
                               (1, 1, 1, 1), "SAME")
    is_max = (dog >= mx).astype(jnp.float32)                  # set/compare
    # gradients + orientation histogram (8 bins)
    gy = dog[:, :, 1:, :] - dog[:, :, :-1, :]
    gx = dog[:, :, :, 1:] - dog[:, :, :, :-1]
    gy, gx = gy[:, :, :, 1:], gx[:, :, 1:, :]
    mag = jnp.sqrt(gy * gy + gx * gx + 1e-12)
    ang = jnp.arctan2(gy, gx)
    bins = ((ang + jnp.pi) / (2 * jnp.pi) * 8).astype(jnp.int32) % 8
    hist = jax.ops.segment_sum(mag.reshape(-1), bins.reshape(-1),
                               num_segments=8)                # statistic
    # descriptors: sampled patches x random projection (matrix)
    patches = dog[:, :, ::8, ::8].reshape(b, -1)              # interval sample
    proj = jax.random.normal(jax.random.PRNGKey(7), (patches.shape[1], 64))
    desc = patches @ proj
    top_vals, _ = jax.lax.top_k(desc.reshape(b, -1), 32)      # sort
    return desc, hist, is_max.sum(), top_vals


SIFT_PROXY_SPEC: Dict[str, Any] = {
    "spec_version": SPEC_VERSION,
    "name": "proxy_sift",
    "description": "Proxy SIFT (Table 3: matrix / sort / sampling / "
                   "transform / statistic)",
    "stack": "mpi",               # CPU+memory intensive: explicit SPMD
    "scale": None,
    "sources": {"src": 1 << 15},
    "edges": [
        _edge("fft", ["src"], "freq", weight=3),
        _edge("matrix_construction", ["freq"], "mat"),
        _edge("matrix_multiplication", ["mat"], "mm", weight=2),
        _edge("interval_sampling", ["mm"], "sampled", stride=8),
        _edge("quick_sort", ["sampled"], "sorted"),
        _edge("min_max", ["sorted"], "norm"),
        _edge("histogram", ["norm"], "out", bins=8),
    ],
    "sink": "out",
}


def sift_proxy() -> ProxyBenchmark:
    return ProxySpec.from_json(SIFT_PROXY_SPEC).to_benchmark()


# ---------------------------------------------------------------------------
# AI proxies (Data-Dwarfs extension) — LM training and decode-serving as
# dwarf DAGs over the AI components (core/dwarfs/ai.py).  These have no
# "original" Hadoop-style step function; their reference targets are the
# full-model dry-run cells benchmarks/lm_proxy.py profiles.
# ---------------------------------------------------------------------------

LM_TRAIN_PROXY_SPEC: Dict[str, Any] = {
    "spec_version": SPEC_VERSION,
    "name": "proxy_lm_train",
    "description": "Proxy LM training step (AI dwarfs: gemm fwd+bwd, "
                   "GQA attention, loss statistics)",
    "stack": "mpi",               # training is SPMD: explicit data parallel
    "scale": None,
    "sources": {"tokens": 1 << 15},
    "edges": [
        # in/out projections: dense-layer GEMM triple, 2 optimizer rounds
        _edge("gemm_train", ["tokens"], "h0", weight=2, rounds=2),
        # GQA flash attention over the residual stream
        _edge("attention", ["h0"], "attn", weight=2, seq_len=128, heads=4,
              kv_heads=2),
        # MLP block dominates train-step flops
        _edge("gemm_train", ["attn"], "mlp", weight=4, rounds=2),
        # loss reduction / metrics
        _edge("count_average", ["mlp"], "out"),
    ],
    "sink": "out",
}


def lm_train_proxy() -> ProxyBenchmark:
    return ProxySpec.from_json(LM_TRAIN_PROXY_SPEC).to_benchmark()


LM_DECODE_PROXY_SPEC: Dict[str, Any] = {
    "spec_version": SPEC_VERSION,
    "name": "proxy_lm_decode",
    "description": "Proxy LM decode step (AI dwarfs: MQA-style attention, "
                   "recurrent scan, top-k sampling)",
    "stack": "openmp",            # latency path: single-process jit
    "scale": None,
    "sources": {"tokens": 1 << 14},
    "edges": [
        # KV-cache-heavy attention: many query heads per KV head
        _edge("attention", ["tokens"], "attn", weight=2, data_size=1 << 14,
              chunk_size=128, seq_len=256, heads=8, kv_heads=2),
        # hybrid-decode recurrence (SSM scan + readout projection)
        _edge("scan_recurrent", ["attn"], "ssm", data_size=1 << 14,
              chunk_size=128, state=8, rounds=1),
        # sampling the next token: top-k over logits
        _edge("top_k", ["ssm"], "out", data_size=1 << 14, chunk_size=128,
              k=16),
    ],
    "sink": "out",
}


def lm_decode_proxy() -> ProxyBenchmark:
    return ProxySpec.from_json(LM_DECODE_PROXY_SPEC).to_benchmark()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def _kmeans_io(scale):  # default dense
    return _kmeans_inputs(jax.random.PRNGKey(0), scale)


#: workload name -> declarative Table-3 proxy spec (the emit/load surface)
PROXY_SPECS: Dict[str, Dict[str, Any]] = {
    "terasort": TERASORT_PROXY_SPEC,
    "kmeans": KMEANS_PROXY_SPEC,
    "pagerank": PAGERANK_PROXY_SPEC,
    "sift": SIFT_PROXY_SPEC,
    "lm_train": LM_TRAIN_PROXY_SPEC,
    "lm_decode": LM_DECODE_PROXY_SPEC,
}

def seed_structures(names: Optional[Sequence[str]] = None) -> List["ProxyDAG"]:
    """Seed pool for the structural search: every named Table-3 proxy's
    DAG, loaded through the versioned ProxySpec round-trip (so a machine
    mutation always starts from the same structures a human would).
    ``names`` restricts/reorders the pool; default is every
    ``PROXY_SPECS`` entry in sorted order."""
    picked = sorted(PROXY_SPECS) if names is None else list(names)
    return [ProxySpec.from_json(PROXY_SPECS[n]).to_benchmark().dag
            for n in picked]


def seed_components(names: Optional[Sequence[str]] = None) -> List[str]:
    """The dwarf components appearing in the Table-3 proxies — the
    default mutation-component pool a structural search draws from when
    the caller does not widen it."""
    picked = sorted(PROXY_SPECS) if names is None else list(names)
    return sorted({e["component"]
                   for n in picked for e in PROXY_SPECS[n]["edges"]})


WORKLOADS: Dict[str, Workload] = {
    "terasort": Workload(
        "terasort", "io-intensive", _terasort_inputs,
        terasort_step,
        {"sort": 0.7, "sampling": 0.1, "graph": 0.2},
        TERASORT_PROXY_SPEC),
    "kmeans": Workload(
        "kmeans", "cpu-intensive", lambda r, s: _kmeans_inputs(r, s),
        lambda x, c: kmeans_step(x, c, 3),
        {"matrix": 0.6, "sort": 0.2, "statistic": 0.2},
        KMEANS_PROXY_SPEC),
    "pagerank": Workload(
        "pagerank", "hybrid", _pagerank_inputs,
        None,  # bound per-scale below (needs n_vertices)
        # Table 1 lists PageRank as Matrix+Graph+Sort; our original realizes
        # the sparse matrix product as gather/segment-sum (graph dwarf)
        {"graph": 0.45, "matrix": 0.25, "sort": 0.15, "statistic": 0.15},
        PAGERANK_PROXY_SPEC),
    "sift": Workload(
        "sift", "cpu-memory-intensive", _sift_inputs,
        sift_step,
        {"matrix": 0.35, "transform": 0.25, "sampling": 0.1, "sort": 0.15,
         "statistic": 0.15},
        SIFT_PROXY_SPEC),
}


def workload_step_fn(name: str, scale: str):
    """Returns (fn, args) ready for characterize()/execution."""
    w = WORKLOADS[name]
    rng = jax.random.PRNGKey(0)
    args = w.make_inputs(rng, scale)
    s = SCALES[scale]
    if name == "pagerank":
        fn = lambda src, dst: pagerank_step(src, dst, s["pagerank_v"],
                                            s["pagerank_iters"])
    elif name == "kmeans":
        fn = lambda x, c: kmeans_step(x, c, s["kmeans_iters"])
    else:
        fn = w.step
    return fn, args


def proxy_fingerprint(name: str):
    """Fingerprint a Table-3 proxy (any ``PROXY_SPECS`` key) through the
    compositional cost model — zero compiles once its edges are cached.

    This is the *proxy side* of the distillation loop: the vector a
    perfectly-tuned synthesis should land on.  Compare
    :func:`workload_fingerprint`, which measures the original."""
    from .engine import fingerprint
    if name not in PROXY_SPECS:
        raise KeyError(f"unknown proxy {name!r}; known: "
                       f"{sorted(PROXY_SPECS)}")
    dag = ProxySpec.from_json(PROXY_SPECS[name]).to_dag()
    return fingerprint(dag, name=name)


def workload_fingerprint(name: str, scale: str = "tiny"):
    """Fingerprint an *original* workload implementation — the measured
    target the paper distills proxies from.

    Big-data originals (``WORKLOADS``) are lowered through HLO cost
    analysis at ``scale`` via :func:`workload_step_fn`; the AI proxies
    (``lm_train``/``lm_decode``), which have no separate original here,
    fall back to their spec DAG's compositional fingerprint."""
    from .engine import fingerprint
    if name in WORKLOADS:
        fn, args = workload_step_fn(name, scale)
        return fingerprint(fn, *args, name=name)
    if name in PROXY_SPECS:
        return proxy_fingerprint(name)
    raise KeyError(f"unknown workload {name!r}; known: "
                   f"{sorted(set(WORKLOADS) | set(PROXY_SPECS))}")
