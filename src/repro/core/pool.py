"""Unified ExecutablePool: every compiled artifact under one policy.

Before the serving engine, each subsystem owned its caches ad hoc: every
:class:`~repro.api.stack.Stack` instance held a private executable dict,
:mod:`repro.core.engine` kept process-wide report/executable dicts, and
:mod:`repro.core.schedule` its plan cache — three admission/eviction
policies and no single place to ask "what is compiled right now?".  A
serving process needs exactly that place: admission, FIFO eviction and
warmup become *one* policy, pool-wide stats expose cold-vs-warm behavior,
and a declared working set can be pre-compiled before traffic arrives.

The pool does not own the artifact *values* — callers keep using their
module/instance dicts (so existing cache-reference semantics, tests and
instance lifetimes are untouched) — it owns the **bookkeeping**: every
cache registers as a :class:`PoolDomain`, lookups route through
:meth:`ExecutablePool.get`, and the pool enforces

* the domain's own FIFO cap (``cap``), and
* a pool-wide cap over all ``kind="executable"`` domains
  (``REPRO_POOL_CAP``; unset = per-domain caps only): when the total
  number of retained compiled programs exceeds it, a victim is chosen
  across all domains by the :func:`pool_policy` — cheapest-to-recompile
  first under the default ``"cost"`` policy, globally oldest-inserted
  under ``"fifo"`` (``REPRO_POOL_POLICY``).

Thread-safety rides on :data:`repro.core.cachetools.LOCK` — one reentrant
process-wide lock shared with the low-level helpers, so pool lookups and
legacy ``cached_get`` callers serialize against each other.

Stack instances register per-instance domains (tests and benchmarks rely
on a fresh ``OpenMPStack()`` starting cold); a ``weakref.finalize``
unregisters the domain when the instance dies so a churn of short-lived
stacks cannot leak bookkeeping.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import weakref
from typing import Any, Callable, Dict, List, Optional, Tuple

from .cachetools import LOCK, hit_rate

#: artifact classes a domain declares; only "executable" domains count
#: against the pool-wide cap (reports are small dataclasses, plans are
#: pure IR — retained compiled XLA programs are what must stay bounded)
KINDS = ("executable", "report", "plan")


def pool_cap() -> Optional[int]:
    """Pool-wide retained-executable cap (``REPRO_POOL_CAP`` env var;
    unset/empty = no pool-wide cap, per-domain caps still apply)."""
    raw = os.environ.get("REPRO_POOL_CAP")
    if raw is None or raw.strip() == "":
        return None
    return max(1, int(raw))


POOL_POLICIES = ("cost", "fifo")


def pool_policy() -> str:
    """Pool-wide eviction policy (``REPRO_POOL_POLICY`` env var).

    * ``"cost"`` (default) — under pool-cap pressure, evict the retained
      executable that is *cheapest to recompile* first (ties broken
      oldest-first).  Cost is the plan's own model
      (``sum(stage.cost for stage in plan.stages)``), attached by callers
      at admission; artifacts admitted without a cost count as ``0.0``
      and are the preferred victims.
    * ``"fifo"`` — the legacy policy: globally oldest-inserted first.

    Per-domain ``cap`` enforcement is FIFO under either policy: a domain
    cap bounds one cache's *churn*, where insertion order is the signal
    (see the serving engine's ``REPRO_EXEC_CACHE_CAP``)."""
    raw = os.environ.get("REPRO_POOL_POLICY")
    if raw is None or raw.strip() == "":
        return "cost"
    if raw not in POOL_POLICIES:
        raise ValueError(f"unknown pool policy {raw!r}; "
                         f"one of {POOL_POLICIES}")
    return raw


@dataclasses.dataclass
class PoolDomain:
    """One registered cache: the owning dict plus its policy knobs."""

    name: str
    cache: Dict                       # the caller-owned artifact dict
    kind: str = "executable"
    cap: Optional[int] = None         # per-domain FIFO cap (None = uncapped)
    #: optional legacy counter dict mirrored on every lookup (e.g. the
    #: stack module's CACHE_STATS — kept so existing tests keep reading it)
    mirror: Optional[Dict[str, int]] = None
    stats: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"hits": 0, "misses": 0, "evictions": 0,
                                 "invalidations": 0, "failures": 0})
    #: insertion sequence per key (global order for pool-wide FIFO)
    seq: Dict[Any, int] = dataclasses.field(default_factory=dict)
    #: recompile-cost per key (the plan cost model; absent = 0.0 — see
    #: :func:`pool_policy`)
    cost: Dict[Any, float] = dataclasses.field(default_factory=dict)

    def oldest_seq(self) -> Optional[int]:
        if not self.cache:
            return None
        return self.seq.get(next(iter(self.cache)), -1)


class ExecutablePool:
    """One admission/eviction/warmup policy over every compiled artifact.

    ``get(domain, key, make)`` is the single lookup-or-build entry point;
    ``warmup(specs)`` pre-compiles a declared working set so a serving
    process reaches its zero-retrace steady state before the first
    request; ``stats()`` reports per-domain and pool-wide hit rates and
    sizes (the cold-vs-warm axis the serving bench gates on)."""

    def __init__(self, cap: Optional[int] = None):
        self._cap = cap               # None -> read REPRO_POOL_CAP live
        self._domains: Dict[str, PoolDomain] = {}
        self._seq = itertools.count()
        #: which rule chose each eviction victim (surfaced in stats())
        self._evictions_by_policy: Dict[str, int] = {
            "domain_fifo": 0, "pool_fifo": 0, "pool_cost": 0}

    # -- registration --------------------------------------------------------

    def register(self, name: str, cache: Optional[Dict] = None, *,
                 kind: str = "executable", cap: Optional[int] = None,
                 mirror: Optional[Dict[str, int]] = None) -> PoolDomain:
        """Register (or fetch) the domain ``name``.  Re-registration with
        the same name returns the existing domain — module-level caches
        register once at import, stack instances pick fresh names."""
        if kind not in KINDS:
            raise ValueError(f"unknown domain kind {kind!r}; one of {KINDS}")
        with LOCK:
            dom = self._domains.get(name)
            if dom is None:
                dom = PoolDomain(name=name, cache={} if cache is None
                                 else cache, kind=kind, cap=cap,
                                 mirror=mirror)
                self._domains[name] = dom
            return dom

    def register_instance(self, owner: Any, name: str, *,
                          kind: str = "executable",
                          cap: Optional[int] = None,
                          mirror: Optional[Dict[str, int]] = None
                          ) -> PoolDomain:
        """Per-instance domain under a unique ``name#k`` suffix, auto-
        unregistered when ``owner`` is garbage-collected — a fresh stack
        instance starts cold and cannot leak pool bookkeeping."""
        with LOCK:
            unique = name
            k = 0
            while unique in self._domains:
                k += 1
                unique = f"{name}#{k}"
            dom = self.register(unique, kind=kind, cap=cap, mirror=mirror)
        weakref.finalize(owner, self.unregister, unique)
        return dom

    def unregister(self, name: str) -> None:
        with LOCK:
            self._domains.pop(name, None)

    def domain(self, name: str) -> PoolDomain:
        return self._domains[name]

    # -- lookup-or-build -----------------------------------------------------

    def get(self, dom: PoolDomain, key: Any, make: Callable[[], Any], *,
            cost: Optional[float] = None) -> Any:
        """Fetch ``key`` from ``dom``, building on a miss under the shared
        lock (two threads missing the same key build once), then enforce
        the domain cap and the pool-wide executable cap.  ``cost`` is the
        artifact's recompile cost under the plan cost model — consulted
        only by the ``"cost"`` eviction policy (:func:`pool_policy`)."""
        with LOCK:
            value = dom.cache.get(key)
            if value is not None:
                dom.stats["hits"] += 1
                if dom.mirror is not None:
                    dom.mirror["hits"] = dom.mirror.get("hits", 0) + 1
                return value
            dom.stats["misses"] += 1
            if dom.mirror is not None:
                dom.mirror["misses"] = dom.mirror.get("misses", 0) + 1
            value = make()
            self.put(dom, key, value, cost=cost)
            return value

    def put(self, dom: PoolDomain, key: Any, value: Any, *,
            cost: Optional[float] = None) -> Any:
        """Admit an externally built artifact (callers with bespoke miss
        accounting — the engine's compile counters — insert through here
        so eviction bookkeeping stays coherent)."""
        with LOCK:
            dom.cache[key] = value
            dom.seq[key] = next(self._seq)
            if cost is not None:
                dom.cost[key] = float(cost)
            self._enforce(dom)
        return value

    # -- eviction ------------------------------------------------------------

    def _evict(self, dom: PoolDomain, key: Any, policy: str) -> None:
        dom.cache.pop(key)
        dom.seq.pop(key, None)
        dom.cost.pop(key, None)
        dom.stats["evictions"] += 1
        self._evictions_by_policy[policy] += 1
        if dom.mirror is not None:
            dom.mirror["evictions"] = dom.mirror.get("evictions", 0) + 1

    def _evict_oldest(self, dom: PoolDomain, policy: str = "domain_fifo") -> None:
        self._evict(dom, next(iter(dom.cache)), policy)

    def _enforce(self, dom: PoolDomain) -> None:
        # the domain's own cap is always FIFO — it bounds one cache's
        # churn, where insertion order is the signal callers rely on
        while dom.cap is not None and len(dom.cache) > dom.cap:
            self._evict_oldest(dom, "domain_fifo")
        cap = pool_cap() if self._cap is None else self._cap
        if cap is None:
            return
        cost_policy = pool_policy() == "cost"
        while self.executables() > cap:
            if cost_policy:
                # cheapest-to-recompile first, oldest among equals; an
                # artifact admitted without a cost counts as 0.0 and is
                # the preferred victim
                d, k = min(
                    ((d, k) for d in self._domains.values()
                     if d.kind == "executable" for k in d.cache),
                    key=lambda dk: (dk[0].cost.get(dk[1], 0.0),
                                    dk[0].seq.get(dk[1], -1)))
                self._evict(d, k, "pool_cost")
            else:
                victim = min(
                    (d for d in self._domains.values()
                     if d.kind == "executable" and d.cache),
                    key=lambda d: d.oldest_seq())
                self._evict_oldest(victim, "pool_fifo")

    def clear(self, name: Optional[str] = None) -> None:
        """Drop every artifact of domain ``name`` (or of every domain)."""
        with LOCK:
            doms = ([self._domains[name]] if name is not None
                    else list(self._domains.values()))
            for d in doms:
                d.cache.clear()
                d.seq.clear()
                d.cost.clear()

    # -- failure health ------------------------------------------------------

    def invalidate(self, dom: PoolDomain, key: Any) -> bool:
        """Drop one artifact because a dispatch through it failed — the
        serving engine's invalidate-on-failure hook.  A retried dispatch
        then rebuilds fresh (the cached executable itself may be the
        fault: a poisoned trace, a kernel miscompiled under since-changed
        env knobs).  Returns whether the key was present; counts into
        ``stats["invalidations"]`` either way a failure was recorded."""
        with LOCK:
            present = key in dom.cache
            if present:
                dom.cache.pop(key)
                dom.seq.pop(key, None)
                dom.cost.pop(key, None)
                dom.stats["invalidations"] += 1
            return present

    def record_failure(self, dom: PoolDomain) -> None:
        """Count one failed dispatch against ``dom`` — the health signal
        ``stats()`` exposes per domain (a domain whose failures grow while
        its hit rate stays high is serving a poisoned executable)."""
        with LOCK:
            dom.stats["failures"] += 1

    # -- introspection -------------------------------------------------------

    def executables(self) -> int:
        return sum(len(d.cache) for d in self._domains.values()
                   if d.kind == "executable")

    def stats(self) -> Dict[str, Any]:
        """Pool-wide + per-domain sizes, hit/miss/eviction counters and
        hit rates — the single cold-vs-warm report the serving bench and
        the eviction-pressure tests read."""
        with LOCK:
            domains = {}
            totals = {"hits": 0, "misses": 0, "evictions": 0,
                      "invalidations": 0, "failures": 0}
            for name, d in sorted(self._domains.items()):
                domains[name] = {"kind": d.kind, "size": len(d.cache),
                                 "cap": d.cap, **d.stats,
                                 "hit_rate": hit_rate(d.stats)}
                for k in totals:
                    totals[k] += d.stats[k]
            return {
                "domains": domains,
                "executables": self.executables(),
                "artifacts": sum(len(d.cache)
                                 for d in self._domains.values()),
                "pool_cap": pool_cap() if self._cap is None else self._cap,
                "pool_policy": pool_policy(),
                "evictions_by_policy": dict(self._evictions_by_policy),
                **totals,
                "hit_rate": hit_rate(totals),
            }

    # -- warmup --------------------------------------------------------------

    def warmup(self, specs, stack: Any = "openmp",
               bucket_sizes: Tuple[int, ...] = (1,),
               batch: bool = False) -> Dict[str, int]:
        """Pre-compile the declared working set: for every spec/DAG in
        ``specs``, the population-lowered plan plus one executable per
        requested serve bucket size on ``stack`` (``1`` = the unbatched
        parametric form, ``n > 1`` = the vmapped request-batch form; see
        :meth:`repro.api.stack.Stack.serve_batch`).  Idempotent — already
        warm entries cost a cache hit.  Returns how many artifacts were
        actually compiled, so a serving process can assert its steady
        state starts at zero retraces."""
        import jax
        import jax.numpy as jnp

        from ..api.stack import _extract_dag, get_stack
        from . import schedule as plans
        if isinstance(stack, str):
            stack = get_stack(stack)
        compiled = 0
        structures = 0
        rng = jax.random.PRNGKey(0)
        for spec in specs:
            dag = _extract_dag(spec)
            if dag is None:
                raise TypeError(f"warmup needs DAG working-set entries "
                                f"(ProxySpec/ProxyDAG/ProxyBenchmark), got "
                                f"{type(spec).__name__}")
            plan = plans.lower_population(dag)
            structures += 1
            sizes = sorted(set(int(b) for b in bucket_sizes))
            if batch:
                sizes.append(0)    # sentinel: the rng-batched form
            for b in sizes:
                m0 = stack.exec_domain().stats["misses"]
                # jit compiles at first *call*, so warmup must execute
                # each form once with representative (template) params —
                # that trace is the one the steady state then never pays
                dyn = dag.dynamic_params()
                if b == 0:
                    fn = stack._compiled_plan(plan, batch=True)
                    out = fn(rng[None], dyn)
                elif b <= 1:
                    fn = stack._compiled_plan(plan, batch=False)
                    out = fn(rng, dyn)
                else:
                    fn = stack._compiled_plan_serve(plan, b)
                    rngs = jax.random.split(rng, b)
                    dynb = jax.tree_util.tree_map(
                        lambda v: jnp.stack([jnp.asarray(v)] * b), dyn)
                    out = fn(rngs, dynb)
                jax.block_until_ready(out)
                compiled += stack.exec_domain().stats["misses"] - m0
        return {"structures": structures, "compiles": compiled}


#: the process-wide pool every subsystem registers with by default
_POOL = ExecutablePool()


def get_pool() -> ExecutablePool:
    """The process-wide `ExecutablePool` singleton."""
    return _POOL


def pool_stats() -> Dict[str, Any]:
    """Shorthand for ``get_pool().stats()``."""
    return _POOL.stats()
