"""Metric vector, Eq.1 accuracy, roofline terms, and an HLO-text cost analyzer.

This is the measurement substrate of the dwarf methodology (DESIGN.md §2): the
paper compares proxy vs. original workloads on a perf-counter metric vector;
our TPU-native analog is a roofline metric vector derived from the compiled
XLA module.

Why a custom HLO analyzer instead of ``compiled.cost_analysis()``: XLA's cost
analysis visits each computation once, so a ``lax.scan``/``while`` body is
counted a single time regardless of trip count.  Every model here scans over
layers (and SSMs scan over sequence chunks), which would undercount FLOPs,
bytes, and collective traffic by 20-70x.  We parse ``compiled.as_text()``,
build the call graph, and multiply ``while`` bodies by their
``known_trip_count`` (with a condition-constant fallback).

All costs are *per device* (the compiled module is post-SPMD-partitioning);
global = per-device x num_devices for a balanced program.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e-class target; per system brief)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9          # per chip


HW_V5E = HardwareSpec()

#: comparable-unit weight of one sorted element (≈ the log₂n comparator
#: passes a network sort spends per element at benchmark sizes).  Shared
#: by the profiler's dwarf-attribution channels and any cost model that
#: prices sort traffic — one number, one place.
SORT_ELEM_COST = 10.0

DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3": 1, "f8e3m4": 1, "f8e8m0fnu": 1, "f4e2m1fn": 0.5,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "s1": 0.125, "u1": 0.125,
}

# Transcendental elementwise ops get a higher VPU weight (XLA convention ~ 4-10)
_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "sine", "cosine", "tan", "power", "sqrt", "rsqrt", "cbrt", "erf",
    "atan2", "logistic",
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "remainder", "is-finite", "real", "imag", "complex",
    "stochastic-convert",
} | _TRANSCENDENTAL

_LOGIC = {
    "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "popcnt", "count-leading-zeros",
}

_COMPARE = {"compare", "select", "clamp"}

_GATHER_SCATTER = {
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
}

_REDUCE = {"reduce", "reduce-window", "select-and-scatter", "map", "iota_reduce"}

_DATA_MOVEMENT = {
    "copy", "broadcast", "reshape", "transpose", "convert", "slice",
    "concatenate", "pad", "reverse", "iota", "reduce-precision", "copy-start",
    "copy-done", "bitcast-convert",
}

COLLECTIVE_OPS = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
    "all-gather-start", "all-reduce-start", "collective-permute-start",
}

_COLLECTIVE_CANON = {
    "all-gather-start": "all-gather",
    "all-reduce-start": "all-reduce",
    "collective-permute-start": "collective-permute",
    "ragged-all-to-all": "all-to-all",
}

_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "all-gather-done",
    "all-reduce-done", "collective-permute-done", "opt-barrier", "domain",
    "add-dependency",
}

# HLO op class -> dwarf attribution (profiler uses this to seed proxy weights)
OP_CLASS_TO_DWARF = {
    "dot": "matrix",
    "convolution": "matrix",
    "fft": "transform",
    "sort": "sort",
    "rng": "sampling",
    "gather_scatter": "graph",
    "reduce": "statistic",
    "logic": "logic",
    "compare_select": "set",
    "elementwise": "matrix_elementwise",  # folded into matrix/statistic later
    "data_movement": None,
    "collective": None,
    "other": None,
}


# ---------------------------------------------------------------------------
# Shape parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,\s]*)\]")


def _parse_single_shape(tok: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.match(tok.strip())
    if not m:
        return ("opaque", ())
    dtype = m.group(1)
    dims_s = m.group(2).strip()
    dims = tuple(int(d) for d in dims_s.split(",")) if dims_s else ()
    return (dtype, dims)


def parse_shapes(tok: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Parse an HLO shape string (possibly a tuple) to [(dtype, dims), ...]."""
    tok = tok.strip()
    if tok.startswith("("):
        inner = tok[1:-1] if tok.endswith(")") else tok[1:]
        shapes = []
        for m in _SHAPE_RE.finditer(inner):
            dims_s = m.group(2).strip()
            dims = tuple(int(d) for d in dims_s.split(",")) if dims_s else ()
            shapes.append((m.group(1), dims))
        return shapes
    return [_parse_single_shape(tok)]


def shape_bytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> float:
    total = 0.0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES.get(dtype, 4)
    return total


def shape_elems(shapes: List[Tuple[str, Tuple[int, ...]]]) -> float:
    total = 0.0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HloInstruction:
    name: str
    opcode: str
    shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str  # raw remainder of the line after the operand list

    @property
    def out_bytes(self) -> float:
        return shape_bytes(self.shapes)

    @property
    def out_elems(self) -> float:
        return shape_elems(self.shapes)


@dataclasses.dataclass
class HloComputation:
    name: str
    instructions: List[HloInstruction]
    by_name: Dict[str, HloInstruction]


_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^\s*([a-z][\w\-]*)\s*\(")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")


def _split_shape_and_rest(rhs: str) -> Tuple[str, str]:
    """Split '<shape> opcode(...)...' into (shape_token, rest)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, c in enumerate(rhs):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1], rhs[i + 1:].strip()
        return rhs, ""
    m = re.match(r"^([a-z][a-z0-9]*\[[^\]]*\](?:\{[^}]*\})?)\s+(.*)$", rhs)
    if m:
        return m.group(1), m.group(2)
    # scalar like 'f32[]' handled above; fall back
    parts = rhs.split(None, 1)
    return parts[0], parts[1] if len(parts) > 1 else ""


def _extract_operands(rest: str) -> Tuple[str, List[str], str]:
    """From 'opcode(...), attrs' return (opcode, operand names, attrs)."""
    m = _OPCODE_RE.match(rest)
    if not m:
        return rest.split("(")[0].strip(), [], ""
    opcode = m.group(1)
    start = rest.index("(", m.start(1))
    depth = 0
    end = start
    for i in range(start, len(rest)):
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operand_str = rest[start + 1: end]
    attrs = rest[end + 1:]
    if opcode == "constant":
        return opcode, [], attrs
    operands = _OPERAND_NAME_RE.findall(operand_str)
    return opcode, operands, attrs


def parse_hlo_module(text: str) -> Tuple[Dict[str, HloComputation], Optional[str]]:
    computations: Dict[str, HloComputation] = {}
    entry: Optional[str] = None
    cur_name: Optional[str] = None
    cur_instrs: List[HloInstruction] = []
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if cur_name is None:
            mh = _COMP_HEADER_RE.match(line.strip())
            if mh:
                cur_name = mh.group(2)
                cur_instrs = []
                if mh.group(1):
                    entry = cur_name
            continue
        if line.strip() == "}":
            computations[cur_name] = HloComputation(
                cur_name, cur_instrs, {i.name: i for i in cur_instrs})
            cur_name = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        shape_tok, rest = _split_shape_and_rest(rhs)
        opcode, operands, attrs = _extract_operands(rest)
        cur_instrs.append(
            HloInstruction(name, opcode, parse_shapes(shape_tok), operands, attrs))
    return computations, entry


# ---------------------------------------------------------------------------
# Cost analysis with trip-count correction
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CostReport:
    """Per-device cost of one compiled program."""

    flops: float = 0.0                # MXU flops (dot/conv/fft-equivalent)
    vpu_ops: float = 0.0              # weighted elementwise lane-ops
    bytes_accessed: float = 0.0       # HBM traffic estimate
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, float] = dataclasses.field(default_factory=dict)
    op_mix: Dict[str, float] = dataclasses.field(default_factory=dict)
    op_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)
    rng_elems: float = 0.0
    sort_elems: float = 0.0
    fft_elems: float = 0.0
    gather_elems: float = 0.0
    reduce_elems: float = 0.0
    logic_elems: float = 0.0
    compare_elems: float = 0.0
    elementwise_elems: float = 0.0
    dot_bytes: float = 0.0
    #: subset of ``flops`` spent in exp-gated contractions — dots whose HLO
    #: neighborhood contains a softmax ``exponential`` (attention score/value
    #: products, selective-scan recurrences).  Always <= ``flops``.
    attention_flops: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_accessed, 1.0)

    def add(self, other: "CostReport", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.vpu_ops += other.vpu_ops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0.0) + v * mult
        for k, v in other.op_mix.items():
            self.op_mix[k] = self.op_mix.get(k, 0.0) + v * mult
        for k, v in other.op_bytes.items():
            self.op_bytes[k] = self.op_bytes.get(k, 0.0) + v * mult
        self.while_trip_counts.extend(other.while_trip_counts)
        for f in ("rng_elems", "sort_elems", "fft_elems", "gather_elems",
                  "reduce_elems", "logic_elems", "compare_elems",
                  "elementwise_elems", "dot_bytes", "attention_flops"):
            setattr(self, f, getattr(self, f) + getattr(other, f) * mult)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["total_collective_bytes"] = self.total_collective_bytes
        d["arithmetic_intensity"] = self.arithmetic_intensity
        return d


def classify_opcode(opcode: str) -> str:
    if opcode in ("dot",):
        return "dot"
    if opcode.startswith("convolution"):
        return "convolution"
    if opcode == "fft":
        return "fft"
    if opcode == "sort":
        return "sort"
    if opcode.startswith("rng"):
        return "rng"
    if opcode in _GATHER_SCATTER:
        return "gather_scatter"
    if opcode in _REDUCE:
        return "reduce"
    if opcode in _LOGIC:
        return "logic"
    if opcode in _COMPARE:
        return "compare_select"
    if opcode in _ELEMENTWISE:
        return "elementwise"
    if opcode in COLLECTIVE_OPS:
        return "collective"
    if opcode in _DATA_MOVEMENT:
        return "data_movement"
    return "other"


class HloCostAnalyzer:
    """Walks the HLO call graph, multiplying while-bodies by trip counts.

    ``vmem_bytes``: when > 0, tensors inside loop bodies whose per-iteration
    traffic fits this budget are treated as VMEM-resident (not HBM traffic).
    This models the TPU execution of blocked kernels — the Pallas
    flash-attention kernel keeps exactly these block temporaries (scores,
    running max/sum) in VMEM scratch; the CPU HLO materializes them.  The
    default (0) is the pessimistic un-fused bound.
    """

    def __init__(self, text: str, vmem_bytes: float = 0.0):
        self.computations, self.entry = parse_hlo_module(text)
        self.vmem_bytes = vmem_bytes
        self._memo: Dict[Tuple[str, bool, bool], CostReport] = {}

    # -- per-instruction costs ------------------------------------------------

    def _operand_shapes(self, comp: HloComputation, instr: HloInstruction,
                        idx: int) -> Optional[List[Tuple[str, Tuple[int, ...]]]]:
        if idx >= len(instr.operands):
            return None
        op = comp.by_name.get(instr.operands[idx])
        return op.shapes if op is not None else None

    def _io_bytes(self, comp: HloComputation, instr: HloInstruction) -> float:
        """HBM traffic of one instruction — touched bytes, not operand sizes.

        Slicing ops read only the slice; in-place updates (DUS/scatter with
        donated buffers) touch only the updated region.  Counting full
        operands would charge a 32k-entry KV cache for every decode step.
        """
        op = instr.opcode

        def operand_bytes(i):
            o = comp.by_name.get(instr.operands[i]) if i < len(instr.operands) else None
            return o.out_bytes if o is not None and o.opcode != "constant" else 0.0

        if op in ("dynamic-slice", "slice", "gather"):
            return 2.0 * instr.out_bytes               # read slice + write out
        if op == "dynamic-update-slice":
            return 3.0 * operand_bytes(1)              # r/w region + update
        if op == "scatter":
            return 3.0 * operand_bytes(2) if len(instr.operands) >= 3 \
                else 3.0 * instr.out_bytes
        total = instr.out_bytes
        for i in range(len(instr.operands)):
            total += operand_bytes(i)
        return total

    def _fusion_operand_bytes(self, comp: HloComputation,
                              instr: HloInstruction) -> float:
        """Bytes a fusion reads: parameters consumed only via slicing ops
        count the slice sizes; everything else counts the full operand."""
        mcall = _CALLS_RE.search(instr.attrs)
        body = self.computations.get(mcall.group(1)) if mcall else None
        total = instr.out_bytes
        if body is None:
            return total + sum(
                (comp.by_name[o].out_bytes
                 if o in comp.by_name and comp.by_name[o].opcode != "constant"
                 else 0.0) for o in instr.operands)
        # map param index -> its uses inside the body
        params = [bi for bi in body.instructions if bi.opcode == "parameter"]
        consumers: Dict[str, List[HloInstruction]] = {}
        for bi in body.instructions:
            for oname in bi.operands:
                consumers.setdefault(oname, []).append(bi)
        for pi, p in enumerate(params):
            if pi >= len(instr.operands):
                break
            o = comp.by_name.get(instr.operands[pi])
            full = o.out_bytes if o is not None and o.opcode != "constant" else 0.0
            uses = consumers.get(p.name, [])
            if uses and all(u.opcode in ("dynamic-slice", "slice", "gather",
                                         "dynamic-update-slice")
                            for u in uses):
                touched = 0.0
                for u in uses:
                    if u.opcode == "dynamic-update-slice":
                        upd = body.by_name.get(u.operands[1]) \
                            if len(u.operands) > 1 else None
                        touched += 2.0 * (upd.out_bytes if upd else 0.0)
                    else:
                        touched += u.out_bytes
                total += min(full, touched)
            else:
                total += full
        return total

    def _dot_flops(self, comp: HloComputation, instr: HloInstruction) -> float:
        out_elems = instr.out_elems
        lhs = self._operand_shapes(comp, instr, 0)
        m = re.search(r"lhs_contracting_dims=\{([\d,\s]*)\}", instr.attrs)
        contract = 1.0
        if lhs and m and m.group(1).strip():
            dims = lhs[0][1]
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(dims):
                    contract *= dims[di]
        return 2.0 * out_elems * contract

    #: softmax signature opcodes that mark a dot as attention-class
    _EXP_OPS = frozenset({"exponential", "exponential-minus-one"})
    #: traversal barriers: an exp on the far side of one of these is a
    #: different computational phase (FFT filters, another contraction, ...)
    _ATTN_BARRIERS = frozenset({"dot", "fft", "sort", "convolution"})

    def _comp_has_exp(self, name: str) -> bool:
        """Whether computation ``name`` (or a nested fusion body) contains a
        softmax exponential.  Memoized per computation."""
        memo = getattr(self, "_exp_memo", None)
        if memo is None:
            memo = self._exp_memo = {}
        if name in memo:
            return memo[name]
        memo[name] = False            # cycle guard
        comp = self.computations.get(name)
        found = False
        if comp is not None:
            for instr in comp.instructions:
                if instr.opcode in self._EXP_OPS:
                    found = True
                    break
                if instr.opcode == "fusion":
                    m = _CALLS_RE.search(instr.attrs)
                    if m and self._comp_has_exp(m.group(1)):
                        found = True
                        break
        memo[name] = found
        return found

    def _users(self, comp: HloComputation) -> Dict[str, List[HloInstruction]]:
        """operand name -> consuming instructions, built once per computation."""
        memo = getattr(self, "_users_memo", None)
        if memo is None:
            memo = self._users_memo = {}
        if comp.name not in memo:
            users: Dict[str, List[HloInstruction]] = {}
            for instr in comp.instructions:
                for o in instr.operands:
                    users.setdefault(o, []).append(instr)
            memo[comp.name] = users
        return memo[comp.name]

    def _dot_is_attention(self, comp: HloComputation, instr: HloInstruction,
                          depth: int = 4) -> bool:
        """True when a softmax ``exponential`` sits in the dot's local HLO
        neighborhood (producers *and* consumers, looking one level into
        fusion bodies): the QK^T score product feeds the softmax, the PV
        product consumes it.  Traversal stops at ``_ATTN_BARRIERS`` so e.g.
        SIFT's exp-shaped FFT filters do not taint its projection GEMM.
        """
        users = self._users(comp)
        seen = {instr.name}
        frontier = [instr]
        for _ in range(depth):
            nxt: List[HloInstruction] = []
            for cur in frontier:
                neighbors = [comp.by_name.get(o) for o in cur.operands]
                neighbors += users.get(cur.name, [])
                for n in neighbors:
                    if n is None or n.name in seen:
                        continue
                    seen.add(n.name)
                    if n.opcode in self._EXP_OPS:
                        return True
                    if n.opcode == "fusion":
                        m = _CALLS_RE.search(n.attrs)
                        if m and self._comp_has_exp(m.group(1)):
                            return True
                        nxt.append(n)
                        continue
                    if n is not instr and n.opcode in self._ATTN_BARRIERS:
                        continue
                    nxt.append(n)
            frontier = nxt
            if not frontier:
                break
        return False

    def _conv_flops(self, comp: HloComputation, instr: HloInstruction) -> float:
        out_elems = instr.out_elems
        m = re.search(r"window=\{size=([\dx]+)", instr.attrs)
        win = 1.0
        if m:
            for d in m.group(1).split("x"):
                win *= int(d)
        # depthwise-vs-dense distinction via feature_group_count
        rhs = self._operand_shapes(comp, instr, 1)
        in_feat = rhs[0][1][-2] if rhs and len(rhs[0][1]) >= 2 else 1
        return 2.0 * out_elems * win * max(in_feat, 1)

    def _fft_flops(self, instr: HloInstruction) -> float:
        m = re.search(r"fft_length=\{([\d,\s]+)\}", instr.attrs)
        n = 1.0
        if m:
            for d in m.group(1).split(","):
                n *= int(d)
        batch = max(instr.out_elems / max(n, 1.0), 1.0)
        return 5.0 * batch * n * max(math.log2(max(n, 2.0)), 1.0)

    # -- computation walk -----------------------------------------------------

    def _trip_count(self, instr: HloInstruction) -> int:
        m = _TRIP_RE.search(instr.attrs)
        if m:
            return int(m.group(1))
        mc = _COND_RE.search(instr.attrs)
        if mc and mc.group(1) in self.computations:
            consts = []
            for ci in self.computations[mc.group(1)].instructions:
                if ci.opcode == "constant":
                    mm = _CONST_INT_RE.search(ci.attrs) or _CONST_INT_RE.search(
                        "constant(" + ci.attrs + ")")
                    if mm:
                        consts.append(int(mm.group(1)))
            if consts:
                return max(consts)
        return 1

    def analyze_computation(self, name: str, count_bytes: bool = True,
                            in_loop: bool = False) -> CostReport:
        key = (name, count_bytes, in_loop)
        if key in self._memo:
            return self._memo[key]
        report = CostReport()
        comp = self.computations.get(name)
        if comp is None:
            self._memo[key] = report
            return report
        for instr in comp.instructions:
            op = instr.opcode
            if op in _SKIP_OPS:
                continue
            cls = classify_opcode(op)
            if op not in ("while", "call", "conditional", "async-start", "fusion"):
                report.op_mix[cls] = report.op_mix.get(cls, 0.0) + 1.0
            operand_bytes = 0.0
            for oname in instr.operands:
                o = comp.by_name.get(oname)
                if o is not None and o.opcode != "constant":
                    operand_bytes += o.out_bytes
            io_bytes = self._io_bytes(comp, instr)

            if op == "while":
                trips = self._trip_count(instr)
                report.while_trip_counts.append(trips)
                mb = _CALLS_RE.search(instr.attrs)
                if mb:
                    body = self.analyze_computation(mb.group(1), count_bytes,
                                                    in_loop=True)
                    report.add(body, float(trips))
                mc = _COND_RE.search(instr.attrs)
                if mc:
                    cond = self.analyze_computation(mc.group(1), count_bytes,
                                                    in_loop=True)
                    report.add(cond, float(trips))
                continue
            if op == "fusion":
                # memory traffic at the fusion boundary only; flops from body
                if count_bytes:
                    fb = self._fusion_operand_bytes(comp, instr)
                    if not (in_loop and self.vmem_bytes > 0
                            and fb <= self.vmem_bytes):
                        report.bytes_accessed += fb
                        report.op_bytes[cls] = report.op_bytes.get(cls, 0.0) + fb
                mcall = _CALLS_RE.search(instr.attrs)
                if mcall:
                    body = self.analyze_computation(mcall.group(1), count_bytes=False)
                    report.add(body, 1.0)
                continue
            if op in ("call", "conditional", "async-start"):
                mcall = _CALLS_RE.search(instr.attrs)
                if mcall:
                    body = self.analyze_computation(mcall.group(1), count_bytes,
                                                    in_loop=in_loop)
                    report.add(body, 1.0)
                continue

            if count_bytes and not (in_loop and self.vmem_bytes > 0
                                    and op not in COLLECTIVE_OPS
                                    and io_bytes <= self.vmem_bytes):
                report.bytes_accessed += io_bytes
                report.op_bytes[cls] = report.op_bytes.get(cls, 0.0) + io_bytes

            if op in COLLECTIVE_OPS:
                canon = _COLLECTIVE_CANON.get(op, op)
                report.collective_bytes[canon] = (
                    report.collective_bytes.get(canon, 0.0) + operand_bytes)
                report.collective_count[canon] = (
                    report.collective_count.get(canon, 0.0) + 1.0)
            elif op == "dot":
                f = self._dot_flops(comp, instr)
                report.flops += f
                report.dot_bytes += io_bytes
                if self._dot_is_attention(comp, instr):
                    report.attention_flops += f
            elif op.startswith("convolution"):
                report.flops += self._conv_flops(comp, instr)
            elif op == "fft":
                f = self._fft_flops(instr)
                report.flops += f
                report.fft_elems += instr.out_elems
            elif op == "sort":
                n = instr.out_elems
                report.sort_elems += n
                report.vpu_ops += n * max(math.log2(max(n, 2.0)), 1.0)
            elif op.startswith("rng"):
                report.rng_elems += instr.out_elems
                report.vpu_ops += instr.out_elems * 4
            elif cls == "gather_scatter":
                report.gather_elems += instr.out_elems
            elif cls == "reduce":
                report.reduce_elems += operand_bytes / 4.0
                report.vpu_ops += operand_bytes / 4.0
            elif cls == "logic":
                report.logic_elems += instr.out_elems
                report.vpu_ops += instr.out_elems
            elif cls == "compare_select":
                report.compare_elems += instr.out_elems
                report.vpu_ops += instr.out_elems
            elif cls == "elementwise":
                w = 8.0 if op in _TRANSCENDENTAL else 1.0
                report.elementwise_elems += instr.out_elems
                report.vpu_ops += instr.out_elems * w
            # reduce's to_apply bodies are per-element lambdas; already counted
        self._memo[key] = report
        return report

    def analyze(self) -> CostReport:
        if self.entry is None:
            # fall back: largest computation
            if not self.computations:
                return CostReport()
            self.entry = max(self.computations.values(),
                             key=lambda c: len(c.instructions)).name
        return self.analyze_computation(self.entry)


def analyze_hlo_text(text: str, vmem_bytes: float = 0.0) -> CostReport:
    return HloCostAnalyzer(text, vmem_bytes=vmem_bytes).analyze()


# ---------------------------------------------------------------------------
# Metric vector + Equation (1) accuracy
# ---------------------------------------------------------------------------

METRIC_KEYS = (
    "flops", "vpu_ops", "bytes_accessed", "arithmetic_intensity",
    "mix_dot", "mix_attention", "mix_elementwise", "mix_reduce",
    "mix_gather_scatter", "mix_sort", "mix_fft", "mix_rng", "mix_logic",
    "mix_compare_select", "collective_bytes", "host_bytes",
)


def elem_channels(report: CostReport) -> Dict[str, float]:
    """Dynamic 'instruction count' per op class = element-ops executed.

    This is the analog of the paper's instruction-mix breakdown (Fig. 6):
    the *fraction of executed work* per instruction class, not the static
    HLO op count (a 1-element add and a 4M-element dot are not one each).
    """
    return {
        "dot": max(report.flops - report.attention_flops, 0.0) / 2.0,
        "attention": report.attention_flops / 2.0,
        "elementwise": report.elementwise_elems,
        "reduce": report.reduce_elems,
        "gather_scatter": report.gather_elems,
        "sort": report.sort_elems,
        "fft": report.fft_elems,
        "rng": report.rng_elems,
        "logic": report.logic_elems,
        "compare_select": report.compare_elems,
    }


def metric_vector(report: CostReport, host_bytes: float = 0.0,
                  exec_time: float = 0.0) -> Dict[str, float]:
    """The TPU-native analog of the paper's Table-5 metric vector.

    Size-independent metrics (ratios + rates) are what proxy accuracy is
    judged on — exactly like the paper's IPC / MIPS / mix% / MB/s — since a
    proxy is ~100x smaller than the original by design.  Absolute totals
    (flops, bytes) are kept for roofline work but are not accuracy metrics.
    """
    channels = elem_channels(report)
    total = sum(channels.values()) or 1.0
    vec = {
        # --- absolute totals (roofline / debugging; not accuracy metrics)
        "flops": report.flops,
        "vpu_ops": report.vpu_ops,
        "bytes_accessed": report.bytes_accessed,
        "collective_bytes": report.total_collective_bytes,
        "host_bytes": host_bytes,
        # --- ratios (cache-behaviour analogs)
        "arithmetic_intensity": report.arithmetic_intensity,
        "vpu_share": report.vpu_ops / max(report.vpu_ops + report.flops, 1.0),
        "coll_share": report.total_collective_bytes / max(report.bytes_accessed, 1.0),
    }
    for cls, v in channels.items():
        vec[f"mix_{cls}"] = v / total
    if exec_time:
        # --- rates (IPC/MIPS/bandwidth analogs; need real execution)
        vec["exec_time"] = exec_time
        vec["mips"] = total / exec_time                   # elem-ops / s
        vec["flop_rate"] = report.flops / exec_time       # FLOP / s
        vec["mem_bw"] = report.bytes_accessed / exec_time  # B / s
        if host_bytes:
            vec["io_bw"] = host_bytes / exec_time         # disk-I/O analog
    return vec


#: size-independent keys used for proxy-accuracy reporting (Fig. 5 analog)
REPORT_METRICS = (
    "arithmetic_intensity", "vpu_share",
    "mix_dot", "mix_attention", "mix_elementwise", "mix_reduce",
    "mix_gather_scatter", "mix_sort", "mix_fft", "mix_rng", "mix_logic",
    "mix_compare_select", "mips", "flop_rate", "mem_bw",
)


def eq1_accuracy(val_h: float, val_p: float) -> float:
    """Equation (1) of the paper: 1 - |(p - h) / h|, clipped to [0, 1]."""
    if abs(val_h) < 1e-12:
        return 1.0 if abs(val_p) < 1e-12 else 0.0
    return float(max(0.0, 1.0 - abs((val_p - val_h) / val_h)))


def metric_accuracy(key: str, val_h: float, val_p: float) -> float:
    """Eq.1 for magnitude metrics; share-point accuracy for mix_* metrics.

    The paper reads its instruction-mix figure (Fig. 6) in percentage
    points ("44% vs 46% integer instructions"), so mix metrics compare as
    1 - |share_p - share_h| rather than relatively — a relative error on a
    0.1% share would be meaningless noise.
    """
    if key.startswith("mix_") or key in ("vpu_share", "coll_share"):
        return float(max(0.0, 1.0 - abs(val_p - val_h)))
    return eq1_accuracy(val_h, val_p)


def vector_accuracy(target: Dict[str, float], proxy: Dict[str, float],
                    keys: Optional[List[str]] = None,
                    weights: Optional[Dict[str, float]] = None) -> Dict[str, float]:
    """Per-metric accuracy + weighted average ('avg')."""
    if keys is None:
        keys = [k for k in target
                if k in proxy and not (abs(target[k]) < 1e-12 and abs(proxy[k]) < 1e-12)]
    accs = {}
    wsum, asum = 0.0, 0.0
    for k in keys:
        if k not in target or k not in proxy:
            continue
        a = metric_accuracy(k, target[k], proxy[k])
        accs[k] = a
        w = (weights or {}).get(k, 1.0)
        wsum += w
        asum += a * w
    accs["avg"] = asum / max(wsum, 1e-12)
    return accs


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one (arch x shape x mesh) cell (per-chip)."""

    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    hlo_flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float = 0.0          # analytic global (6ND etc.)
    chips: int = 1
    useful_flops_ratio: float = 0.0   # model_flops / (hlo_flops * chips)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: bounded below by the dominant term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """dominant-term share: compute_s / step_time — 1.0 = compute-bound at peak."""
        return self.compute_s / max(self.step_time_s, 1e-30)

    @property
    def mfu(self) -> float:
        """Model-flops utilisation at the no-overlap bound."""
        if self.model_flops <= 0:
            return 0.0
        return (self.model_flops / (self.chips * HW_V5E.peak_flops_bf16)) / max(
            self.step_time_s, 1e-30)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["step_time_s"] = self.step_time_s
        d["roofline_fraction"] = self.roofline_fraction
        d["mfu"] = self.mfu
        return d


def roofline_from_report(report: CostReport, chips: int,
                         model_flops: float = 0.0,
                         hw: HardwareSpec = HW_V5E) -> Roofline:
    compute_s = report.flops / hw.peak_flops_bf16
    memory_s = report.bytes_accessed / hw.hbm_bw
    collective_s = report.total_collective_bytes / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / max(report.flops * chips, 1.0) if model_flops else 0.0
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, hlo_flops_per_chip=report.flops,
        bytes_per_chip=report.bytes_accessed,
        collective_bytes_per_chip=report.total_collective_bytes,
        model_flops=model_flops, chips=chips, useful_flops_ratio=useful)
