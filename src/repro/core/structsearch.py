"""Structural population search over the Fig.-3 DAG design space.

The paper defines a proxy benchmark as a *DAG-like combination of dwarf
components with different weights* (Fig. 3) — but the population tuner
(:class:`~repro.core.autotune.PopulationTuner`) searches only the weights
and dynamic params under one frozen structure.  Gao et al. (Data Dwarfs,
2018) and Jia et al. (Characterizing and Subsetting, 2014) both show the
*composition* of the units of computation, not just their intensities, is
what discriminates workloads — so this module treats the DAG itself as the
search variable:

* **Mutation primitives** (:mod:`repro.core.dag`): edge insertion
  (splicing into a chain, or accumulating into a join node), edge
  removal with consumer bypass, component swaps, and split/merge of
  same-component chains.  Every primitive preserves the structural
  invariants (`validate_structure`): topologically ordered, acyclic,
  every edge connected to the sink.
* **Cheap structural scoring** (:class:`~repro.core.engine.StructureScorer`):
  candidate structures score through the compositional cost model —
  per-edge body reports are cached by component structure key, and a
  mutated child scores as a *delta* from its parent's cached vector, so
  most mutated structures score with **zero new traces or compiles**.
* **Inner weight loop**: only the surviving elite structures earn a
  :class:`~repro.core.autotune.PopulationTuner` run over their dynamic
  leaves; a single total candidate budget is split between the two loops
  (:func:`~repro.core.autotune.split_budget`).

The search is deterministic for a fixed seed: mutation proposals replay
from ``np.random.RandomState``, structures deduplicate on
``canonical_structure_key`` (stable under node relabeling), and scoring is
pure arithmetic over cached HLO reports.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .autotune import (DEFAULT_METRICS, DEFAULT_STRUCTURE_BUDGET_FRAC,
                       DEFAULT_WEIGHTS, PopulationTuner, _deviations,
                       coerce_target, split_budget)
from .dag import (Edge, ProxyDAG, StructureError, _neighbor_params,
                  insert_accumulating_edge, insert_edge, merge_chain,
                  remove_edge, split_edge, swap_component)
from .engine import (_BASIS_FIELDS, StructureScorer, _body_report,
                     _report_to_vec)
from .metrics import vector_accuracy
from .proxy import ProxyBenchmark

# ---------------------------------------------------------------------------
# guided component choice (impact analysis over the channel basis)
# ---------------------------------------------------------------------------

#: mix metric -> flat-basis channel field (mirrors ``metrics.elem_channels``)
_MIX_CHANNEL: Dict[str, str] = {
    "mix_dot": "flops",
    "mix_attention": "attention_flops",
    "mix_elementwise": "elementwise_elems",
    "mix_reduce": "reduce_elems",
    "mix_gather_scatter": "gather_elems",
    "mix_sort": "sort_elems",
    "mix_fft": "fft_elems",
    "mix_rng": "rng_elems",
    "mix_logic": "logic_elems",
    "mix_compare_select": "compare_elems",
}

_CHANNEL_IDX = {f: i for i, f in enumerate(_BASIS_FIELDS)}
_ELEM_FIELDS = ("elementwise_elems", "reduce_elems", "gather_elems",
                "sort_elems", "fft_elems", "rng_elems", "logic_elems",
                "compare_elems")


def deficit_channel(target: Dict[str, float], metrics: Dict[str, float],
                    keys: Sequence[str], margin: float = 0.02
                    ) -> Optional[str]:
    """The basis channel the proxy most under-supplies vs the target (in
    mix share points), or ``None`` when every mix share is close — the
    guidance signal for mutation proposals: a missing channel can only be
    created by *structure*, never by re-weighting edges that lack it."""
    best, gap = None, margin
    for k in keys:
        field = _MIX_CHANNEL.get(k)
        if field is None:
            continue
        g = target.get(k, 0.0) - metrics.get(k, 0.0)
        if g > gap:
            best, gap = field, g
    return best


def _channel_share(vec: np.ndarray, field: str) -> float:
    """Share of a body vector's element-op work on ``field`` (dot counts
    as flops/2, matching ``metrics.elem_channels``; ``flops`` is the
    non-attention dot channel, ``attention_flops`` the attention one)."""
    attn = float(vec[_CHANNEL_IDX["attention_flops"]])

    def chan(f: str) -> float:
        v = float(vec[_CHANNEL_IDX[f]])
        if f == "flops":
            return max(v - attn, 0.0) / 2.0
        if f == "attention_flops":
            return v / 2.0
        return v
    total = chan("flops") + chan("attention_flops") \
        + sum(chan(f) for f in _ELEM_FIELDS)
    return chan(field) / max(total, 1.0)


def _component_channel_share(component: str, site: Edge,
                             field: str) -> float:
    """How strongly one repeat of ``component`` (at the mutation site's
    shape params) feeds ``field`` — from the engine's cached body reports,
    so repeated guidance queries compile nothing new.  A failing probe
    propagates: the pool is validated against the registry up front
    (:func:`validate_components`), so an error here is a real analysis
    bug, not a bad component name to paper over."""
    probe = Edge(component, ["x"], "y", _neighbor_params(site, component, 1))
    return _channel_share(_report_to_vec(_body_report(probe)), field)


def validate_components(components: Sequence[str]) -> List[str]:
    """Resolve every pool name against the dwarf registry (``KeyError``
    with the known names on a typo) — a silent bad name would otherwise
    only surface as guidance quietly degrading to uniform draws."""
    from .dwarfs import get_component
    for c in components:
        get_component(c)
    return list(components)


# ---------------------------------------------------------------------------
# mutation proposals
# ---------------------------------------------------------------------------

#: proposal kinds and their draw probabilities (insertions lead: they are
#: the only moves that can create a missing channel)
MUTATION_KINDS: Tuple[Tuple[str, float], ...] = (
    ("insert", 0.30),
    ("swap", 0.25),
    ("insert_accumulate", 0.15),
    ("remove", 0.15),
    ("split", 0.10),
    ("merge", 0.05),
)

#: probability that an insert/swap follows the deficit-channel guidance
#: instead of drawing its component uniformly
GUIDED_FRAC = 0.5


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One applied structure mutation, with the edit set delta scoring
    needs: ``removed`` are parent edges the move dropped, ``added`` the
    child edges it introduced (rewired-only edges appear in neither —
    node renames do not change body cost)."""

    kind: str
    detail: str
    removed: Tuple[Edge, ...] = ()
    added: Tuple[Edge, ...] = ()


def _draw_kind(rs: np.random.RandomState) -> str:
    r = rs.rand()
    acc = 0.0
    for kind, p in MUTATION_KINDS:
        acc += p
        if r < acc:
            return kind
    return MUTATION_KINDS[0][0]


def _choose_component(site: Edge, rs: np.random.RandomState,
                      components: Sequence[str],
                      bias: Optional[str]) -> Optional[str]:
    pool = [c for c in components if c != site.component]
    if not pool:
        return None
    if bias is not None and rs.rand() < GUIDED_FRAC:
        shares = [(c, _component_channel_share(c, site, bias)) for c in pool]
        best = max(shares, key=lambda cs: cs[1])
        if best[1] > 0.0:
            return best[0]
    return pool[rs.randint(len(pool))]


def propose_mutation(dag: ProxyDAG, rs: np.random.RandomState,
                     components: Sequence[str],
                     bias: Optional[str] = None,
                     max_tries: int = 8
                     ) -> Optional[Tuple[ProxyDAG, Mutation]]:
    """Draw one valid structure mutation of ``dag``, or ``None`` when
    ``max_tries`` draws found no legal site.  Deterministic in ``rs``."""
    validate_components(components)
    n = len(dag.edges)
    for _ in range(max_tries):
        kind = _draw_kind(rs)
        try:
            if kind == "insert":
                sites = [i for i, e in enumerate(dag.edges)
                         if len(e.src) == 1]
                if not sites:
                    continue
                i = sites[rs.randint(len(sites))]
                comp = _choose_component(dag.edges[i], rs, components, bias)
                if comp is None:
                    continue
                w = 1 + rs.randint(4)
                child = insert_edge(dag, i, comp, weight=w)
                return child, Mutation(
                    "insert", f"insert {comp}(w={w}) before e{i}",
                    added=(child.edges[i],))
            if kind == "insert_accumulate":
                i = rs.randint(n)
                defined = sorted(set(dag.sources)
                                 | {e.dst for e in dag.edges[: i + 1]})
                src = defined[rs.randint(len(defined))]
                comp = _choose_component(dag.edges[i], rs, components, bias)
                if comp is None:
                    continue
                child = insert_accumulating_edge(dag, src, i, comp, weight=1)
                return child, Mutation(
                    "insert_accumulate",
                    f"accumulate {comp}({src}) into e{i}.dst",
                    added=(child.edges[i + 1],))
            if kind == "swap":
                i = rs.randint(n)
                comp = _choose_component(dag.edges[i], rs, components, bias)
                if comp is None:
                    continue
                child = swap_component(dag, i, comp)
                return child, Mutation(
                    "swap", f"swap e{i}:{dag.edges[i].component}->{comp}",
                    removed=(dag.edges[i],), added=(child.edges[i],))
            if kind == "remove":
                i = rs.randint(n)
                child = remove_edge(dag, i)
                return child, Mutation(
                    "remove", f"remove e{i}:{dag.edges[i].component}",
                    removed=(dag.edges[i],))
            if kind == "split":
                sites = [i for i, e in enumerate(dag.edges)
                         if e.params.rounded().weight >= 2]
                if not sites:
                    continue
                i = sites[rs.randint(len(sites))]
                w = dag.edges[i].params.rounded().weight
                w1 = 1 + rs.randint(w - 1)
                child = split_edge(dag, i, w1)
                return child, Mutation(
                    "split", f"split e{i}:{dag.edges[i].component} at {w1}",
                    removed=(dag.edges[i],),
                    added=(child.edges[i], child.edges[i + 1]))
            if kind == "merge":
                i = rs.randint(max(n - 1, 1))
                child = merge_chain(dag, i)
                return child, Mutation(
                    "merge", f"merge e{i}+e{i + 1}:{dag.edges[i].component}",
                    removed=(dag.edges[i], dag.edges[i + 1]),
                    added=(child.edges[i],))
        except StructureError:
            continue
    return None


# ---------------------------------------------------------------------------
# the structural tuner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StructureCandidate:
    """One structure in the outer population."""

    dag: ProxyDAG
    metrics: Dict[str, float]
    accuracy: float
    worst_dev: float
    lineage: str                  # mutation trail from its seed structure
    tuned: bool = False           # has the inner weight loop run on it?


@dataclasses.dataclass
class StructureGeneration:
    """One outer-loop generation's summary."""

    index: int
    proposed: int                 # mutation draws attempted
    scored: int                   # new (deduped, valid) structures scored
    tuned_elites: int             # elites the inner weight loop ran on
    best_accuracy: float
    best_deviation: float
    best_lineage: str
    structure_candidates: int     # cumulative structure-budget spend
    weight_candidates: int        # cumulative inner-loop spend


@dataclasses.dataclass
class StructuralTuneResult:
    proxy: ProxyBenchmark
    converged: bool
    generations: int
    structures_scored: int        # distinct structures scored (outer spend)
    weight_candidates: int        # inner PopulationTuner spend
    candidates_evaluated: int     # total = structures + weight candidates
    initial_accuracy: Dict[str, float]
    final_accuracy: Dict[str, float]
    final_deviation: float
    best_lineage: str
    new_body_compiles: int        # HLO analyses the search itself triggered
    history: List[StructureGeneration]

    def summary(self) -> str:
        rows = [f"structural_tune[{self.proxy.name}]: "
                f"converged={self.converged} gens={self.generations} "
                f"structures={self.structures_scored} "
                f"weight_candidates={self.weight_candidates} "
                f"avg_acc {self.initial_accuracy.get('avg', 0):.3f} -> "
                f"{self.final_accuracy.get('avg', 0):.3f} "
                f"worst_dev {self.final_deviation:+.3f} "
                f"via [{self.best_lineage}]"]
        for g in self.history:
            rows.append(
                f"  gen{g.index:02d} scored={g.scored}/{g.proposed} "
                f"tuned={g.tuned_elites} best_acc={g.best_accuracy:.3f} "
                f"worst_dev={g.best_deviation:+.3f} [{g.best_lineage}]")
        return "\n".join(rows)


class StructuralTuner:
    """Evolutionary search over DAG *structures*, wrapping
    :class:`~repro.core.autotune.PopulationTuner` as the inner weight/param
    loop — together they tune the full Fig.-3 design space.

    Each outer generation mutates the elite structures
    (``mutations_per_parent`` proposals each, guided toward the target's
    most under-supplied mix channel), deduplicates on the canonical
    structure key, scores survivors through the compositional
    :class:`~repro.core.engine.StructureScorer` (delta scoring — zero
    compiles when every component/shape was already profiled), and then
    spends a slice of the weight budget running the population tuner on
    the top ``elites`` structures.  ``max_candidates`` bounds the *total*
    spend: ``structure_budget_frac`` of it funds structure scoring, the
    rest the inner weight generations — the knob that makes a fair fight
    against a weight-only tuner under the same budget.
    """

    def __init__(self, target_metrics: Dict[str, float],
                 metric_keys: Sequence[str] = DEFAULT_METRICS,
                 tol: float = 0.15,
                 structure_population: int = 8,
                 generations: int = 4,
                 mutations_per_parent: int = 4,
                 elites: int = 2,
                 max_candidates: int = 256,
                 structure_budget_frac: float = DEFAULT_STRUCTURE_BUDGET_FRAC,
                 components: Optional[Sequence[str]] = None,
                 seed_structures: Optional[Sequence[ProxyDAG]] = None,
                 inner_population: int = 8,
                 execute: bool = False,
                 stack: str = "openmp",
                 seed: int = 0,
                 weights: Optional[Dict[str, float]] = None):
        target_metrics = coerce_target(target_metrics)
        self.target = target_metrics
        self.keys = [k for k in metric_keys
                     if abs(target_metrics.get(k, 0.0)) > 1e-12]
        self.tol = tol
        self.structure_population = max(2, int(structure_population))
        self.generations = max(1, int(generations))
        self.mutations_per_parent = max(1, int(mutations_per_parent))
        self.elites = max(1, int(elites))
        self.max_candidates = max(2, int(max_candidates))
        self.structure_budget, self.weight_budget = split_budget(
            self.max_candidates, structure_budget_frac)
        # the input structure itself is always scored
        self.structure_budget = max(1, self.structure_budget)
        self.components = (None if components is None
                           else sorted(components))
        self.seed_structures = list(seed_structures or [])
        self.inner_population = max(2, int(inner_population))
        self.execute = execute
        self.stack = stack
        self.seed = seed
        self.weights = dict(DEFAULT_WEIGHTS) if weights is None else weights
        self.structures_scored = 0
        self.weight_candidates = 0

    # -- scoring --------------------------------------------------------------

    def _accuracy(self, metrics: Dict[str, float]) -> float:
        return vector_accuracy(self.target, metrics, self.keys,
                               self.weights)["avg"]

    def _worst_dev(self, metrics: Dict[str, float]) -> float:
        devs = _deviations(self.target, metrics, self.keys)
        return max((abs(d) for d in devs.values()), default=math.inf)

    def _candidate(self, dag: ProxyDAG, metrics: Dict[str, float],
                   lineage: str) -> StructureCandidate:
        return StructureCandidate(dag, metrics, self._accuracy(metrics),
                                  self._worst_dev(metrics), lineage)

    # -- inner weight loop ----------------------------------------------------

    def _weight_slice(self) -> int:
        """Per-elite inner-loop budget: the weight share spread evenly
        over every (generation, elite) slot."""
        slots = self.generations * self.elites
        return self.weight_budget // max(slots, 1)

    def _tune_weights(self, scorer: StructureScorer,
                      cand: StructureCandidate, gen: int) -> None:
        # the total-spend clamp keeps max_candidates a hard bound even
        # when __init__ bumped structure_budget to cover the mandatory
        # input-structure score
        budget = min(self._weight_slice(),
                     self.weight_budget - self.weight_candidates,
                     self.max_candidates - self.structures_scored
                     - self.weight_candidates)
        if budget < self.inner_population:
            return
        inner = PopulationTuner(
            self.target, metric_keys=self.keys, tol=self.tol,
            population=self.inner_population,
            generations=max(1, budget // self.inner_population),
            max_candidates=budget, seed=self.seed + 7919 * gen,
            stack=self.stack, execute=self.execute, weights=self.weights)
        res = inner.tune(ProxyBenchmark(cand.dag))
        self.weight_candidates += res.candidates_evaluated
        cand.dag = res.proxy.dag
        cand.metrics = scorer.score(cand.dag)
        cand.accuracy = self._accuracy(cand.metrics)
        cand.worst_dev = self._worst_dev(cand.metrics)
        cand.tuned = True

    # -- main loop ------------------------------------------------------------

    def tune(self, proxy: ProxyBenchmark) -> StructuralTuneResult:
        proxy = proxy.clone()
        self.structures_scored = 0
        self.weight_candidates = 0
        scorer = StructureScorer()
        components = self.components
        if components is None:
            components = sorted({e.component for e in proxy.dag.edges}
                                | {e.component for d in self.seed_structures
                                   for e in d.edges})
        validate_components(components)

        seen = set()
        pool: List[StructureCandidate] = []
        for i, dag in enumerate([proxy.dag] + self.seed_structures):
            key = dag.canonical_structure_key()
            if key in seen or (pool and self.structures_scored
                               >= self.structure_budget):
                continue
            seen.add(key)
            dag.validate_structure()
            metrics = scorer.score(dag)
            self.structures_scored += 1
            pool.append(self._candidate(
                dag, metrics, "start" if i == 0 else f"seed{i}"))
        init_acc = vector_accuracy(self.target, pool[0].metrics, self.keys,
                                   self.weights)
        best = max(pool, key=lambda c: c.accuracy)
        history: List[StructureGeneration] = []
        for gen in range(1, self.generations + 1):
            if best.worst_dev <= self.tol:
                break
            rs = np.random.RandomState(self.seed + 104729 * gen)
            bias = deficit_channel(self.target, best.metrics, self.keys)
            parents = sorted(pool, key=lambda c: -c.accuracy)[: self.elites]
            proposed = scored = 0
            fresh: List[StructureCandidate] = []
            for parent in parents:
                for _ in range(self.mutations_per_parent):
                    if self.structures_scored >= self.structure_budget:
                        break
                    got = propose_mutation(parent.dag, rs, components, bias)
                    proposed += 1
                    if got is None:
                        continue
                    child, mut = got
                    key = child.canonical_structure_key()
                    if key in seen:
                        continue
                    seen.add(key)
                    metrics = scorer.score_child(parent.dag, child,
                                                 mut.removed, mut.added)
                    self.structures_scored += 1
                    scored += 1
                    fresh.append(self._candidate(
                        child, metrics,
                        (mut.detail if parent.lineage == "start"
                         else f"{parent.lineage}; {mut.detail}")))
            pool = sorted(pool + fresh,
                          key=lambda c: -c.accuracy)[: self.structure_population]
            tuned = 0
            for cand in pool[: self.elites]:
                if cand.tuned:
                    # an elite that already ran its inner loop keeps its
                    # tuned weights; the slice stays banked for elites
                    # that newly survived into the front
                    continue
                before = self.weight_candidates
                self._tune_weights(scorer, cand, gen)
                tuned += int(self.weight_candidates > before)
            pool.sort(key=lambda c: -c.accuracy)
            if pool[0].accuracy > best.accuracy:
                best = pool[0]
            history.append(StructureGeneration(
                index=gen, proposed=proposed, scored=scored,
                tuned_elites=tuned, best_accuracy=best.accuracy,
                best_deviation=best.worst_dev, best_lineage=best.lineage,
                structure_candidates=self.structures_scored,
                weight_candidates=self.weight_candidates))
        final = ProxyBenchmark(best.dag, description=proxy.description)
        final_acc = vector_accuracy(self.target, best.metrics, self.keys,
                                    self.weights)
        return StructuralTuneResult(
            proxy=final,
            converged=best.worst_dev <= self.tol,
            generations=len(history),
            structures_scored=self.structures_scored,
            weight_candidates=self.weight_candidates,
            candidates_evaluated=(self.structures_scored
                                  + self.weight_candidates),
            initial_accuracy=init_acc,
            final_accuracy=final_acc,
            final_deviation=best.worst_dev,
            best_lineage=best.lineage,
            new_body_compiles=scorer.new_compiles,
            history=history)


def structural_tune(proxy: ProxyBenchmark, target_metrics: Dict[str, float],
                    **kw) -> StructuralTuneResult:
    return StructuralTuner(target_metrics, **kw).tune(proxy)


# ---------------------------------------------------------------------------
# canonical fidelity harness (shared by the tier-1 tests and the CI gate)
# ---------------------------------------------------------------------------


def structural_fidelity_harness(size: int = 16384, chunk: int = 256
                                ) -> Tuple[ProxyDAG, ProxyDAG, List[str]]:
    """``(reference, detuned, component_pool)`` for the structure-only
    fidelity contract: the reference pipeline carries an fft stage the
    detuned structure lacks *entirely* (not weight-0 — absent), so no
    re-weighting of the detuned edges can create the missing transform
    channel.  A weight-only tuner must saturate on this target; the
    structural tuner must insert the edge and converge.  One definition,
    imported by both ``tests/test_fidelity.py`` and the
    ``structure_sweep`` CI gate in ``benchmarks/compile_vs_run.py`` — so
    the test and the gate can never drift apart silently."""
    from .dwarfs import ComponentParams

    def _e(comp, src, dst, weight=1):
        return Edge(comp, src, dst,
                    ComponentParams(data_size=size, chunk_size=chunk,
                                    weight=weight))

    reference = ProxyDAG(
        "fft_ref", {"records": size},
        [_e("interval_sampling", ["records"], "sampled"),
         _e("fft", ["sampled"], "freq", 2),
         _e("quick_sort", ["freq"], "sorted", 4),
         _e("merge_sort", ["sorted"], "merged", 2)], "merged")
    detuned = ProxyDAG(
        "fft_detuned", {"records": size},
        [_e("interval_sampling", ["records"], "sampled"),
         _e("quick_sort", ["sampled"], "sorted", 2),
         _e("merge_sort", ["sorted"], "merged")], "merged")
    pool = ["interval_sampling", "quick_sort", "merge_sort", "fft",
            "hash", "monte_carlo"]
    return reference, detuned, pool


def ai_fidelity_harness(size: int = 16384, chunk: int = 256
                        ) -> Tuple[ProxyDAG, ProxyDAG, List[str]]:
    """``(reference, detuned, component_pool)`` for the AI-dwarf structure
    contract: the reference is an lm_train-style pipeline whose attention
    stage the detuned structure lacks *entirely*, so only a structural
    insertion of an attention-class component can create the missing
    ``mix_attention`` channel (the exp-gated-contraction basis field no
    amount of gemm re-weighting supplies).  One definition, imported by
    both ``tests/test_ai_dwarfs.py`` and the ``lm_structure`` gate in
    ``benchmarks/compile_vs_run.py``."""
    from .dwarfs import ComponentParams

    def _e(comp, src, dst, weight=1, **extra):
        return Edge(comp, src, dst,
                    ComponentParams(data_size=size, chunk_size=chunk,
                                    weight=weight, extra=extra))

    # weights balanced so the attention stage carries ~0.27 of the mix —
    # far beyond the 0.10 share tolerance (the detuned structure deviates
    # hard), yet reachable by an *inserted* extras-free attention edge
    # (default geometry at this data_size supplies ~0.16 share at weight 8,
    # so the inner weight loop can close the gap).  The gemm edges carry no
    # ``rounds`` extra: a dynamic extra becomes a tunable leaf, and every
    # distinct jittered value bakes a new body analysis — which would break
    # the zero-new-compiles contract this harness exists to gate.
    reference = ProxyDAG(
        "lm_ref", {"tokens": size},
        [_e("gemm_train", ["tokens"], "h0", 1),
         _e("attention", ["h0"], "attn", 4, seq_len=64, heads=4, kv_heads=2),
         _e("gemm_train", ["attn"], "mlp", 1),
         _e("count_average", ["mlp"], "out")], "out")
    detuned = ProxyDAG(
        "lm_detuned", {"tokens": size},
        [_e("gemm_train", ["tokens"], "h0", 1),
         _e("gemm_train", ["h0"], "mlp", 1),
         _e("count_average", ["mlp"], "out")], "out")
    pool = ["gemm_train", "attention", "scan_recurrent", "count_average",
            "quick_sort"]
    return reference, detuned, pool
