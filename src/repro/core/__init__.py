# The paper's primary contribution: the dwarf-based scalable benchmarking
# methodology — eight dwarf components, DAG-like proxy benchmarks, the
# profiler (HLO metric vector) and the auto-tuning tool.
from .autotune import (AutoTuner, PopulationTuner, PopulationTuneResult,
                       TuneResult, autotune, population_tune)
from .dag import Edge, ProxyDAG
from .dwarfs import DWARFS, ComponentParams, get_component
from .metrics import (HW_V5E, CostReport, HardwareSpec, Roofline,
                      analyze_hlo_text, eq1_accuracy, metric_vector,
                      roofline_from_report, vector_accuracy)
from .profiler import WorkloadProfile, characterize, decompose_to_dwarfs
from .proxy import ProxyBenchmark, proxy_from_dwarf_weights
from .schedule import (BucketSchedule, ExecutionPlan, FusedStage,
                       fusion_threshold, lower)

__all__ = [
    "AutoTuner", "PopulationTuner", "PopulationTuneResult", "TuneResult",
    "autotune", "population_tune", "Edge", "ProxyDAG", "DWARFS",
    "ComponentParams", "get_component", "HW_V5E", "CostReport",
    "HardwareSpec", "Roofline", "analyze_hlo_text", "eq1_accuracy",
    "metric_vector", "roofline_from_report", "vector_accuracy",
    "WorkloadProfile", "characterize", "decompose_to_dwarfs",
    "ProxyBenchmark", "proxy_from_dwarf_weights",
    "BucketSchedule", "ExecutionPlan", "FusedStage", "fusion_threshold",
    "lower",
]
