# The paper's primary contribution: the dwarf-based scalable benchmarking
# methodology — eight dwarf components, DAG-like proxy benchmarks, the
# profiler (HLO metric vector) and the auto-tuning tool.
from .autotune import (AutoTuner, PopulationTuner, PopulationTuneResult,
                       TuneResult, autotune, coerce_target, population_tune,
                       split_budget)
from .dag import Edge, ProxyDAG, StructureError
from .dwarfs import DWARFS, ComponentParams, get_component
from .engine import (FINGERPRINT_CHANNELS, FINGERPRINT_VERSION,
                     WorkloadFingerprint, fingerprint)
from .metrics import (HW_V5E, CostReport, HardwareSpec, Roofline,
                      analyze_hlo_text, eq1_accuracy, metric_vector,
                      roofline_from_report, vector_accuracy)
from .profiler import WorkloadProfile, characterize, decompose_to_dwarfs
from .proxy import ProxyBenchmark, proxy_from_dwarf_weights
from .schedule import (BucketSchedule, ExecutionPlan, FusedStage,
                       fusion_threshold, lower)
from .structsearch import (Mutation, StructuralTuner, StructuralTuneResult,
                           propose_mutation, structural_tune)
from .subset import SubsetReport, normalize_fingerprints, subset_fingerprints

__all__ = [
    "AutoTuner", "PopulationTuner", "PopulationTuneResult", "TuneResult",
    "autotune", "coerce_target", "population_tune", "split_budget",
    "Edge", "ProxyDAG", "StructureError", "DWARFS",
    "ComponentParams", "get_component",
    "FINGERPRINT_CHANNELS", "FINGERPRINT_VERSION", "WorkloadFingerprint",
    "fingerprint", "HW_V5E", "CostReport",
    "HardwareSpec", "Roofline", "analyze_hlo_text", "eq1_accuracy",
    "metric_vector", "roofline_from_report", "vector_accuracy",
    "WorkloadProfile", "characterize", "decompose_to_dwarfs",
    "ProxyBenchmark", "proxy_from_dwarf_weights",
    "BucketSchedule", "ExecutionPlan", "FusedStage", "fusion_threshold",
    "lower",
    "Mutation", "StructuralTuner", "StructuralTuneResult",
    "propose_mutation", "structural_tune",
    "SubsetReport", "normalize_fingerprints", "subset_fingerprints",
]
