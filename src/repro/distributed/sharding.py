"""Sharding rules: TP over 'model', DP/FSDP over 'data', pure DP over 'pod'.

Conventions (DESIGN.md §6):
  * batch dims shard over ('pod', 'data') — pods are data-parallel replicas
    (gradient all-reduce crosses the DCN-ish pod axis once per step).
  * 2-D weights: output dim over 'model' (Megatron column-parallel), input
    dim over 'data' (ZeRO-3/FSDP) when divisible; row-parallel for the
    second matmul of each pair (wo / w_down / out_proj).
  * expert weights (E, d, f): f over 'model', d over 'data' — EPxTP without
    uneven shards (E = 40/384/16 are not divisible by 16; dims are).
  * block-stacked params carry a leading n_blocks scan axis — never sharded.
  * a dim is sharded only if divisible by the axis size (no uneven shards).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


def mesh_axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape.get(axis, 1)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def _maybe(axis, dim: int, mesh: Mesh):
    """axis (or axis tuple) if it divides dim, else None."""
    if axis is None:
        return None
    size = int(np.prod([mesh_axis_size(mesh, a) for a in
                        (axis if isinstance(axis, tuple) else (axis,))]))
    return axis if _div(dim, size) else None


def batch_spec_axis(mesh: Mesh, batch: int):
    """('pod','data') / 'data' / None depending on divisibility."""
    axes = batch_axes(mesh)
    full = int(np.prod([mesh_axis_size(mesh, a) for a in axes]))
    if _div(batch, full):
        return axes if len(axes) > 1 else axes[0]
    if _div(batch, mesh_axis_size(mesh, "data")):
        return "data"
    return None


def candidate_spec_axis(mesh: Mesh, n: int,
                        prefer: Tuple[str, ...] = ("pod", "data")):
    """:func:`batch_spec_axis` analogue for a tuner-population axis.

    A population of ``n`` dynamic-param candidates (the leading axis of a
    stacked dyn pytree — ``ParamSpace.stack_candidates``) shards over the
    data-parallel-ish axes of the mesh: candidates are independent, so the
    candidate batch is embarrassingly parallel exactly like an rng batch.
    ``prefer`` names the axes to try (a stack passes its own axis name,
    e.g. ``("rank",)`` / ``("worker",)``); returns the axis (or axis
    tuple) when ``n`` divides, else ``None`` (replicate).
    """
    axes = tuple(a for a in prefer if a in mesh.axis_names)
    if not axes:
        return None
    full = int(np.prod([mesh_axis_size(mesh, a) for a in axes]))
    if _div(n, full):
        return axes if len(axes) > 1 else axes[0]
    for a in axes:
        if _div(n, mesh_axis_size(mesh, a)):
            return a
    return None


def population_shardings(mesh: Mesh, dyn_batched: Any,
                         prefer: Tuple[str, ...] = ("pod", "data")) -> Any:
    """NamedSharding pytree for a stacked dynamic-param pytree: each
    leaf's leading candidate axis shards over the mesh when divisible;
    scalars and indivisible leaves replicate."""

    def leaf(x):
        shape = getattr(x, "shape", ())
        ax = (candidate_spec_axis(mesh, int(shape[0]), prefer)
              if len(shape) >= 1 else None)
        if ax is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(ax, *([None] * (len(shape) - 1))))

    return jax.tree.map(leaf, dyn_batched)


def bucket_shardings(mesh: Mesh, bucket_dynb: Any,
                     prefer: Tuple[str, ...] = ("pod", "data")) -> Any:
    """:func:`population_shardings` at weight-bucket granularity: the
    ExecutionPlan's ``BucketSchedule`` hands each stratum to the stack as
    its own candidate batch, so placement happens per bucket — every
    bucket shares one executable and its leading axis shards over the
    mesh when the *bucket* size divides (smaller-than-population batches
    replicate instead of forcing uneven shards)."""
    return population_shardings(mesh, bucket_dynb, prefer=prefer)


def serve_shardings(mesh: Mesh, rngs: Any, dyn_batched: Any,
                    prefer: Tuple[str, ...] = ("pod", "data")
                    ) -> Tuple[NamedSharding, Any]:
    """Placement for a serving micro-batch: unlike a population (one
    shared rng, candidate-batched dyn), every request carries its own rng,
    so the rng batch and the dyn pytree share one leading *request* axis
    and must partition together — request ``i``'s rng and params land on
    the same device.  Returns ``(rng_sharding, dyn_shardings)``; both
    replicate when the chunk size does not divide the preferred axes."""
    shape = getattr(rngs, "shape", ())
    ax = (candidate_spec_axis(mesh, int(shape[0]), prefer)
          if len(shape) >= 1 else None)
    if ax is None:
        rng_s = NamedSharding(mesh, P())
        dyn_s = jax.tree.map(lambda x: NamedSharding(mesh, P()),
                             dyn_batched)
    else:
        rng_s = NamedSharding(mesh, P(ax, *([None] * (len(shape) - 1))))
        dyn_s = population_shardings(mesh, dyn_batched, prefer=prefer)
    return rng_s, dyn_s


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

_COLUMN = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "dt_proj",
           "w_if", "lm_head"}
# w_x is row-parallel so the sLSTM scan input needs one psum per layer
# instead of a per-step psum on a sharded carry
_ROW = {"wo", "w_down", "out_proj", "w_out", "x_proj", "w_x"}


def _leaf_spec(name: str, shape: Tuple[int, ...], mesh: Mesh,
               fsdp: bool, stacked: bool,
               heads_divisible: bool = True,
               kv_divisible: bool = True,
               moe_ep: bool = False) -> P:
    """Spec for one parameter; ``stacked`` = leading n_blocks scan axis."""
    dims = shape[1:] if stacked else shape
    lead = (None,) if stacked else ()
    dp = "data" if fsdp else None

    def spec(*axes):
        return P(*lead, *axes)

    # attention head sharding must respect head boundaries: a sub-head TP
    # split puts an all-reduce inside every attention block (measured:
    # 2816 all-reduces/step on tinyllama).  If q-heads don't divide by tp,
    # attention runs data-parallel only; if only kv-heads don't, KV
    # projections replicate (GQA KV is small) and q/o stay column/row.
    if name in ("wq", "wk", "wv", "wo", "bq", "bk", "bv"):
        if not heads_divisible or (name in ("wk", "wv", "bk", "bv")
                                   and not kv_divisible):
            if len(dims) == 2:
                return spec(_maybe(dp, dims[0], mesh), None)
            return spec(*([None] * len(dims)))

    if len(dims) == 0:
        return spec()
    if len(dims) == 1:
        if name in ("bq", "bk", "bv"):
            return spec(_maybe("model", dims[0], mesh))
        return spec(_maybe("model", dims[0], mesh))
    if name == "embed":
        # vocab dim deliberately NOT sharded: a V-sharded table forces XLA to
        # replicate the (B,S,d) gather output / grad scatter across 'data',
        # destroying batch sharding end-to-end (measured: 8x activation blow-up)
        return spec(None, _maybe("model", dims[1], mesh))
    if name in ("router",):
        return spec(_maybe("model", dims[0], mesh), None)
    if name in ("conv_w",):
        return spec(None, _maybe("model", dims[1], mesh))
    if name in ("A_log", "D"):
        return spec(_maybe("model", dims[0], mesh),
                    *([None] * (len(dims) - 1)))
    if name == "r_h":
        # sLSTM recurrent weights replicate: any sharding of the carry puts
        # a 1MB psum inside every sequential time step (measured: 98k
        # all-reduces/step on xlstm train_4k)
        return spec(*([None] * len(dims)))
    if len(dims) == 3:           # experts (E, d, f) / (E, f, d); r_h (H,hd,4hd)
        if name in ("w_gate", "w_up", "w_down") and moe_ep:
            # expert parallelism: experts over 'model', FSDP on d_model dim
            ddim = 1 if name != "w_down" else 2
            ax = [None, None, None]
            ax[0] = _maybe("model", dims[0], mesh)
            ax[ddim] = _maybe(dp, dims[ddim], mesh)
            return spec(*ax)
        if name in ("w_gate", "w_up"):
            return spec(None, _maybe(dp, dims[1], mesh),
                        _maybe("model", dims[2], mesh))
        if name == "w_down":
            return spec(None, _maybe("model", dims[1], mesh),
                        _maybe(dp, dims[2], mesh))
        return spec(None, None, _maybe("model", dims[-1], mesh))
    # 2-D
    if name in _ROW:
        return spec(_maybe("model", dims[0], mesh), _maybe(dp, dims[1], mesh))
    if name in _COLUMN or True:  # column-parallel is the generic fallback
        mspec = _maybe("model", dims[1], mesh)
        if mspec is None:        # fall back to row-parallel
            return spec(_maybe("model", dims[0], mesh), None)
        return spec(_maybe(dp, dims[0], mesh), mspec)


def param_specs(params_tree: Any, mesh: Mesh, fsdp: bool = True,
                cfg: Optional[ArchConfig] = None) -> Any:
    """PartitionSpec tree matching ``params_tree`` (arrays or SDS)."""
    tp = mesh_axis_size(mesh, "model")
    heads_div = cfg is None or cfg.n_heads % tp == 0
    kv_div = cfg is None or cfg.n_kv_heads % tp == 0
    moe_ep = bool(cfg and cfg.moe_experts and cfg.moe_experts % tp == 0)

    def walk(path, leaf):
        name = None
        stacked = False
        for p in path:
            key = getattr(p, "key", None)
            if key == "blocks":
                stacked = True
            if key is not None:
                name = key
        shape = leaf.shape
        return _leaf_spec(name or "", shape, mesh, fsdp, stacked,
                          heads_divisible=heads_div, kv_divisible=kv_div,
                          moe_ep=moe_ep)

    return jax.tree_util.tree_map_with_path(walk, params_tree)


def named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation / cache specs
# ---------------------------------------------------------------------------


def input_shardings(cfg: ArchConfig, specs: Dict[str, Any], mesh: Mesh
                    ) -> Dict[str, Any]:
    out = {}
    for k, v in specs.items():
        if not hasattr(v, "shape") or v.shape == ():
            out[k] = NamedSharding(mesh, P())
            continue
        b = batch_spec_axis(mesh, v.shape[0])
        out[k] = NamedSharding(mesh, P(b, *([None] * (len(v.shape) - 1))))
    return out


def cache_specs_tree(cfg: ArchConfig, cache_shapes: Any, mesh: Mesh) -> Any:
    """KV caches: batch over data axes when divisible, else sequence over
    'data' (long-context single-sequence decode); head_dim over 'model'.
    SSM/xLSTM states: widest state dim over 'model'."""

    def walk(path, leaf):
        shape = leaf.shape
        # (n_blocks, B, S, Kv, hd) KV cache
        if len(shape) == 5:
            b = batch_spec_axis(mesh, shape[1])
            seq = None if b is not None else _maybe("data", shape[2], mesh)
            return P(None, b, seq, None, _maybe("model", shape[4], mesh))
        if len(shape) == 4:      # mamba (n_blocks, B, di, st) / mlstm C
            b = batch_spec_axis(mesh, shape[1])
            return P(None, b, _maybe("model", shape[2], mesh), None)
        if len(shape) == 3:      # (n_blocks, B, x)
            b = batch_spec_axis(mesh, shape[1])
            return P(None, b, _maybe("model", shape[2], mesh))
        if len(shape) == 2:
            b = batch_spec_axis(mesh, shape[1])
            return P(None, b)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(walk, cache_shapes)
