from .sharding import (batch_axes, cache_specs_tree, input_shardings, named,
                       param_specs)
