"""Fault tolerance: restartable training loop and elastic mesh transitions.

At 1000+ nodes the failure model is: (a) a worker dies mid-step -> the job
restarts from the latest atomic checkpoint with deterministic data skipping;
(b) a worker is slow (straggler) -> the step deadline fires and the
microbatch schedule re-dispatches around it; (c) capacity changes -> the
elastic path restores the same checkpoint onto a different mesh via
per-leaf device_put with the new shardings (see train/checkpoint.py).

On this CPU container the mechanisms are exercised with injected failures
(tests/test_fault_tolerance.py); the policies are the production ones.

.. deprecated::
   The fault *primitives* — :class:`InjectedFailure`,
   :class:`StragglerMonitor`, :class:`StragglerReport` — moved to
   :mod:`repro.faults`, which owns deterministic fault injection for both
   the training and the serving paths (seeded :class:`repro.faults.FaultPlan`
   chaos schedules).  They are re-exported here for backward compatibility;
   import them from ``repro.faults`` in new code.  Only the training loop
   (:class:`ResilientTrainLoop`) still lives in this module.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax

# Deprecation shims: the canonical home of these primitives is repro.faults
# (importing through here keeps existing call sites working unchanged).
from ..faults import InjectedFailure, StragglerMonitor, StragglerReport
from ..train.checkpoint import (AsyncCheckpointer, latest_step,
                                restore_checkpoint)

__all__ = ["InjectedFailure", "StragglerMonitor", "StragglerReport",
           "LoopResult", "ResilientTrainLoop"]


@dataclasses.dataclass
class LoopResult:
    state: Any
    metrics_history: List[Dict[str, float]]
    restarts: int
    straggler_reports: List[StragglerReport]


class ResilientTrainLoop:
    """Checkpoint/restart training loop with deterministic data replay.

    ``batch_fn(step) -> batch`` must be deterministic in ``step`` so that a
    restart resumes on exactly the data it would have seen (the data pipeline
    derives its RNG from the step index).
    """

    def __init__(self, train_step: Callable, ckpt_dir: str,
                 ckpt_every: int = 50, keep: int = 3,
                 straggler_threshold: float = 2.0):
        self.train_step = train_step
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.checkpointer = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.monitor = StragglerMonitor(straggler_threshold)

    def run(self, state: Any, batch_fn: Callable[[int], Any], num_steps: int,
            failure_injector: Optional[Callable[[int], None]] = None,
            shardings: Any = None) -> LoopResult:
        history: List[Dict[str, float]] = []
        restarts = 0
        step = int(jax.device_get(state.step))
        while step < num_steps:
            try:
                if failure_injector is not None:
                    failure_injector(step)
                t0 = time.perf_counter()
                state, metrics = self.train_step(state, batch_fn(step))
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.monitor.observe(step, dt)
                history.append({k: float(jax.device_get(v))
                                for k, v in metrics.items()})
                step += 1
                if step % self.ckpt_every == 0 or step == num_steps:
                    self.checkpointer.save(state, step)
            except InjectedFailure:
                restarts += 1
                self.checkpointer.wait()
                last = latest_step(self.ckpt_dir)
                if last is None:
                    raise
                state = restore_checkpoint(state, self.ckpt_dir, last,
                                           shardings=shardings)
                step = int(jax.device_get(state.step))
        self.checkpointer.wait()
        return LoopResult(state, history, restarts, self.monitor.reports)
