from ..faults import FaultPlan, InjectedFailure
from .engine import (ArrivalTrace, CircuitBreaker, ProxyRequest,
                     ResourceMonitor, ServeReport, ServingEngine,
                     burst_trace, poisson_trace, serve)
from .serve_step import generate, make_decode_step, make_prefill_step
