"""Serving steps: batched prefill and single-token decode with greedy/top-k
sampling.  ``decode_32k`` / ``long_500k`` shape cells lower ``decode_step``
(one new token against a seq_len-deep cache), not ``train_step``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.model import Model


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, cache, inputs: Dict[str, jnp.ndarray]):
        kw = {k: inputs[k] for k in
              ("vision_embeds", "mrope_positions", "frames") if k in inputs}
        logits, cache = model.prefill(params, inputs["tokens"], cache, **kw)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return next_tok, cache
    return prefill_step


def make_decode_step(model: Model, temperature: float = 0.0) -> Callable:
    def decode_step(params, cache, tokens, index, rng=None):
        logits, cache = model.decode_step(params, tokens, cache, index)
        last = logits[:, -1]
        if temperature > 0.0 and rng is not None:
            next_tok = jax.random.categorical(rng, last / temperature)
        else:
            next_tok = jnp.argmax(last, axis=-1)
        return next_tok, cache
    return decode_step


def generate(model: Model, params, prompt: jnp.ndarray, max_new: int,
             max_seq: int, inputs: Optional[Dict] = None) -> jnp.ndarray:
    """Greedy generation driver (prefill + decode loop) — example/tests."""
    B, S = prompt.shape
    cache = model.init_cache(B, max_seq)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    tok, cache = prefill(params, cache, {"tokens": prompt, **(inputs or {})})
    toks = [tok]
    # vision prefix shifts absolute positions
    offset = model.cfg.vision_tokens if model.cfg.vision_tokens else 0
    for i in range(max_new - 1):
        tok, cache = decode(params, cache, tok[:, None],
                            jnp.asarray(S + offset + i, jnp.int32))
        toks.append(tok)
    return jnp.stack(toks, axis=1)
