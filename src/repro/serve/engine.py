"""Proxy serving engine: concurrent request streams with tail-latency SLOs
and a fault-tolerance layer.

The paper's proxies stand in for production big-data services, and Gao et
al. (arXiv 1802.00699) frame dwarf proxies explicitly as *service-level*
workload mimics — but a benchmark that only ever executes one proxy at a
time cannot report the metrics a service is judged by: latency
percentiles under load, time to first result, sustained throughput,
behavior under failure.  This module closes that gap on top of the
compile-once/run-many machinery:

* A request **queue** admits heterogeneous :class:`ProxyRequest`\\ s (any
  structure + per-request dynamic params + per-request rng + optional
  per-request latency ``deadline_s`` and SLO class) and groups them by
  compiled identity — ``(stack, plan.structure_key())`` — into
  per-structure FIFO lanes.
* The dispatch loop drains the most urgent lane (earliest absolute
  deadline first, oldest head otherwise) into a **micro-batch** (up to
  ``max_batch`` requests), stratifies it by the engine cost model, and
  executes it in fixed-size chunks through the stack's cached serve
  executables (``Stack._compiled_plan_serve`` — one vmapped call per
  chunk, every request its own rng/params lane).  Chunk sizes never vary
  (the tail pads by repeating its last request), so steady-state serving
  is **zero retraces**, at most one compile per new (structure, chunk
  size) — and :meth:`ServingEngine.warmup` pre-pays even those through
  the :class:`~repro.core.pool.ExecutablePool`.
* ``batch_wait_s`` sets the **partial-chunk timeout flush** policy: ``0``
  dispatches eagerly (the default), ``inf`` holds a lane until a full
  chunk accumulates, and a finite positive value holds at most that long
  before flushing a short padded chunk — bounding the price a lone
  request pays for batching instead of holding P99 hostage.
* Every request's queue wait, service time, total latency and terminal
  status are recorded; the :class:`ServeReport` emits P50/P95/P99, time
  to first result, sustained throughput, the micro-batch histogram,
  cold-dispatch / retry / deadline-miss / degradation accounting and a
  :class:`ResourceMonitor` host/device-memory summary.

Fault tolerance (the resilience layer):

* A seeded :class:`repro.faults.FaultPlan` injects executor failures,
  stragglers and pool-eviction storms at chosen request indices —
  honored identically under both clocks, so chaos runs are
  bit-reproducible.
* Failed chunks **retry** with capped exponential backoff; a chunk that
  fails again is **bisected** so a poison request is isolated instead of
  failing its whole batch.  Real (non-injected) dispatch failures also
  invalidate the chunk's pooled executable (it may itself be the fault).
* A per-``(stack, structure)`` **circuit breaker** trips after repeated
  failures and degrades that lane — requests serve singly through the
  stock XLA lowering (:func:`repro.kernels.dispatch.forced_backend`)
  until enough degraded dispatches succeed to close the breaker again.
  Every degraded dispatch is counted; no request is ever lost — each
  reaches a terminal status (``ok`` / ``retried`` / ``degraded`` /
  ``failed``).

Live submission: :meth:`ServingEngine.start` turns the grouping loop
into a long-lived dispatcher thread; :meth:`ServingEngine.submit` admits
requests from any number of concurrent threads and returns a
``concurrent.futures.Future`` per request; :meth:`ServingEngine.drain`
blocks until the queues empty and :meth:`ServingEngine.shutdown` joins
the service and returns the session's :class:`ServeReport`.

Two clocks make runs comparable and CI-gateable:

* ``clock="wall"`` executes for real; service times are measured.
* ``clock="virtual"`` never executes — service times come from the
  engine's deterministic per-candidate cost model
  (:meth:`ExecutionPlan.candidate_costs`), so the same trace (and the
  same fault plan) yields bit-identical percentiles on any machine, any
  number of times.  The queue dynamics (admission order, grouping,
  batching, retries, degradation) are exactly the wall-clock loop's.

Arrival traces are seeded and deterministic: :func:`poisson_trace` (open
loop — arrivals don't wait for completions) and :func:`burst_trace`
(synchronized waves; ``bursts=1`` is the capacity test where everything
arrives at once).  ``mode="closed"`` serves any trace closed-loop: each
request is admitted only when the previous one completes — the
sequential baseline micro-batching is judged against.
"""

from __future__ import annotations

import dataclasses
import math
import os
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..api.stack import CACHE_STATS, Stack, classify_failure, get_stack
from ..core import schedule as plans
from ..core.dag import ProxyDAG
from ..core.pool import ExecutablePool, get_pool
from ..faults import FaultPlan, InjectedFailure
from ..kernels.dispatch import forced_backend, megakernel_enabled

#: virtual-clock calibration: modeled cost units (flops + vpu + bytes)
#: retired per second, plus a fixed per-dispatch overhead — the absolute
#: scale is arbitrary; percentile *structure* under the queueing dynamics
#: is what the deterministic clock exists for
VIRTUAL_RATE = 5.0e10
VIRTUAL_OVERHEAD_S = 2.0e-4
#: modeled compile cost a virtual-clock dispatch pays when its executable
#: is cold (post eviction-storm chaos, or a degraded form's first use)
VIRTUAL_COLD_S = 2.0e-2

#: terminal per-request statuses (every request reaches exactly one)
STATUSES = ("ok", "retried", "degraded", "failed")


# ---------------------------------------------------------------------------
# requests + traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ProxyRequest:
    """One admission: a proxy structure, its dynamic params, its rng."""

    rid: int                   # position in the trace (result ordering)
    structure: str             # spec name / label (reporting only)
    dag: ProxyDAG              # shared per-structure template
    dyn: Any                   # unbatched dynamic_params()-shaped pytree
    rng: jax.Array
    arrival_s: float           # arrival offset from trace start
    #: latency budget relative to arrival; completion later than
    #: ``arrival_s + deadline_s`` counts a deadline miss (never a drop)
    deadline_s: Optional[float] = None
    slo: str = "standard"      # SLO class label (deadline-miss breakdown)

    @property
    def abs_deadline(self) -> float:
        """Absolute deadline (inf when the request declared none) — the
        earliest-deadline-first lane-selection key."""
        if self.deadline_s is None:
            return math.inf
        return self.arrival_s + self.deadline_s


@dataclasses.dataclass
class ArrivalTrace:
    """A deterministic, seeded request stream."""

    name: str
    seed: int
    requests: List[ProxyRequest]

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)

    @property
    def structures(self) -> List[str]:
        return sorted({r.structure for r in self.requests})

    def unique_dags(self) -> List[ProxyDAG]:
        """One template per distinct structure — the warmup working set."""
        seen, dags = set(), []
        for r in self.requests:
            key = r.dag.canonical_structure_key()
            if key not in seen:
                seen.add(key)
                dags.append(r.dag)
        return dags


def _templates(mix: Optional[Sequence[str]]):
    """(name, dag, space, base_values) per spec in the request mix."""
    from ..api.params import ParamSpace
    from ..api.spec import ProxySpec
    from ..core.workloads import PROXY_SPECS
    names = tuple(mix) if mix else tuple(sorted(PROXY_SPECS))
    out = []
    for name in names:
        if name not in PROXY_SPECS:
            raise KeyError(f"unknown proxy spec {name!r}; known: "
                           f"{sorted(PROXY_SPECS)}")
        dag = ProxySpec.from_json(PROXY_SPECS[name]).to_benchmark().dag
        space = ParamSpace.from_dag(dag)
        out.append((name, dag, space, space.values(dag)))
    return out


def _make_request(i: int, tmpl, seed: int, arrival: float,
                  deadline_s: Optional[float] = None,
                  slo: str = "standard") -> ProxyRequest:
    name, dag, space, base = tmpl
    row = space.sample_dynamic(1, base, seed=seed + 7919 * i)[0]
    dynb = space.stack_candidates(dag, row[None])
    dyn = jax.tree_util.tree_map(lambda v: v[0], dynb)
    return ProxyRequest(
        rid=i, structure=name, dag=dag, dyn=dyn,
        rng=jax.random.fold_in(jax.random.PRNGKey(seed), i),
        arrival_s=float(arrival), deadline_s=deadline_s, slo=slo)


def poisson_trace(n: int = 32, rate_rps: float = 100.0, seed: int = 0,
                  mix: Optional[Sequence[str]] = None,
                  deadline_s: Optional[float] = None,
                  slo: str = "standard") -> ArrivalTrace:
    """Open-loop Poisson arrivals at ``rate_rps``, request mix drawn
    uniformly from ``mix`` (default: every ``PROXY_SPECS`` proxy), every
    request's dynamic params independently sampled from its structure's
    :class:`~repro.api.params.ParamSpace` — all under one seed, so the
    trace is bit-reproducible across processes and machines.
    ``deadline_s``/``slo`` stamp every request with a latency budget and
    SLO class for deadline-miss accounting."""
    rs = np.random.RandomState(seed)
    arrivals = np.cumsum(rs.exponential(1.0 / max(rate_rps, 1e-9), size=n))
    tmpl = _templates(mix)
    picks = rs.randint(0, len(tmpl), size=n)
    return ArrivalTrace(
        name=f"poisson:n={n}:rate={rate_rps:g}:seed={seed}", seed=seed,
        requests=[_make_request(i, tmpl[picks[i]], seed, arrivals[i],
                                deadline_s, slo)
                  for i in range(n)])


def burst_trace(n: int = 32, bursts: int = 4, period_s: float = 0.05,
                seed: int = 0,
                mix: Optional[Sequence[str]] = None,
                deadline_s: Optional[float] = None,
                slo: str = "standard") -> ArrivalTrace:
    """Synchronized arrival waves: ``n`` requests split evenly across
    ``bursts`` bursts ``period_s`` apart (every member of a burst arrives
    at the same instant — the tail-latency stressor Poisson smoothing
    hides).  ``bursts=1`` is the capacity trace: everything at t=0."""
    rs = np.random.RandomState(seed)
    tmpl = _templates(mix)
    picks = rs.randint(0, len(tmpl), size=n)
    per = max(1, -(-n // max(bursts, 1)))        # ceil split
    return ArrivalTrace(
        name=f"burst:n={n}:bursts={bursts}:seed={seed}", seed=seed,
        requests=[_make_request(i, tmpl[picks[i]], seed,
                                (i // per) * period_s, deadline_s, slo)
                  for i in range(n)])


# ---------------------------------------------------------------------------
# resource monitor
# ---------------------------------------------------------------------------


def _page_size() -> int:
    try:
        return os.sysconf("SC_PAGE_SIZE")
    except (AttributeError, ValueError, OSError):  # pragma: no cover
        return 4096


class ResourceMonitor(threading.Thread):
    """Daemon thread sampling host RSS (``/proc/self/statm``) and device
    memory (``Device.memory_stats``, where the backend exposes it) while a
    serve runs — no psutil dependency, negligible overhead."""

    def __init__(self, interval_s: float = 0.005):
        super().__init__(daemon=True)
        self.interval_s = interval_s
        self._halt = threading.Event()
        self.host_rss: List[int] = []
        self.device_bytes: List[int] = []

    def _sample(self) -> None:
        try:
            with open("/proc/self/statm") as f:
                self.host_rss.append(
                    int(f.read().split()[1]) * _page_size())
        except (OSError, ValueError, IndexError):  # pragma: no cover
            pass
        try:
            ms = jax.local_devices()[0].memory_stats()
            if ms and "bytes_in_use" in ms:
                self.device_bytes.append(int(ms["bytes_in_use"]))
        except Exception:           # CPU backends expose no memory_stats
            pass

    def run(self) -> None:
        while not self._halt.is_set():
            self._sample()
            self._halt.wait(self.interval_s)

    def stop(self) -> Dict[str, float]:
        """Idempotent stop+join+summarize: safe to call from a
        ``finally`` even if the monitor already stopped."""
        self._halt.set()
        if self.is_alive():
            self.join(timeout=2.0)
        self._sample()              # at least one sample, however short
        out: Dict[str, float] = {
            "samples": float(len(self.host_rss)),
            "host_rss_peak_bytes": float(max(self.host_rss, default=0)),
            "host_rss_mean_bytes": float(np.mean(self.host_rss))
            if self.host_rss else 0.0,
        }
        if self.device_bytes:
            out["device_peak_bytes"] = float(max(self.device_bytes))
        return out


# ---------------------------------------------------------------------------
# ServeReport
# ---------------------------------------------------------------------------


def _percentiles(xs: Sequence[float]) -> Dict[str, float]:
    if not xs:
        return {k: 0.0 for k in ("p50", "p95", "p99", "mean", "max")}
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()), "max": float(a.max())}


@dataclasses.dataclass
class ServeReport:
    """Uniform result of one served trace / live session — the SLO and
    resilience surface."""

    stack: str
    clock: str                      # "wall" | "virtual"
    mode: str                       # "open" | "closed" | "live"
    n_requests: int
    structures: int                 # distinct compiled groups served
    makespan_s: float               # first arrival -> last completion
    throughput_rps: float           # n_requests / makespan
    time_to_first_result_s: float
    latency_s: Dict[str, float]     # p50/p95/p99/mean/max end-to-end
    queue_wait_s: Dict[str, float]  # arrival -> dispatch start
    service_s: Dict[str, float]     # dispatch chunk execution
    batch_hist: Dict[int, int]      # micro-batch size -> dispatch count
    dispatches: int                 # executable calls (chunks)
    cold_dispatches: int            # chunks that compiled first
    compile_s: float                # wall time of cold chunks (compile-
                                    # inclusive service; 0 when warm)
    retraces: int                   # CACHE_STATS trace delta (wall clock)
    resources: Dict[str, float]
    # -- resilience accounting (PR 7) ---------------------------------------
    failures: int = 0               # failed dispatch attempts observed
    retries: int = 0                # chunk re-dispatches after a failure
    deadline_misses: int = 0        # completions past their budget
    deadline_miss_by_slo: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    degraded_dispatches: int = 0    # dispatches served under open breaker
    breaker_trips: int = 0          # circuit-breaker open transitions
    timeout_flushes: int = 0        # partial chunks flushed by batch_wait
    lost_requests: int = 0          # requests with no terminal status
                                    # (the zero-loss invariant: always 0)
    #: per-request terminal status in trace order ("ok" | "retried" |
    #: "degraded" | "failed")
    statuses: List[str] = dataclasses.field(default_factory=list,
                                            repr=False)
    fault_plan: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: per-request host results in trace order (bit-identity checks);
    #: empty under the virtual clock, ``None`` for failed requests
    results: List[Any] = dataclasses.field(default_factory=list, repr=False)
    #: requests served per structure name — with :attr:`templates` this
    #: lets ``repro.api.fingerprint(report)`` distill the mix's aggregate
    #: channel vector without re-running the trace
    structure_mix: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: structure name -> its ProxyDAG template (not serialized)
    templates: Dict[str, Any] = dataclasses.field(default_factory=dict,
                                                  repr=False)

    def status_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s in self.statuses:
            out[s] = out.get(s, 0) + 1
        return out

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(dataclasses.replace(self, templates={}))
        d.pop("results")
        d.pop("statuses")
        d.pop("templates")
        d["status_counts"] = self.status_counts()
        d["batch_hist"] = {str(k): v
                           for k, v in sorted(self.batch_hist.items())}
        return d


# ---------------------------------------------------------------------------
# circuit breaker + per-run session state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CircuitBreaker:
    """Per-``(stack, structure)`` failure gate.

    ``closed`` = normal dispatch.  After ``threshold`` consecutive
    failures it ``open``\\ s: the lane degrades (singleton dispatches
    through the forced-XLA fallback) until ``recovery`` consecutive
    degraded dispatches succeed, which closes it again.  A failure while
    open resets the recovery progress."""

    threshold: int = 3
    recovery: int = 4
    state: str = "closed"
    consecutive_failures: int = 0
    successes_while_open: int = 0
    trips: int = 0

    @property
    def open(self) -> bool:
        return self.state == "open"

    def record_failure(self) -> bool:
        """Count a failure; returns True when this failure trips the
        breaker open."""
        self.consecutive_failures += 1
        self.successes_while_open = 0
        if self.state == "closed" \
                and self.consecutive_failures >= self.threshold:
            self.state = "open"
            self.trips += 1
            return True
        return False

    def record_success(self) -> None:
        self.consecutive_failures = 0
        if self.state == "open":
            self.successes_while_open += 1
            if self.successes_while_open >= self.recovery:
                self.state = "closed"
                self.successes_while_open = 0


class _Session:
    """Mutable accounting for one serve() run or one live session."""

    def __init__(self, execute: bool, closed: bool,
                 faults: Optional[FaultPlan]):
        self.execute = execute
        self.closed = closed
        self.faults = faults if faults is not None else FaultPlan()
        self.lat: Dict[int, float] = {}
        self.qwait: Dict[int, float] = {}
        self.svc: Dict[int, float] = {}
        self.results: Dict[int, Any] = {}
        self.statuses: Dict[int, str] = {}
        self.errors: Dict[int, str] = {}
        self.costs: Dict[int, float] = {}
        self.attempts: Dict[int, int] = {}
        self.batch_hist: Dict[int, int] = {}
        self.breakers: Dict[Tuple, CircuitBreaker] = {}
        self.dispatches = 0
        self.cold_dispatches = 0
        self.compile_s = 0.0
        self.failures = 0
        self.retries = 0
        self.degraded_dispatches = 0
        self.timeout_flushes = 0
        self.deadline_misses = 0
        self.deadline_miss_by_slo: Dict[str, int] = {}
        self.first_done: Optional[float] = None
        #: virtual-clock executable-cache model: before the first eviction
        #: storm every dispatch is warm (warmup pre-paid the compiles);
        #: after a storm, each executable pays :data:`VIRTUAL_COLD_S` once
        #: to re-warm — the deterministic analog of the wall-clock
        #: recompile
        self.virtual_warm: set = set()
        self.virtual_storms = 0
        self.evicted_rids: set = set()
        self.traces0 = CACHE_STATS["traces"]


# ---------------------------------------------------------------------------
# ServingEngine
# ---------------------------------------------------------------------------


class _LiveState:
    """Book-keeping of one start()/shutdown() live-serving session."""

    def __init__(self, engine: "ServingEngine"):
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)
        self.groups: Dict[Tuple, Dict[str, Any]] = {}
        self.session = _Session(execute=True, closed=False,
                                faults=engine.faults)
        self.futures: Dict[int, Future] = {}
        self.monitor = ResourceMonitor()
        self.thread: Optional[threading.Thread] = None
        self.t0 = time.perf_counter()
        self.next_rid = 0
        self.inflight = 0            # submitted but not yet resolved
        self.stopping = False
        self.first_arrival: Optional[float] = None
        self.last_done = 0.0


class ServingEngine:
    """Continuous micro-batching over one software stack, with retries,
    deadlines, graceful degradation and live submission.

    ``max_batch`` bounds how many same-structure requests one dispatch
    drains; ``bucket_size`` pins the executable chunk size (default: the
    population policy — one lane per device, so a single-device CPU
    serves unbatched parametric calls and a mesh fills its device axis);
    ``batch_wait_s`` sets the partial-chunk flush policy (0 = dispatch
    eagerly, ``inf`` = hold for full chunks, finite = flush after that
    wait).  ``faults`` installs a default :class:`~repro.faults.FaultPlan`
    for every serve/live session; retry and circuit-breaker knobs
    configure the resilience layer.  All compiled artifacts live in the
    shared :class:`ExecutablePool`; :meth:`warmup` pre-compiles a
    declared working set so the first request is served warm."""

    def __init__(self, stack: Union[str, Stack] = "openmp",
                 max_batch: int = 8, bucket_size: Optional[int] = None,
                 pool: Optional[ExecutablePool] = None,
                 batch_wait_s: float = 0.0,
                 faults: Optional[FaultPlan] = None,
                 max_retries: int = 3,
                 backoff_base_s: float = 1.0e-3,
                 backoff_cap_s: float = 5.0e-2,
                 breaker_threshold: int = 3,
                 breaker_recovery: int = 4):
        self.stack = get_stack(stack) if isinstance(stack, str) else stack
        self.max_batch = max(1, int(max_batch))
        self.bucket_size = bucket_size
        self.pool = pool if pool is not None else get_pool()
        self.batch_wait_s = max(0.0, float(batch_wait_s))
        self.faults = faults
        self.max_retries = max(0, int(max_retries))
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.breaker_threshold = max(1, int(breaker_threshold))
        self.breaker_recovery = max(1, int(breaker_recovery))
        self._live: Optional[_LiveState] = None

    # -- sizing --------------------------------------------------------------

    def _chunk_size(self) -> int:
        """The fixed executable chunk size.  Fixed — never shrunk to a
        small batch (tails pad instead) — so the steady state needs
        exactly one executable per (structure, size)."""
        if self.bucket_size is not None:
            return max(1, min(int(self.bucket_size), self.max_batch))
        return max(1, min(plans.resolve_bucket_size(self.max_batch),
                          self.max_batch))

    # -- warmup --------------------------------------------------------------

    def warmup(self, specs, bucket_sizes: Optional[Tuple[int, ...]] = None
               ) -> Dict[str, int]:
        """Pre-compile the working set: every distinct structure in
        ``specs`` (an :class:`ArrivalTrace`, request list, or
        DAG/spec iterable) at this engine's chunk sizes — after which a
        serve of those structures starts at zero retraces."""
        if isinstance(specs, ArrivalTrace):
            specs = specs.unique_dags()
        else:
            specs = list(specs)
            if specs and isinstance(specs[0], ProxyRequest):
                specs = ArrivalTrace("adhoc", 0, specs).unique_dags()
        if bucket_sizes is None:
            bucket_sizes = (1, self._chunk_size())
        return self.pool.warmup(specs, stack=self.stack,
                                bucket_sizes=bucket_sizes)

    # -- group bookkeeping ---------------------------------------------------

    def _group_for(self, groups: Dict[Tuple, Dict[str, Any]],
                   r: ProxyRequest) -> Tuple:
        """Ensure ``r``'s compiled-identity group exists; returns its key."""
        plan = plans.lower_population(r.dag)
        gkey = (self.stack.name, plan.structure_key())
        if gkey not in groups:
            groups[gkey] = {"plan": plan, "queue": deque(), "remaining": 0}
        return gkey

    def _cost_of(self, plan, r: ProxyRequest) -> float:
        dynb1 = jax.tree_util.tree_map(lambda v: np.asarray(v)[None], r.dyn)
        c, _ = plan.candidate_costs(dynb1)
        return float(c[0])

    def _breaker(self, sess: _Session, gkey: Tuple) -> CircuitBreaker:
        br = sess.breakers.get(gkey)
        if br is None:
            br = CircuitBreaker(threshold=self.breaker_threshold,
                                recovery=self.breaker_recovery)
            sess.breakers[gkey] = br
        return br

    # -- dispatch ------------------------------------------------------------

    def _attempt(self, sess: _Session, g: Dict[str, Any],
                 chunk: List[ProxyRequest], valid: int, b: int,
                 degraded: bool) -> Tuple[float, bool, List]:
        """Execute (or, under the virtual clock, model) one fixed-size
        chunk.  Returns ``(service_s, was_cold, per-request results)``.
        ``degraded`` forces the stock XLA lowering (its executables cache
        under their own backend-tagged keys)."""
        stack = self.stack
        plan = g["plan"]
        if not degraded:
            # injected failures are decided *before* execution (the
            # executor "dies" mid-batch); attempts were already counted
            failing = [r for r in chunk[:valid]
                       if sess.faults.should_fail(r.rid,
                                                  sess.attempts[r.rid] - 1)]
            if failing:
                raise InjectedFailure(
                    f"injected executor failure for rids "
                    f"{sorted(r.rid for r in failing)}")
        if not sess.execute:
            # warm-form identity mirrors Stack._exec_key: backend tag +
            # the megakernel arming flag (a flag flip mid-session is a
            # different compiled form, so it must model cold)
            wkey = (g["plan"].structure_key(), b,
                    "xla" if degraded else None, megakernel_enabled())
            cold = sess.virtual_storms > 0 and wkey not in sess.virtual_warm
            sess.virtual_warm.add(wkey)
            service = (max(sess.costs[r.rid] for r in chunk[:valid])
                       / VIRTUAL_RATE + VIRTUAL_OVERHEAD_S
                       + (VIRTUAL_COLD_S if cold else 0.0))
            return service, cold, []
        m0 = stack.exec_domain().stats["misses"]
        t0 = time.perf_counter()
        with forced_backend("xla" if degraded else None):
            if b == 1:
                fn = stack._compiled_plan(plan, batch=False)
                r = chunk[0]
                # copy the dyn scalars: the batch=False form donates its
                # dyn buffers on accelerators, and a trace may be replayed
                dyn = jax.tree_util.tree_map(jnp.array, r.dyn)
                out, _ = stack._population_call(fn, r.rng, dyn)
            else:
                fn = stack._compiled_plan_serve(plan, b)
                rngs = jnp.stack([r.rng for r in chunk])
                dynb = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                    *[r.dyn for r in chunk])
                out = stack._serve_call(fn, rngs, dynb)
            jax.block_until_ready(out)
        service = time.perf_counter() - t0
        was_cold = stack.exec_domain().stats["misses"] > m0
        host = np.asarray(out)
        results = ([host] if b == 1
                   else [host[j] for j in range(valid)])
        return service, was_cold, results

    def _storm(self, sess: _Session, chunk: List[ProxyRequest]) -> None:
        """Honor any pool-eviction storm scheduled on this chunk's rids:
        wall clock evicts the stack's real executables (the next dispatch
        recompiles), virtual clock forgets its warm set (the next
        dispatch models the cold cost) — identical dynamics per plan."""
        storm = [r.rid for r in chunk
                 if sess.faults.evicts(r.rid)
                 and r.rid not in sess.evicted_rids]
        if not storm:
            return
        sess.evicted_rids.update(storm)
        if sess.execute:
            self.pool.clear(self.stack.exec_domain().name)
        else:
            sess.virtual_storms += 1
            sess.virtual_warm.clear()

    def _invalidate_executable(self, plan, b: int) -> None:
        """Invalidate-on-failure: drop the pooled executable a real
        dispatch failure went through — it may itself be the fault — and
        record the failure against the domain's health stats."""
        stack = self.stack
        dom = stack.exec_domain()
        if b == 1:
            key = stack._exec_key(False, plan.structure_key())
        else:
            key = stack._exec_key(("serve", b), plan.structure_key())
        self.pool.invalidate(dom, key)

    def _record(self, sess: _Session, r: ProxyRequest, start: float,
                done_t: float, service: float, status: str) -> None:
        base = start if sess.closed else r.arrival_s
        sess.qwait[r.rid] = start - base
        sess.svc[r.rid] = service
        lat = done_t - base
        sess.lat[r.rid] = lat
        sess.statuses[r.rid] = status
        if sess.first_done is None and status != "failed":
            sess.first_done = done_t
        if r.deadline_s is not None and lat > r.deadline_s + 1e-12:
            sess.deadline_misses += 1
            sess.deadline_miss_by_slo[r.slo] = \
                sess.deadline_miss_by_slo.get(r.slo, 0) + 1

    def _serve_chunk(self, sess: _Session, g: Dict[str, Any], gkey: Tuple,
                     reqs: List[ProxyRequest], b: int, start: float
                     ) -> float:
        """Serve ``reqs`` (≤ ``b`` requests of one structure) with the
        full resilience policy: retry with capped exponential backoff,
        bisect a repeatedly-failing multi-request chunk to isolate the
        poison request, degrade under an open breaker.  Records terminal
        accounting for every request; returns elapsed seconds."""
        breaker = self._breaker(sess, gkey)
        elapsed = 0.0
        chunk_attempt = 0
        while True:
            degraded = breaker.open
            if degraded and len(reqs) > 1:
                # open breaker: serve singly through the fallback path
                for r in reqs:
                    elapsed += self._serve_chunk(sess, g, gkey, [r], b,
                                                 start + elapsed)
                return elapsed
            valid = len(reqs)
            b_eff = 1 if degraded else b
            chunk = list(reqs)
            while len(chunk) < b_eff:    # fixed chunk size: pad by
                chunk.append(chunk[-1])  # repeating the last request
            self._storm(sess, chunk[:valid])
            for r in chunk[:valid]:
                sess.attempts[r.rid] = sess.attempts.get(r.rid, 0) + 1
            straggle = max((sess.faults.straggler_delay_s(r.rid)
                            for r in chunk[:valid]), default=0.0)
            elapsed += straggle          # delayed dispatch (both clocks)
            try:
                service, was_cold, outs = self._attempt(
                    sess, g, chunk, valid, b_eff, degraded)
            except Exception as exc:
                cls = classify_failure(exc)
                sess.failures += 1
                self.pool.record_failure(self.stack.exec_domain())
                breaker.record_failure()
                if sess.execute and cls not in ("injected",):
                    self._invalidate_executable(g["plan"], b_eff)
                if len(reqs) == 1:
                    r = reqs[0]
                    if cls == "fatal" \
                            or sess.attempts[r.rid] > self.max_retries:
                        sess.errors[r.rid] = f"{cls}: {exc}"
                        self._record(sess, r, start, start + elapsed,
                                     0.0, "failed")
                        return elapsed
                elif chunk_attempt >= 1 or cls == "fatal":
                    # chunk failed again (or can never succeed as-is):
                    # bisect to isolate the poison request instead of
                    # failing the whole batch
                    mid = max(1, len(reqs) // 2)
                    elapsed += self._serve_chunk(sess, g, gkey, reqs[:mid],
                                                 b, start + elapsed)
                    elapsed += self._serve_chunk(sess, g, gkey, reqs[mid:],
                                                 b, start + elapsed)
                    return elapsed
                backoff = min(self.backoff_base_s * (2 ** chunk_attempt),
                              self.backoff_cap_s)
                elapsed += backoff
                sess.retries += 1
                chunk_attempt += 1
                continue
            # success
            breaker.record_success()
            sess.dispatches += 1
            if degraded:
                sess.degraded_dispatches += 1
            if was_cold:
                sess.cold_dispatches += 1
                sess.compile_s += service
            elapsed += service
            done_t = start + elapsed
            for j, r in enumerate(chunk[:valid]):
                status = ("degraded" if degraded
                          else "retried" if sess.attempts[r.rid] > 1
                          else "ok")
                self._record(sess, r, start, done_t, service, status)
                if outs:
                    sess.results[r.rid] = outs[j]
            return elapsed

    def _serve_batch(self, sess: _Session, g: Dict[str, Any], gkey: Tuple,
                     batch: List[ProxyRequest], b: int, start: float
                     ) -> float:
        """Serve one drained micro-batch: stratify by modeled cost so a
        chunk's vmapped lanes share a trip bound (cheap requests never
        wait out a straggler lane), then run each fixed-size chunk
        through the resilient dispatch path."""
        sess.batch_hist[len(batch)] = sess.batch_hist.get(len(batch), 0) + 1
        order = sorted(batch, key=lambda r: (sess.costs[r.rid], r.rid))
        elapsed = 0.0
        for c0 in range(0, len(order), b):
            elapsed += self._serve_chunk(sess, g, gkey, order[c0:c0 + b],
                                         b, start + elapsed)
        return elapsed

    # -- serving loop (trace replay, both clocks) ----------------------------

    def serve(self, trace: Union[ArrivalTrace, Sequence[ProxyRequest]],
              clock: str = "wall", mode: str = "open",
              faults: Optional[FaultPlan] = None) -> ServeReport:
        """Serve every request of ``trace`` and report the SLO metrics.

        ``clock="wall"`` executes and measures; ``clock="virtual"`` is the
        deterministic cost-model simulation (no execution, identical
        reports across runs — including under a ``faults`` plan).
        ``mode="open"`` admits requests at their trace arrival times;
        ``mode="closed"`` admits each request only when the previous
        completes (the sequential baseline — batch size is pinned to 1).
        ``faults`` overrides the engine's default fault plan for this
        run."""
        if clock not in ("wall", "virtual"):
            raise ValueError(f"clock must be 'wall' or 'virtual', "
                             f"got {clock!r}")
        if mode not in ("open", "closed"):
            raise ValueError(f"mode must be 'open' or 'closed', "
                             f"got {mode!r}")
        requests = list(trace.requests if isinstance(trace, ArrivalTrace)
                        else trace)
        execute = clock == "wall"
        closed = mode == "closed"
        sess = _Session(execute=execute, closed=closed,
                        faults=self.faults if faults is None else faults)

        # group requests by compiled identity; model per-request costs
        # once (the stratification and virtual-service key)
        groups: Dict[Tuple, Dict[str, Any]] = {}
        gkey_of: Dict[int, Tuple] = {}
        for r in requests:
            gkey = self._group_for(groups, r)
            gkey_of[r.rid] = gkey
            groups[gkey]["remaining"] += 1
            sess.costs[r.rid] = self._cost_of(groups[gkey]["plan"], r)

        monitor = ResourceMonitor()
        monitor.start()

        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        first_arrival = pending[0].arrival_s if pending else 0.0
        b = 1 if closed else self._chunk_size()
        max_batch = 1 if closed else self.max_batch
        wait = 0.0 if closed else self.batch_wait_s

        now = first_arrival
        i_next = 0

        def admit(t: float) -> None:
            nonlocal i_next
            while (i_next < len(pending)
                   and pending[i_next].arrival_s <= t + 1e-12):
                r = pending[i_next]
                i_next += 1
                g = groups[gkey_of[r.rid]]
                g["queue"].append(r)
                g["remaining"] -= 1

        def urgency(k: Tuple) -> Tuple:
            head = groups[k]["queue"][0]
            return (head.abs_deadline, head.arrival_s, head.rid)

        try:
            while i_next < len(pending) or any(g["queue"]
                                               for g in groups.values()):
                if closed:
                    # closed loop: next request becomes ready the instant
                    # the previous completes — trace arrival is ignored
                    if not any(g["queue"] for g in groups.values()):
                        r = pending[i_next]
                        i_next += 1
                        g = groups[gkey_of[r.rid]]
                        g["queue"].append(r)
                        g["remaining"] -= 1
                else:
                    admit(now)
                nonempty = [k for k, g in groups.items() if g["queue"]]
                if not nonempty:
                    now = max(now, pending[i_next].arrival_s)
                    continue
                if wait > 0.0:
                    # partial-chunk flush policy: a lane is dispatchable
                    # when a full chunk waits, no future arrival can ever
                    # fill it, or its head has waited out the flush
                    # timeout — the P99 hostage bound
                    def ready(k: Tuple) -> bool:
                        g = groups[k]
                        return (len(g["queue"]) >= b
                                or g["remaining"] == 0
                                or now - g["queue"][0].arrival_s
                                >= wait - 1e-12)
                    ready_keys = [k for k in nonempty if ready(k)]
                    if not ready_keys:
                        flush_at = min(
                            groups[k]["queue"][0].arrival_s + wait
                            for k in nonempty)
                        next_arr = (pending[i_next].arrival_s
                                    if i_next < len(pending) else math.inf)
                        now = min(flush_at, next_arr)
                        continue
                else:
                    ready_keys = nonempty
                # drain the most urgent lane: earliest absolute deadline
                # first, oldest waiting head otherwise
                gkey = min(ready_keys, key=urgency)
                g = groups[gkey]
                if (wait > 0.0 and len(g["queue"]) < b
                        and g["remaining"] > 0
                        and now - g["queue"][0].arrival_s >= wait - 1e-12):
                    sess.timeout_flushes += 1
                k = min(max_batch, len(g["queue"]))
                batch = [g["queue"].popleft() for _ in range(k)]
                now += self._serve_batch(sess, g, gkey, batch, b, now)
        finally:
            # never leak the sampler thread, even on an exception
            resources = monitor.stop()
        return self._build_report(sess, requests, len(groups),
                                  first_arrival, now, clock, mode,
                                  resources)

    # -- report --------------------------------------------------------------

    def _build_report(self, sess: _Session,
                      requests: Sequence[ProxyRequest], n_groups: int,
                      first_arrival: float, end: float, clock: str,
                      mode: str, resources: Dict[str, float]
                      ) -> ServeReport:
        makespan = max(end - first_arrival, 0.0)
        n = len(requests)
        served = [r for r in requests if r.rid in sess.lat]
        lost = n - len(served)
        trips = sum(br.trips for br in sess.breakers.values())
        mix: Dict[str, int] = {}
        templates: Dict[str, Any] = {}
        for r in requests:
            mix[r.structure] = mix.get(r.structure, 0) + 1
            if r.dag is not None:
                templates.setdefault(r.structure, r.dag)
        return ServeReport(
            stack=self.stack.name, clock=clock, mode=mode, n_requests=n,
            structures=n_groups,
            makespan_s=makespan,
            throughput_rps=n / max(makespan, 1e-12),
            time_to_first_result_s=(sess.first_done - first_arrival
                                    if sess.first_done is not None else 0.0),
            latency_s=_percentiles([sess.lat[r.rid] for r in served]),
            queue_wait_s=_percentiles([sess.qwait[r.rid] for r in served]),
            service_s=_percentiles([sess.svc[r.rid] for r in served]),
            batch_hist=sess.batch_hist,
            dispatches=sess.dispatches,
            cold_dispatches=sess.cold_dispatches,
            compile_s=sess.compile_s,
            retraces=(CACHE_STATS["traces"] - sess.traces0
                      if sess.execute else 0),
            resources=resources,
            failures=sess.failures,
            retries=sess.retries,
            deadline_misses=sess.deadline_misses,
            deadline_miss_by_slo=dict(sess.deadline_miss_by_slo),
            degraded_dispatches=sess.degraded_dispatches,
            breaker_trips=trips,
            timeout_flushes=sess.timeout_flushes,
            lost_requests=lost,
            statuses=[sess.statuses.get(r.rid, "lost") for r in requests],
            fault_plan=sess.faults.summary(),
            structure_mix=mix,
            templates=templates,
            results=[sess.results.get(r.rid) for r in requests])

    # -- live submission (start / submit / drain / shutdown) -----------------

    def start(self) -> "ServingEngine":
        """Start the long-lived dispatcher: after this, concurrent
        threads may :meth:`submit` requests; the grouping loop serves
        them with the same micro-batching, flush, and resilience policy
        as trace replay.  Returns ``self`` for chaining."""
        if self._live is not None:
            raise RuntimeError("ServingEngine is already started")
        live = _LiveState(self)
        self._live = live
        live.monitor.start()
        live.thread = threading.Thread(target=self._live_loop, daemon=True)
        live.thread.start()
        return self

    def submit(self, request: Optional[ProxyRequest] = None, *,
               structure: Optional[str] = None,
               rng: Optional[jax.Array] = None,
               deadline_s: Optional[float] = None,
               slo: str = "standard") -> "Future":
        """Thread-safe live admission; returns a ``Future`` resolving to
        the request's host result (or raising its terminal error).

        Pass an explicit :class:`ProxyRequest` (its ``rid``/``arrival_s``
        are re-stamped by the engine), or name a ``structure`` from
        ``PROXY_SPECS`` to have the engine draw that proxy's dynamic
        params deterministically from the assigned rid."""
        live = self._live
        if live is None:
            raise RuntimeError("ServingEngine.submit before start(); call "
                               "start() (and ideally warmup()) first")
        with live.cond:
            if live.stopping:
                raise RuntimeError("ServingEngine is shutting down")
            rid = live.next_rid
            live.next_rid += 1
            arrival = time.perf_counter() - live.t0
            if request is None:
                if structure is None:
                    raise TypeError("submit() needs a ProxyRequest or a "
                                    "structure= spec name")
                tmpl = self._template(structure)
                request = _make_request(rid, tmpl, seed=0, arrival=arrival,
                                        deadline_s=deadline_s, slo=slo)
            else:
                request = dataclasses.replace(
                    request, rid=rid, arrival_s=arrival,
                    deadline_s=(request.deadline_s if deadline_s is None
                                else deadline_s),
                    slo=slo if slo != "standard" else request.slo)
            if rng is not None:
                request = dataclasses.replace(request, rng=rng)
            gkey = self._group_for(live.groups, request)
            g = live.groups[gkey]
            sess = live.session
            sess.costs[rid] = self._cost_of(g["plan"], request)
            fut: Future = Future()
            live.futures[rid] = fut
            live.inflight += 1
            if live.first_arrival is None:
                live.first_arrival = arrival
            g["queue"].append(request)
            live.cond.notify_all()
        return fut

    def _template(self, structure: str):
        cache = self.__dict__.setdefault("_template_cache", {})
        if structure not in cache:
            cache[structure] = _templates((structure,))[0]
        return cache[structure]

    def _live_loop(self) -> None:
        live = self._live
        sess = live.session
        b = self._chunk_size()
        wait = self.batch_wait_s

        while True:
            with live.cond:
                batch: List[ProxyRequest] = []
                gkey = None
                while True:
                    now = time.perf_counter() - live.t0
                    nonempty = [k for k, g in live.groups.items()
                                if g["queue"]]
                    if nonempty:
                        def ready(k: Tuple) -> bool:
                            g = live.groups[k]
                            return (wait <= 0.0 or live.stopping
                                    or len(g["queue"]) >= b
                                    or now - g["queue"][0].arrival_s
                                    >= wait - 1e-12)
                        ready_keys = [k for k in nonempty if ready(k)]
                        if ready_keys:
                            gkey = min(
                                ready_keys,
                                key=lambda k: (
                                    live.groups[k]["queue"][0].abs_deadline,
                                    live.groups[k]["queue"][0].arrival_s,
                                    live.groups[k]["queue"][0].rid))
                            g = live.groups[gkey]
                            if (wait > 0.0 and len(g["queue"]) < b
                                    and not live.stopping):
                                sess.timeout_flushes += 1
                            k = min(self.max_batch, len(g["queue"]))
                            batch = [g["queue"].popleft()
                                     for _ in range(k)]
                            break
                        flush_in = min(
                            live.groups[k]["queue"][0].arrival_s + wait
                            - now for k in nonempty)
                        live.cond.wait(max(min(flush_in, 0.05), 1e-4))
                        continue
                    if live.stopping:
                        return
                    live.cond.wait(0.05)
            start = time.perf_counter() - live.t0
            try:
                elapsed = self._serve_batch(sess, live.groups[gkey], gkey,
                                            batch, b, start)
            except BaseException as exc:  # defense in depth: the batch
                elapsed = 0.0             # path handles its own failures
                for r in batch:
                    sess.errors[r.rid] = f"dispatcher: {exc}"
                    self._record(sess, r, start, start, 0.0, "failed")
            with live.cond:
                live.last_done = max(live.last_done, start + elapsed)
                for r in batch:
                    fut = live.futures.pop(r.rid, None)
                    if fut is not None:
                        if sess.statuses.get(r.rid) == "failed":
                            fut.set_exception(RuntimeError(
                                sess.errors.get(r.rid,
                                                "request failed")))
                        else:
                            fut.set_result(sess.results.get(r.rid))
                    live.inflight -= 1
                live.cond.notify_all()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request has resolved (queues empty
        and no dispatch in flight).  Returns False on timeout."""
        live = self._live
        if live is None:
            return True
        with live.cond:
            return live.cond.wait_for(
                lambda: live.inflight == 0
                and not any(g["queue"] for g in live.groups.values()),
                timeout=timeout)

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> ServeReport:
        """Stop the dispatcher and return the live session's
        :class:`ServeReport`.  ``drain=True`` (default) serves everything
        already submitted first; ``drain=False`` fails pending requests'
        futures immediately.  The resource monitor is always joined —
        shutdown never leaks the sampler thread."""
        live = self._live
        if live is None:
            raise RuntimeError("ServingEngine.shutdown without start()")
        if drain:
            self.drain(timeout=timeout)
        with live.cond:
            live.stopping = True
            if not drain:
                for g in live.groups.values():
                    while g["queue"]:
                        r = g["queue"].popleft()
                        fut = live.futures.pop(r.rid, None)
                        if fut is not None:
                            fut.set_exception(
                                RuntimeError("engine shut down before "
                                             "dispatch"))
                        live.inflight -= 1
                        live.session.statuses.setdefault(r.rid, "failed")
                        live.session.errors[r.rid] = "shutdown"
            live.cond.notify_all()
        try:
            if live.thread is not None:
                live.thread.join(timeout=10.0)
        finally:
            resources = live.monitor.stop()
            self._live = None
        sess = live.session
        requests: List[ProxyRequest] = []
        # statuses/latencies index by rid; rebuild the admitted order
        for rid in range(live.next_rid):
            requests.append(ProxyRequest(
                rid=rid, structure="", dag=None, dyn=None, rng=None,
                arrival_s=0.0))
        first = live.first_arrival if live.first_arrival is not None else 0.0
        return self._build_report(sess, requests, len(live.groups),
                                  first, live.last_done, "wall", "live",
                                  resources)


# ---------------------------------------------------------------------------
# public entry point (repro.api.serve)
# ---------------------------------------------------------------------------


def serve(trace: Union[ArrivalTrace, Sequence[ProxyRequest]], *,
          stack: Union[str, Stack] = "openmp", clock: str = "wall",
          mode: str = "open", max_batch: int = 8,
          bucket_size: Optional[int] = None,
          batch_wait_s: float = 0.0,
          faults: Optional[FaultPlan] = None,
          warmup: bool = True, **engine_kw) -> ServeReport:
    """Serve a request stream end to end: build a :class:`ServingEngine`
    on ``stack``, optionally pre-compile the trace's working set, and
    return the :class:`ServeReport`.  ``faults`` injects a seeded
    :class:`~repro.faults.FaultPlan`; ``batch_wait_s`` sets the
    partial-chunk flush policy; other keyword args reach the engine
    (retry/backoff/breaker knobs)."""
    eng = ServingEngine(stack=stack, max_batch=max_batch,
                        bucket_size=bucket_size,
                        batch_wait_s=batch_wait_s, faults=faults,
                        **engine_kw)
    if warmup and clock == "wall":
        eng.warmup(trace)
    return eng.serve(trace, clock=clock, mode=mode)
