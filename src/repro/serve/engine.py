"""Proxy serving engine: concurrent request streams with tail-latency SLOs.

The paper's proxies stand in for production big-data services, and Gao et
al. (arXiv 1802.00699) frame dwarf proxies explicitly as *service-level*
workload mimics — but a benchmark that only ever executes one proxy at a
time cannot report the metrics a service is judged by: latency
percentiles under load, time to first result, sustained throughput.  This
module closes that gap on top of the compile-once/run-many machinery:

* A request **queue** admits heterogeneous :class:`ProxyRequest`\\ s (any
  structure + per-request dynamic params + per-request rng) and groups
  them by compiled identity — ``(stack, plan.structure_key())`` — into
  per-structure FIFO lanes.
* The dispatch loop drains the lane with the oldest waiting head into a
  **micro-batch** (up to ``max_batch`` requests), stratifies it by the
  engine cost model, and executes it in fixed-size chunks through the
  stack's cached serve executables (``Stack._compiled_plan_serve`` — one
  vmapped call per chunk, every request its own rng/params lane).  Chunk
  sizes never vary (the tail pads by repeating its last request), so
  steady-state serving is **zero retraces**, at most one compile per new
  (structure, chunk size) — and :meth:`ServingEngine.warmup` pre-pays
  even those through the :class:`~repro.core.pool.ExecutablePool`.
* Every request's queue wait, service time and total latency are
  recorded; the :class:`ServeReport` emits P50/P95/P99, time to first
  result, sustained throughput, the micro-batch histogram, cold-dispatch
  accounting and a :class:`ResourceMonitor` host/device-memory summary.

Two clocks make runs comparable and CI-gateable:

* ``clock="wall"`` executes for real; service times are measured.
* ``clock="virtual"`` never executes — service times come from the
  engine's deterministic per-candidate cost model
  (:meth:`ExecutionPlan.candidate_costs`), so the same trace yields
  bit-identical percentiles on any machine, any number of times.  The
  queue dynamics (admission order, grouping, batching) are exactly the
  wall-clock loop's.

Arrival traces are seeded and deterministic: :func:`poisson_trace` (open
loop — arrivals don't wait for completions) and :func:`burst_trace`
(synchronized waves; ``bursts=1`` is the capacity test where everything
arrives at once).  ``mode="closed"`` serves any trace closed-loop: each
request is admitted only when the previous one completes — the
sequential baseline micro-batching is judged against.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..api.stack import Stack, get_stack, CACHE_STATS
from ..core import schedule as plans
from ..core.dag import ProxyDAG
from ..core.pool import ExecutablePool, get_pool

#: virtual-clock calibration: modeled cost units (flops + vpu + bytes)
#: retired per second, plus a fixed per-dispatch overhead — the absolute
#: scale is arbitrary; percentile *structure* under the queueing dynamics
#: is what the deterministic clock exists for
VIRTUAL_RATE = 5.0e10
VIRTUAL_OVERHEAD_S = 2.0e-4


# ---------------------------------------------------------------------------
# requests + traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ProxyRequest:
    """One admission: a proxy structure, its dynamic params, its rng."""

    rid: int                   # position in the trace (result ordering)
    structure: str             # spec name / label (reporting only)
    dag: ProxyDAG              # shared per-structure template
    dyn: Any                   # unbatched dynamic_params()-shaped pytree
    rng: jax.Array
    arrival_s: float           # arrival offset from trace start


@dataclasses.dataclass
class ArrivalTrace:
    """A deterministic, seeded request stream."""

    name: str
    seed: int
    requests: List[ProxyRequest]

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)

    @property
    def structures(self) -> List[str]:
        return sorted({r.structure for r in self.requests})

    def unique_dags(self) -> List[ProxyDAG]:
        """One template per distinct structure — the warmup working set."""
        seen, dags = set(), []
        for r in self.requests:
            key = r.dag.canonical_structure_key()
            if key not in seen:
                seen.add(key)
                dags.append(r.dag)
        return dags


def _templates(mix: Optional[Sequence[str]]):
    """(name, dag, space, base_values) per spec in the request mix."""
    from ..api.params import ParamSpace
    from ..api.spec import ProxySpec
    from ..core.workloads import PROXY_SPECS
    names = tuple(mix) if mix else tuple(sorted(PROXY_SPECS))
    out = []
    for name in names:
        if name not in PROXY_SPECS:
            raise KeyError(f"unknown proxy spec {name!r}; known: "
                           f"{sorted(PROXY_SPECS)}")
        dag = ProxySpec.from_json(PROXY_SPECS[name]).to_benchmark().dag
        space = ParamSpace.from_dag(dag)
        out.append((name, dag, space, space.values(dag)))
    return out


def _make_request(i: int, tmpl, seed: int, arrival: float) -> ProxyRequest:
    name, dag, space, base = tmpl
    row = space.sample_dynamic(1, base, seed=seed + 7919 * i)[0]
    dynb = space.stack_candidates(dag, row[None])
    dyn = jax.tree_util.tree_map(lambda v: v[0], dynb)
    return ProxyRequest(
        rid=i, structure=name, dag=dag, dyn=dyn,
        rng=jax.random.fold_in(jax.random.PRNGKey(seed), i),
        arrival_s=float(arrival))


def poisson_trace(n: int = 32, rate_rps: float = 100.0, seed: int = 0,
                  mix: Optional[Sequence[str]] = None) -> ArrivalTrace:
    """Open-loop Poisson arrivals at ``rate_rps``, request mix drawn
    uniformly from ``mix`` (default: every ``PROXY_SPECS`` proxy), every
    request's dynamic params independently sampled from its structure's
    :class:`~repro.api.params.ParamSpace` — all under one seed, so the
    trace is bit-reproducible across processes and machines."""
    rs = np.random.RandomState(seed)
    arrivals = np.cumsum(rs.exponential(1.0 / max(rate_rps, 1e-9), size=n))
    tmpl = _templates(mix)
    picks = rs.randint(0, len(tmpl), size=n)
    return ArrivalTrace(
        name=f"poisson:n={n}:rate={rate_rps:g}:seed={seed}", seed=seed,
        requests=[_make_request(i, tmpl[picks[i]], seed, arrivals[i])
                  for i in range(n)])


def burst_trace(n: int = 32, bursts: int = 4, period_s: float = 0.05,
                seed: int = 0,
                mix: Optional[Sequence[str]] = None) -> ArrivalTrace:
    """Synchronized arrival waves: ``n`` requests split evenly across
    ``bursts`` bursts ``period_s`` apart (every member of a burst arrives
    at the same instant — the tail-latency stressor Poisson smoothing
    hides).  ``bursts=1`` is the capacity trace: everything at t=0."""
    rs = np.random.RandomState(seed)
    tmpl = _templates(mix)
    picks = rs.randint(0, len(tmpl), size=n)
    per = max(1, -(-n // max(bursts, 1)))        # ceil split
    return ArrivalTrace(
        name=f"burst:n={n}:bursts={bursts}:seed={seed}", seed=seed,
        requests=[_make_request(i, tmpl[picks[i]], seed,
                                (i // per) * period_s)
                  for i in range(n)])


# ---------------------------------------------------------------------------
# resource monitor
# ---------------------------------------------------------------------------


def _page_size() -> int:
    try:
        return os.sysconf("SC_PAGE_SIZE")
    except (AttributeError, ValueError, OSError):  # pragma: no cover
        return 4096


class ResourceMonitor(threading.Thread):
    """Daemon thread sampling host RSS (``/proc/self/statm``) and device
    memory (``Device.memory_stats``, where the backend exposes it) while a
    serve runs — no psutil dependency, negligible overhead."""

    def __init__(self, interval_s: float = 0.005):
        super().__init__(daemon=True)
        self.interval_s = interval_s
        self._halt = threading.Event()
        self.host_rss: List[int] = []
        self.device_bytes: List[int] = []

    def _sample(self) -> None:
        try:
            with open("/proc/self/statm") as f:
                self.host_rss.append(
                    int(f.read().split()[1]) * _page_size())
        except (OSError, ValueError, IndexError):  # pragma: no cover
            pass
        try:
            ms = jax.local_devices()[0].memory_stats()
            if ms and "bytes_in_use" in ms:
                self.device_bytes.append(int(ms["bytes_in_use"]))
        except Exception:           # CPU backends expose no memory_stats
            pass

    def run(self) -> None:
        while not self._halt.is_set():
            self._sample()
            self._halt.wait(self.interval_s)

    def stop(self) -> Dict[str, float]:
        self._halt.set()
        self.join(timeout=2.0)
        self._sample()              # at least one sample, however short
        out: Dict[str, float] = {
            "samples": float(len(self.host_rss)),
            "host_rss_peak_bytes": float(max(self.host_rss, default=0)),
            "host_rss_mean_bytes": float(np.mean(self.host_rss))
            if self.host_rss else 0.0,
        }
        if self.device_bytes:
            out["device_peak_bytes"] = float(max(self.device_bytes))
        return out


# ---------------------------------------------------------------------------
# ServeReport
# ---------------------------------------------------------------------------


def _percentiles(xs: Sequence[float]) -> Dict[str, float]:
    if not xs:
        return {k: 0.0 for k in ("p50", "p95", "p99", "mean", "max")}
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()), "max": float(a.max())}


@dataclasses.dataclass
class ServeReport:
    """Uniform result of one served trace — the SLO surface."""

    stack: str
    clock: str                      # "wall" | "virtual"
    mode: str                       # "open" | "closed"
    n_requests: int
    structures: int                 # distinct compiled groups served
    makespan_s: float               # first arrival -> last completion
    throughput_rps: float           # n_requests / makespan
    time_to_first_result_s: float
    latency_s: Dict[str, float]     # p50/p95/p99/mean/max end-to-end
    queue_wait_s: Dict[str, float]  # arrival -> dispatch start
    service_s: Dict[str, float]     # dispatch chunk execution
    batch_hist: Dict[int, int]      # micro-batch size -> dispatch count
    dispatches: int                 # executable calls (chunks)
    cold_dispatches: int            # chunks that compiled first
    compile_s: float                # wall time of cold chunks (compile-
                                    # inclusive service; 0 when warm)
    retraces: int                   # CACHE_STATS trace delta (wall clock)
    resources: Dict[str, float]
    #: per-request host results in trace order (bit-identity checks);
    #: empty under the virtual clock
    results: List[Any] = dataclasses.field(default_factory=list, repr=False)

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.pop("results")
        d["batch_hist"] = {str(k): v
                           for k, v in sorted(self.batch_hist.items())}
        return d


# ---------------------------------------------------------------------------
# ServingEngine
# ---------------------------------------------------------------------------


class ServingEngine:
    """Continuous micro-batching over one software stack.

    ``max_batch`` bounds how many same-structure requests one dispatch
    drains; ``bucket_size`` pins the executable chunk size (default: the
    population policy — one lane per device, so a single-device CPU
    serves unbatched parametric calls and a mesh fills its device axis).
    All compiled artifacts live in the shared :class:`ExecutablePool`;
    :meth:`warmup` pre-compiles a declared working set so the first
    request is served warm."""

    def __init__(self, stack: Union[str, Stack] = "openmp",
                 max_batch: int = 8, bucket_size: Optional[int] = None,
                 pool: Optional[ExecutablePool] = None):
        self.stack = get_stack(stack) if isinstance(stack, str) else stack
        self.max_batch = max(1, int(max_batch))
        self.bucket_size = bucket_size
        self.pool = pool if pool is not None else get_pool()

    # -- sizing --------------------------------------------------------------

    def _chunk_size(self) -> int:
        """The fixed executable chunk size.  Fixed — never shrunk to a
        small batch (tails pad instead) — so the steady state needs
        exactly one executable per (structure, size)."""
        if self.bucket_size is not None:
            return max(1, min(int(self.bucket_size), self.max_batch))
        return max(1, min(plans.resolve_bucket_size(self.max_batch),
                          self.max_batch))

    # -- warmup --------------------------------------------------------------

    def warmup(self, specs, bucket_sizes: Optional[Tuple[int, ...]] = None
               ) -> Dict[str, int]:
        """Pre-compile the working set: every distinct structure in
        ``specs`` (an :class:`ArrivalTrace`, request list, or
        DAG/spec iterable) at this engine's chunk sizes — after which a
        serve of those structures starts at zero retraces."""
        if isinstance(specs, ArrivalTrace):
            specs = specs.unique_dags()
        else:
            specs = list(specs)
            if specs and isinstance(specs[0], ProxyRequest):
                specs = ArrivalTrace("adhoc", 0, specs).unique_dags()
        if bucket_sizes is None:
            bucket_sizes = (1, self._chunk_size())
        return self.pool.warmup(specs, stack=self.stack,
                                bucket_sizes=bucket_sizes)

    # -- dispatch ------------------------------------------------------------

    def _dispatch(self, plan, chunk: List[ProxyRequest], valid: int,
                  b: int, execute: bool,
                  costs: Dict[int, float]) -> Tuple[float, bool, List]:
        """Execute (or, under the virtual clock, model) one fixed-size
        chunk.  Returns ``(service_s, was_cold, per-request results)``."""
        stack = self.stack
        if not execute:
            service = (max(costs[r.rid] for r in chunk[:valid])
                       / VIRTUAL_RATE + VIRTUAL_OVERHEAD_S)
            return service, False, []
        m0 = stack.exec_domain().stats["misses"]
        t0 = time.perf_counter()
        if b == 1:
            fn = stack._compiled_plan(plan, batch=False)
            r = chunk[0]
            # copy the dyn scalars: the batch=False form donates its dyn
            # buffers on accelerators, and a trace may be replayed
            dyn = jax.tree_util.tree_map(jnp.array, r.dyn)
            out, _ = stack._population_call(fn, r.rng, dyn)
        else:
            fn = stack._compiled_plan_serve(plan, b)
            rngs = jnp.stack([r.rng for r in chunk])
            dynb = jax.tree_util.tree_map(
                lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                *[r.dyn for r in chunk])
            out = stack._serve_call(fn, rngs, dynb)
        jax.block_until_ready(out)
        service = time.perf_counter() - t0
        was_cold = stack.exec_domain().stats["misses"] > m0
        host = np.asarray(out)
        results = ([host] if b == 1
                   else [host[j] for j in range(valid)])
        return service, was_cold, results

    # -- serving loop --------------------------------------------------------

    def serve(self, trace: Union[ArrivalTrace, Sequence[ProxyRequest]],
              clock: str = "wall", mode: str = "open") -> ServeReport:
        """Serve every request of ``trace`` and report the SLO metrics.

        ``clock="wall"`` executes and measures; ``clock="virtual"`` is the
        deterministic cost-model simulation (no execution, identical
        reports across runs).  ``mode="open"`` admits requests at their
        trace arrival times; ``mode="closed"`` admits each request only
        when the previous completes (the sequential baseline — batch size
        is pinned to 1)."""
        if clock not in ("wall", "virtual"):
            raise ValueError(f"clock must be 'wall' or 'virtual', "
                             f"got {clock!r}")
        if mode not in ("open", "closed"):
            raise ValueError(f"mode must be 'open' or 'closed', "
                             f"got {mode!r}")
        requests = list(trace.requests if isinstance(trace, ArrivalTrace)
                        else trace)
        execute = clock == "wall"
        closed = mode == "closed"
        stack = self.stack

        # group requests by compiled identity; model per-request costs
        # once (the stratification and virtual-service key)
        groups: Dict[Tuple, Dict[str, Any]] = {}
        gkey_of: Dict[int, Tuple] = {}
        costs: Dict[int, float] = {}
        for r in requests:
            plan = plans.lower_population(r.dag)
            gkey = (stack.name, plan.structure_key())
            if gkey not in groups:
                groups[gkey] = {"plan": plan, "queue": deque()}
            gkey_of[r.rid] = gkey
            dynb1 = jax.tree_util.tree_map(
                lambda v: np.asarray(v)[None], r.dyn)
            c, _ = plan.candidate_costs(dynb1)
            costs[r.rid] = float(c[0])

        monitor = ResourceMonitor()
        monitor.start()
        traces0 = CACHE_STATS["traces"]

        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        first_arrival = pending[0].arrival_s if pending else 0.0
        b = 1 if closed else self._chunk_size()
        max_batch = 1 if closed else self.max_batch

        lat: Dict[int, float] = {}
        qwait: Dict[int, float] = {}
        svc: Dict[int, float] = {}
        results: Dict[int, Any] = {}
        batch_hist: Dict[int, int] = {}
        dispatches = cold_dispatches = 0
        compile_s = 0.0
        first_done: Optional[float] = None
        now = first_arrival
        i_next = 0

        def admit(t: float) -> None:
            nonlocal i_next
            while (i_next < len(pending)
                   and pending[i_next].arrival_s <= t + 1e-12):
                r = pending[i_next]
                i_next += 1
                groups[gkey_of[r.rid]]["queue"].append(r)

        while i_next < len(pending) or any(g["queue"]
                                           for g in groups.values()):
            if closed:
                # closed loop: next request becomes ready the instant the
                # previous completes — its trace arrival is ignored
                if not any(g["queue"] for g in groups.values()):
                    r = pending[i_next]
                    i_next += 1
                    groups[gkey_of[r.rid]]["queue"].append(r)
            else:
                admit(now)
                if not any(g["queue"] for g in groups.values()):
                    now = max(now, pending[i_next].arrival_s)
                    continue
            # drain the lane whose head has waited longest
            gkey = min(
                (k for k, g in groups.items() if g["queue"]),
                key=lambda k: (groups[k]["queue"][0].arrival_s,
                               groups[k]["queue"][0].rid))
            g = groups[gkey]
            k = min(max_batch, len(g["queue"]))
            batch = [g["queue"].popleft() for _ in range(k)]
            batch_hist[k] = batch_hist.get(k, 0) + 1
            start = now
            # stratify by modeled cost so a chunk's vmapped lanes share a
            # trip bound (cheap requests never wait out a straggler lane)
            order = sorted(batch, key=lambda r: (costs[r.rid], r.rid))
            service_acc = 0.0
            for c0 in range(0, len(order), b):
                chunk = order[c0:c0 + b]
                valid = len(chunk)
                while len(chunk) < b:        # fixed chunk size: pad by
                    chunk.append(chunk[-1])  # repeating the last request
                service, was_cold, outs = self._dispatch(
                    g["plan"], chunk, valid, b, execute, costs)
                dispatches += 1
                if was_cold:
                    cold_dispatches += 1
                    compile_s += service
                service_acc += service
                done_t = start + service_acc
                if first_done is None:
                    first_done = done_t
                for j, r in enumerate(chunk[:valid]):
                    qwait[r.rid] = start - (r.arrival_s
                                            if not closed else start)
                    svc[r.rid] = service
                    lat[r.rid] = done_t - (r.arrival_s
                                           if not closed else start)
                    if outs:
                        results[r.rid] = outs[j]
            now = start + service_acc

        resources = monitor.stop()
        makespan = max(now - first_arrival, 0.0)
        n = len(requests)
        return ServeReport(
            stack=stack.name, clock=clock, mode=mode, n_requests=n,
            structures=len(groups),
            makespan_s=makespan,
            throughput_rps=n / max(makespan, 1e-12),
            time_to_first_result_s=(first_done - first_arrival
                                    if first_done is not None else 0.0),
            latency_s=_percentiles([lat[r.rid] for r in requests]),
            queue_wait_s=_percentiles([qwait[r.rid] for r in requests]),
            service_s=_percentiles([svc[r.rid] for r in requests]),
            batch_hist=batch_hist,
            dispatches=dispatches,
            cold_dispatches=cold_dispatches,
            compile_s=compile_s,
            retraces=CACHE_STATS["traces"] - traces0 if execute else 0,
            resources=resources,
            results=[results.get(r.rid) for r in requests])


# ---------------------------------------------------------------------------
# public entry point (repro.api.serve)
# ---------------------------------------------------------------------------


def serve(trace: Union[ArrivalTrace, Sequence[ProxyRequest]], *,
          stack: Union[str, Stack] = "openmp", clock: str = "wall",
          mode: str = "open", max_batch: int = 8,
          bucket_size: Optional[int] = None,
          warmup: bool = True) -> ServeReport:
    """Serve a request stream end to end: build a :class:`ServingEngine`
    on ``stack``, optionally pre-compile the trace's working set, and
    return the :class:`ServeReport`."""
    eng = ServingEngine(stack=stack, max_batch=max_batch,
                        bucket_size=bucket_size)
    if warmup and clock == "wall":
        eng.warmup(trace)
    return eng.serve(trace, clock=clock, mode=mode)
