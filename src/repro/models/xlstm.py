"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan with exponential gating + stabilizer).

TPU adaptation: the mLSTM recurrence C_t = f_t C_{t-1} + i_t v_t k_t^T is
evaluated chunkwise — intra-chunk as a masked attention-like einsum (MXU),
inter-chunk as a ``lax.scan`` over the (B, H, hd, hd) matrix state — the
same restructuring used for Mamba (serial CUDA kernel -> chunked MXU form).
sLSTM keeps its inherently serial form (``lax.scan`` over time); its state
is O(B*d) so the step is VPU-bound and tiny.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .components import _dtype, dense_init

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd_x()
    ks = jax.random.split(rng, 6)
    return {
        "w_up": dense_init(ks[0], d, 2 * d, cfg),
        "wq": dense_init(ks[1], d, H * hd, cfg),
        "wk": dense_init(ks[2], d, H * hd, cfg),
        "wv": dense_init(ks[3], d, H * hd, cfg),
        "w_if": dense_init(ks[4], d, 2 * H, cfg),        # input/forget gates
        "w_out": dense_init(ks[5], d, d, cfg,
                            scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }


def _mlstm_chunk(carry, xs):
    """carry: (C, n, m): (B,H,hd,hd), (B,H,hd), (B,H).
    xs: q,k,v (B,L,H,hd); logi, logf (B,L,H) log-gates (fp32)."""
    C0, n0, m0 = carry
    q, k, v, li, lf = xs
    B, L, H, hd = q.shape
    csum_f = jnp.cumsum(lf, axis=1)                      # (B, L, H)
    # end-of-chunk stabilizer: max over local contributions
    #   exp(csum_f_L - csum_f_j + li_j) and the decayed carry exp(m0 + csum_f_L)
    local = csum_f[:, -1:] - csum_f + li                 # (B, L, H)
    m_new = jnp.maximum(jnp.max(local, axis=1), m0 + csum_f[:, -1])
    # intra-chunk attention-like term
    #   s_ij = q_i . k_j * exp(li_j + sum_{j<t<=i} lf_t - m_i*)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("blhd,bshd->bhls", qf, kf) * (hd ** -0.5)
    gate = (csum_f[:, :, None] - csum_f[:, None, :]
            + li[:, None, :])                            # (B, L_i, L_j, H)
    gate = jnp.moveaxis(gate, 3, 1)                      # (B, H, L, L)
    causal = jnp.tril(jnp.ones((L, L), bool))
    m_loc = jnp.max(jnp.where(causal, gate, -jnp.inf), axis=-1,
                    keepdims=True)                       # (B, H, L, 1)
    # running stabilizer per query position: max(local, decayed carry-in)
    m_run = jnp.maximum(m_loc[..., 0],
                        m0[:, :, None] + jnp.moveaxis(csum_f, 1, 2))
    w = jnp.where(causal, jnp.exp(gate - m_run[..., None]), 0.0)
    intra = jnp.einsum("bhls,bhls,bshd->blhd", scores, w, vf)
    norm_intra = jnp.einsum("bhls,bhls->blh", scores, w)       # signed q.n
    # inter-chunk: contribution of C0 decayed to each position
    decay = jnp.exp(m0[:, None] + csum_f - m_run.transpose(0, 2, 1))
    inter = jnp.einsum("blhd,bhde->blhe", qf, C0) * decay[..., None] \
        * (hd ** -0.5)
    norm_inter = jnp.einsum("blhd,bhd->blh", qf, n0) * decay * (hd ** -0.5)
    num = intra + inter
    # xLSTM normalizer: max(|q . n_t|, exp(-m_t)) with signed accumulation
    den = jnp.maximum(jnp.abs(norm_intra + norm_inter),
                      jnp.exp(-m_run.transpose(0, 2, 1)))
    y = num / den[..., None]
    # state update to end of chunk
    tail_f = csum_f[:, -1:, :] - csum_f                  # decay from t to L
    wgt = jnp.exp(tail_f + li - m_new[:, None])          # (B, L, H)
    C_new = jnp.exp(m0 + csum_f[:, -1] - m_new)[..., None, None] * C0 \
        + jnp.einsum("blh,blhd,blhe->bhde", wgt, kf, vf)
    n_new = jnp.exp(m0 + csum_f[:, -1] - m_new)[..., None] * n0 \
        + jnp.einsum("blh,blhd->bhd", wgt, kf)
    return (C_new, n_new, m_new), y


def mlstm_apply(p, x: jnp.ndarray, cfg: ArchConfig,
                state: Optional[Tuple] = None):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd_x()
    up = x @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)
    q = (u @ p["wq"]).reshape(B, S, H, hd)
    k = (u @ p["wk"]).reshape(B, S, H, hd)
    v = (u @ p["wv"]).reshape(B, S, H, hd)
    gates = (u @ p["w_if"]).astype(jnp.float32).reshape(B, S, 2, H)
    li = -jax.nn.softplus(-gates[:, :, 0])               # log sigmoid(i)
    lf = -jax.nn.softplus(-gates[:, :, 1])               # log sigmoid(f)
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state
    L = min(cfg.chunk, S)
    if S % L == 0 and S > 1:
        nch = S // L
        resh = lambda t: t.reshape(B, nch, L, *t.shape[2:]).swapaxes(0, 1)
        xs = (resh(q), resh(k), resh(v), resh(li), resh(lf))
        (CN, nN, mN), ys = jax.lax.scan(_mlstm_chunk, (C0, n0, m0), xs)
        y = ys.swapaxes(0, 1).reshape(B, S, H * hd)
    else:
        (CN, nN, mN), y = _mlstm_chunk((C0, n0, m0), (q, k, v, li, lf))
        y = y.reshape(B, S, H * hd)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out, (CN, nN, mN)


def mlstm_state_init(cfg: ArchConfig, batch: int):
    H, hd = cfg.n_heads, cfg.hd_x()
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd_x()
    k1, k2 = jax.random.split(rng)
    return {
        "w_x": dense_init(k1, d, 4 * d, cfg),            # z, i, f, o pre-acts
        "r_h": (jax.random.normal(k2, (H, hd, 4 * hd), jnp.float32)
                * (hd ** -0.5)).astype(_dtype(cfg)),     # block-diag recurrent
        "b": jnp.zeros((4 * d,), jnp.float32),
    }


def slstm_apply(p, x: jnp.ndarray, cfg: ArchConfig,
                state: Optional[Tuple] = None):
    """Sequential exponential-gated LSTM with normalizer/stabilizer state."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd_x()
    # w_x is row-parallel (see sharding rules): the product arrives as ONE
    # bf16 psum per layer and the sequential scan below runs collective-free
    pre = (x @ p["w_x"]).astype(jnp.float32) + p["b"]    # (B, S, 4d)
    if state is None:
        h0 = jnp.zeros((B, H, hd), jnp.float32)
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.ones((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        h0, c0, n0, m0 = state
    rh = p["r_h"].astype(jnp.float32)

    def step(carry, xt):
        h, c, n, m = carry                               # (B,H,hd) x3, (B,H)
        rec = jnp.einsum("bhd,hde->bhe", h, rh)          # (B, H, 4hd)
        zifo = xt.reshape(B, H, 4 * hd) + rec
        zz, ii, ff, oo = jnp.split(zifo, 4, axis=-1)
        z = jnp.tanh(zz)
        o = jax.nn.sigmoid(oo)
        log_i = jnp.mean(ii, -1)                         # per-head gate
        log_f = -jax.nn.softplus(-jnp.mean(ff, -1))
        m_new = jnp.maximum(log_f + m, log_i)
        i_s = jnp.exp(log_i - m_new)[..., None]
        f_s = jnp.exp(log_f + m - m_new)[..., None]
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    xs = jnp.moveaxis(pre, 1, 0)                         # (S, B, 4d)
    (hN, cN, nN, mN), ys = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d).astype(x.dtype)
    return y, (hN, cN, nN, mN)


def slstm_state_init(cfg: ArchConfig, batch: int):
    H, hd = cfg.n_heads, cfg.hd_x()
    return (jnp.zeros((batch, H, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.ones((batch, H, hd), jnp.float32),
            jnp.zeros((batch, H), jnp.float32))
