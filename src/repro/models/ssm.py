"""Mamba (S6) block — chunked selective scan, TPU-adapted.

The CUDA reference fuses the selective scan into one kernel over time; on TPU
we restructure as a *chunkwise* scan: ``lax.scan`` over sequence chunks with
the intra-chunk recurrence unrolled as a first-order linear recurrence in
log-space (cumulative products), which maps to VPU-friendly batched ops
instead of a serial per-step kernel (DESIGN.md §2 hardware adaptation).
State: (B, d_inner, d_state).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .components import _dtype, dense_init


def mamba_init(rng, cfg: ArchConfig) -> Dict:
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, cfg),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(_dtype(cfg)),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * st, cfg),
        "dt_proj": dense_init(ks[3], dt_rank, di, cfg),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, cfg,
                               scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d via shifted adds. x: (B, S, di); w: (K, di)."""
    K = w.shape[0]
    B, S, di = x.shape
    if state is None:
        hist = jnp.zeros((B, K - 1, di), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)             # (B, S+K-1, di)
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i: i + S] * w[i]
    new_state = xp[:, S:]                                # last K-1 inputs
    return jax.nn.silu(out), new_state


def _ssm_chunk(carry, xs, A):
    """One chunk of the selective scan.

    carry: h (B, di, st) fp32.  xs: dt (B, L, di), Bc (B, L, st),
    Cc (B, L, st), u (B, L, di).  Returns updated carry and y (B, L, di).

    h_t = a_t h_{t-1} + b_t solved with an intra-chunk associative scan on
    (a, b) pairs; all decay factors a = exp(-dt*A) are in (0, 1], so the
    parallel form is unconditionally stable (the naive divide-by-cumprod
    prefix trick overflows for long chunks).
    """
    h0 = carry
    dt, Bc, Cc, u = xs
    la = -jnp.einsum("bld,dn->bldn", dt, A)              # log a_t  (negative)
    a = jnp.exp(la)                                      # (B, L, di, st) <= 1
    b = jnp.einsum("bld,bln->bldn", dt * u, Bc)          # input injection

    def combine(left, right):
        la_, lb_ = left
        ra_, rb_ = right
        return la_ * ra_, ra_ * lb_ + rb_

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = aa * h0[:, None] + bb                            # (B, L, di, st)
    y = jnp.einsum("bldn,bln->bld", h, Cc)
    return h[:, -1], y


def mamba_apply(p, x: jnp.ndarray, cfg: ArchConfig,
                state: Optional[Tuple] = None):
    """x: (B, S, d).  state=(conv_state, ssm_state) enables decode mode.

    Returns (y, new_state); new_state is None when state is None and S is
    chunk-divisible training/prefill (stateless full-sequence mode returns
    the final state anyway — cheap and useful for prefill->decode handoff).
    """
    B, S, d = x.shape
    di, st = cfg.d_inner, cfg.ssm_state
    dt_rank = max(1, d // 16)
    conv_state = state[0] if state is not None else None
    h0 = (state[1].astype(jnp.float32) if state is not None
          else jnp.zeros((B, di, st), jnp.float32))

    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                     # (B, S, di)
    u, new_conv = _causal_conv(u, p["conv_w"], conv_state)
    proj = u @ p["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj.astype(jnp.float32),
                              [dt_rank, dt_rank + st], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])                 # (B, S, di)
    A = jnp.exp(p["A_log"])                              # (di, st) positive
    uf = u.astype(jnp.float32)

    L = min(cfg.chunk, S)
    if S % L == 0 and S > 1:
        nch = S // L
        resh = lambda t: t.reshape(B, nch, L, -1).swapaxes(0, 1)
        xs = (resh(dt), resh(Bc), resh(Cc), resh(uf))
        hN, ys = jax.lax.scan(lambda c, s: _ssm_chunk(c, s, A), h0, xs)
        y = ys.swapaxes(0, 1).reshape(B, S, di)
    else:                                                # decode / ragged
        hN, y = _ssm_chunk(h0, (dt, Bc, Cc, uf), A)
    y = y + uf * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, (new_conv, hN.astype(jnp.float32))


def mamba_state_init(cfg: ArchConfig, batch: int):
    return (jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), _dtype(cfg)),
            jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32))
