"""Unified LM: pattern-scanned layer stack covering all 10 architectures.

The layer stack is ``cfg.pattern`` repeated ``cfg.n_blocks`` times; block
params are stacked on axis 0 and the stack runs under ``jax.lax.scan`` (HLO
size O(pattern), compile time independent of depth — the profiler multiplies
costs by the known trip count).  Modality frontends (whisper audio conv,
qwen2-vl patches) are stubs: precomputed embeddings arrive as inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from .blocks import apply_layer, init_layer, init_layer_cache
from .components import _dtype, dense_init, rms_norm


class Model:
    def __init__(self, cfg: ArchConfig,
                 batch_axes: Optional[Tuple[str, ...]] = None):
        self.cfg = cfg
        # sharding propagation into scan/while bodies is unreliable (GSPMD
        # picked batch-replicated layouts in the layer loop); constraining
        # the residual stream once per block pins it down.
        self.batch_axes = batch_axes

    def _constrain(self, x: jnp.ndarray) -> jnp.ndarray:
        if not self.batch_axes:
            return x
        ba = self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
        spec = jax.sharding.PartitionSpec(ba, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)

    # -- params ---------------------------------------------------------------

    def init(self, rng: jax.Array) -> Dict:
        cfg = self.cfg
        n_keys = 4 + len(cfg.pattern) * cfg.n_blocks + cfg.encoder_layers
        keys = iter(jax.random.split(rng, n_keys + 4))
        params: Dict[str, Any] = {
            "embed": (jax.random.normal(next(keys), (cfg.vocab, cfg.d_model),
                                        jnp.float32) * 0.02
                      ).astype(_dtype(cfg)),
            "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(next(keys), cfg.d_model, cfg.vocab,
                                           cfg)
        blocks: Dict[str, Any] = {}
        for i, lt in enumerate(cfg.pattern):
            per_block = [init_layer(next(keys), lt, cfg)
                         for _ in range(cfg.n_blocks)]
            blocks[f"p{i}_{lt}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *per_block)
        params["blocks"] = blocks
        if cfg.encoder_layers:
            enc_blocks = [init_layer(next(keys), "attn_enc", cfg)
                          for _ in range(cfg.encoder_layers)]
            params["encoder"] = {
                "pos": (jax.random.normal(next(keys),
                                          (cfg.encoder_seq, cfg.d_model),
                                          jnp.float32) * 0.02
                        ).astype(_dtype(cfg)),
                "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
                "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
            }
        return params

    # -- encoder (whisper) ------------------------------------------------------

    def encode(self, params: Dict, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        enc = params["encoder"]
        x = frames + enc["pos"].astype(frames.dtype)

        def body(h, bp):
            h, _, _ = apply_layer("attn_enc", bp, self._constrain(h), cfg,
                                  positions=None, causal=False)
            return h, ()

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, enc["blocks"])
        return rms_norm(x, enc["final_ln"].astype(x.dtype))

    # -- full-sequence forward --------------------------------------------------

    def forward(self, params: Dict, tokens: jnp.ndarray, *,
                vision_embeds: Optional[jnp.ndarray] = None,
                mrope_positions: Optional[jnp.ndarray] = None,
                frames: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens: (B, S_text). Returns (logits, aux_loss)."""
        cfg = self.cfg
        x = params["embed"][tokens]                      # gather
        if vision_embeds is not None:                    # VLM prefix
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        x = self._constrain(x)
        B, S, _ = x.shape
        if cfg.mrope:
            positions = mrope_positions
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        enc_out = self.encode(params, frames) if frames is not None else None

        def block(h, bp):
            h = self._constrain(h)
            aux = jnp.zeros((), jnp.float32)
            for i, lt in enumerate(cfg.pattern):
                h, _, a = apply_layer(lt, bp[f"p{i}_{lt}"], h, cfg,
                                      positions=positions, enc_out=enc_out)
                aux = aux + a
            return self._constrain(h), aux

        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_saveable
                      if cfg.remat_policy == "dots" else None)
            block_fn = jax.checkpoint(block, policy=policy)
        else:
            block_fn = block
        x, auxs = jax.lax.scan(block_fn, x, params["blocks"])
        x = rms_norm(x, params["final_ln"].astype(x.dtype))
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = x @ head.astype(x.dtype)
        if cfg.logits_fp32:
            logits = logits.astype(jnp.float32)
        return logits, jnp.sum(auxs)

    # -- serving ------------------------------------------------------------------

    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        cache: Dict[str, Any] = {}
        for i, lt in enumerate(cfg.pattern):
            per_block = [init_layer_cache(lt, cfg, batch, max_seq, _dtype(cfg))
                         for _ in range(cfg.n_blocks)]
            cache[f"p{i}_{lt}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *per_block)
        return cache

    def _run_with_cache(self, params, x, cache, index, positions,
                        enc_out=None):
        cfg = self.cfg

        def block(h, xs):
            h = self._constrain(h)
            bp, bc = xs
            new_bc = {}
            aux = jnp.zeros((), jnp.float32)
            for i, lt in enumerate(cfg.pattern):
                key = f"p{i}_{lt}"
                h, nc, a = apply_layer(lt, bp[key], h, cfg,
                                       positions=positions, cache=bc[key],
                                       cache_index=index, enc_out=enc_out)
                new_bc[key] = nc
                aux = aux + a
            return h, (new_bc, aux)

        x, (new_cache, auxs) = jax.lax.scan(
            block, x, (params["blocks"], cache))
        x = rms_norm(x, params["final_ln"].astype(x.dtype))
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = x @ head.astype(x.dtype)
        if cfg.logits_fp32:
            logits = logits.astype(jnp.float32)
        return logits, new_cache

    def prefill(self, params: Dict, tokens: jnp.ndarray, cache: Dict, *,
                vision_embeds=None, mrope_positions=None, frames=None
                ) -> Tuple[jnp.ndarray, Dict]:
        """Fill the cache with S prompt tokens; logits for the last position."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        positions = (mrope_positions if cfg.mrope
                     else jnp.broadcast_to(jnp.arange(S)[None], (B, S)))
        enc_out = self.encode(params, frames) if frames is not None else None
        logits, cache = self._run_with_cache(params, x, cache, 0, positions,
                                             enc_out)
        return logits[:, -1:], cache

    def decode_step(self, params: Dict, tokens: jnp.ndarray, cache: Dict,
                    index: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
        """One token per sequence. tokens: (B, 1); index: scalar position."""
        cfg = self.cfg
        x = params["embed"][tokens]
        B = x.shape[0]
        if cfg.mrope:
            positions = jnp.broadcast_to(
                jnp.full((1, 1), 0, jnp.int32) + index, (B, 3, 1))
        else:
            positions = jnp.broadcast_to(index[None, None], (B, 1)
                                         ).astype(jnp.int32)
        return self._run_with_cache(params, x, cache, index, positions)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation) + step builders
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Dry-run inputs for (arch, shape): weak-type-correct, shardable."""
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    specs: Dict[str, Any] = {}
    s_text = S - cfg.vision_tokens if cfg.vision_tokens else S
    if shape.kind == "train":
        specs["tokens"] = sds((B, s_text), i32)
        specs["labels"] = sds((B, S), i32)
        if cfg.vision_tokens:
            specs["vision_embeds"] = sds((B, cfg.vision_tokens, cfg.d_model), dt)
            specs["mrope_positions"] = sds((B, 3, S), i32)
        if cfg.is_encdec:
            specs["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dt)
    elif shape.kind == "prefill":
        specs["tokens"] = sds((B, s_text), i32)
        if cfg.vision_tokens:
            specs["vision_embeds"] = sds((B, cfg.vision_tokens, cfg.d_model), dt)
            specs["mrope_positions"] = sds((B, 3, S), i32)
        if cfg.is_encdec:
            specs["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dt)
    else:  # decode
        specs["tokens"] = sds((B, 1), i32)
        specs["index"] = sds((), i32)
    return specs


def cache_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    model = Model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
