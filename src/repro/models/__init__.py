from .model import Model, cache_specs, input_specs

__all__ = ["Model", "cache_specs", "input_specs"]
