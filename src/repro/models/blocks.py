"""Layer-type registry: init / apply / cache-init per block layer type.

Types: attn, attn_moe, attn_enc, attn_cross, mamba, mamba_moe, mlstm, slstm.
An architecture is ``pattern`` (a tuple of types) repeated ``n_blocks`` times;
the stack scans over blocks with per-type params stacked on axis 0.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .components import (attention, attn_init, mlp_apply, mlp_init, moe_apply,
                         moe_init, rms_norm)
from .ssm import mamba_apply, mamba_init, mamba_state_init
from .xlstm import (mlstm_apply, mlstm_init, mlstm_state_init, slstm_apply,
                    slstm_init, slstm_state_init)


def _ln(cfg):
    return jnp.ones((cfg.d_model,), jnp.float32)


# -- init -------------------------------------------------------------------


def init_layer(rng, ltype: str, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(rng, 4)
    if ltype in ("attn", "attn_enc"):
        return {"ln1": _ln(cfg), "attn": attn_init(ks[0], cfg),
                "ln2": _ln(cfg), "mlp": mlp_init(ks[1], cfg)}
    if ltype == "attn_moe":
        return {"ln1": _ln(cfg), "attn": attn_init(ks[0], cfg),
                "ln2": _ln(cfg), "moe": moe_init(ks[1], cfg)}
    if ltype == "attn_cross":
        return {"ln1": _ln(cfg), "attn": attn_init(ks[0], cfg),
                "ln_x": _ln(cfg), "xattn": attn_init(ks[1], cfg),
                "ln2": _ln(cfg), "mlp": mlp_init(ks[2], cfg)}
    if ltype == "mamba":
        return {"ln1": _ln(cfg), "mamba": mamba_init(ks[0], cfg)}
    if ltype == "mamba_moe":
        return {"ln1": _ln(cfg), "mamba": mamba_init(ks[0], cfg),
                "ln2": _ln(cfg), "moe": moe_init(ks[1], cfg)}
    if ltype == "mlstm":
        return {"ln1": _ln(cfg), "mlstm": mlstm_init(ks[0], cfg)}
    if ltype == "slstm":
        return {"ln1": _ln(cfg), "slstm": slstm_init(ks[0], cfg)}
    raise ValueError(f"unknown layer type {ltype!r}")


# -- cache ------------------------------------------------------------------


def init_layer_cache(ltype: str, cfg: ArchConfig, batch: int,
                     max_seq: int, dtype) -> Any:
    """Decode-time cache entry for one layer (None when stateless)."""
    if ltype.startswith("attn"):
        kv = (jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype),
              jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype))
        if ltype == "attn_cross":
            xkv = (jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd),
                             dtype),
                   jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd),
                             dtype))
            return {"kv": kv, "xkv": xkv}
        return {"kv": kv}
    if ltype.startswith("mamba"):
        return {"ssm": mamba_state_init(cfg, batch)}
    if ltype == "mlstm":
        return {"mlstm": mlstm_state_init(cfg, batch)}
    if ltype == "slstm":
        return {"slstm": slstm_state_init(cfg, batch)}
    raise ValueError(ltype)


# -- apply ------------------------------------------------------------------


def apply_layer(ltype: str, p: Dict, x: jnp.ndarray, cfg: ArchConfig, *,
                positions: Optional[jnp.ndarray],
                cache: Optional[Dict] = None,
                cache_index=None,
                enc_out: Optional[jnp.ndarray] = None,
                causal: bool = True) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict] = None

    if ltype.startswith("attn"):
        h = rms_norm(x, p["ln1"].astype(x.dtype))
        if cache is not None:
            a, kv = attention(p["attn"], h, h, cfg, positions, causal=True,
                              cache=cache["kv"], cache_index=cache_index)
            new_cache = {"kv": kv}
        else:
            a, _ = attention(p["attn"], h, h, cfg, positions,
                             causal=(causal and ltype != "attn_enc"))
        x = x + a
        if ltype == "attn_cross":
            hx = rms_norm(x, p["ln_x"].astype(x.dtype))
            if cache is not None:
                from .components import _dispatch_sdpa, _project_qkv
                if enc_out is not None:
                    # prefill: build the cross KV cache from encoder output
                    q, ck, cv = _project_qkv(p["xattn"], hx, enc_out, cfg)
                    ck, cv = ck.astype(cache["xkv"][0].dtype), \
                        cv.astype(cache["xkv"][1].dtype)
                else:
                    ck, cv = cache["xkv"]
                    q, _, _ = _project_qkv(p["xattn"], hx, hx, cfg)
                o = _dispatch_sdpa(q, ck, cv, causal=False, cfg=cfg)
                x = x + o @ p["xattn"]["wo"]
                new_cache["xkv"] = (ck, cv)
            else:
                a, _ = attention(p["xattn"], hx, enc_out, cfg, None,
                                 causal=False)
                x = x + a
        h2 = rms_norm(x, p["ln2"].astype(x.dtype))
        if ltype == "attn_moe":
            m, aux = moe_apply(p["moe"], h2, cfg)
        else:
            m = mlp_apply(p["mlp"], h2)
        return x + m, new_cache, aux

    if ltype.startswith("mamba"):
        h = rms_norm(x, p["ln1"].astype(x.dtype))
        state = cache["ssm"] if cache is not None else None
        y, new_state = mamba_apply(p["mamba"], h, cfg, state)
        x = x + y
        if cache is not None:
            new_cache = {"ssm": new_state}
        if ltype == "mamba_moe":
            h2 = rms_norm(x, p["ln2"].astype(x.dtype))
            m, aux = moe_apply(p["moe"], h2, cfg)
            x = x + m
        return x, new_cache, aux

    if ltype == "mlstm":
        h = rms_norm(x, p["ln1"].astype(x.dtype))
        state = cache["mlstm"] if cache is not None else None
        y, new_state = mlstm_apply(p["mlstm"], h, cfg, state)
        if cache is not None:
            new_cache = {"mlstm": new_state}
        return x + y, new_cache, aux

    if ltype == "slstm":
        h = rms_norm(x, p["ln1"].astype(x.dtype))
        state = cache["slstm"] if cache is not None else None
        y, new_state = slstm_apply(p["slstm"], h, cfg, state)
        if cache is not None:
            new_cache = {"slstm": new_state}
        return x + y, new_cache, aux

    raise ValueError(ltype)
