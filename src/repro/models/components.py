"""Shared model components: norms, RoPE/M-RoPE, attention, MLP, MoE.

Functional style: every component is ``init(rng, cfg) -> params`` plus
``apply(params, x, ...)``; params are plain pytrees so they stack cleanly
along a leading block axis for ``lax.scan`` over layers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(rng, d_in: int, d_out: int, cfg: ArchConfig, scale: float = 1.0):
    std = scale * (d_in ** -0.5)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * std
            ).astype(_dtype(cfg))


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    # scale cast BEFORE the multiply: an f32 scale silently promotes the
    # whole residual stream to f32 (2x activation + collective bytes)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * scale.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) -> rotated x."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
                ) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions: (B, 3, S) = (t, h, w) streams.

    head_dim is split into three sections (16/24/24ths of hd/2 pairs per the
    released config; we use hd/2 split 2:1:1) each rotated by its stream.
    """
    hd = x.shape[-1]
    half = hd // 2
    sec_t = half // 2
    sec_h = (half - sec_t) // 2
    sec_w = half - sec_t - sec_h
    freqs = rope_freqs(hd, theta)                       # (half,)
    # per-pair position stream: t for first sec_t, h next, w last
    stream = jnp.concatenate([
        jnp.zeros((sec_t,), jnp.int32),
        jnp.ones((sec_h,), jnp.int32),
        jnp.full((sec_w,), 2, jnp.int32)])
    # gather per-section positions: (B, S, half)
    p = jnp.moveaxis(positions, 1, -1).astype(jnp.float32)   # (B, S, 3)
    sel = p[..., stream]                                     # (B, S, half)
    ang = sel * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / bias / cross)
# ---------------------------------------------------------------------------


def attn_init(rng, cfg: ArchConfig, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(rng, 8)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, cfg),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, cfg,
                         scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), _dtype(cfg))
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), _dtype(cfg))
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), _dtype(cfg))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p, x_q, x_kv, cfg: ArchConfig):
    B, Sq, _ = x_q.shape
    Skv = x_kv.shape[1]
    q = x_q @ p["wq"]
    k = x_kv @ p["wk"]
    v = x_kv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, cfg.n_heads, cfg.hd)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"].astype(q.dtype))
        k = rms_norm(k, p["k_norm"].astype(k.dtype))
    return q, k, v


def blockwise_sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   causal: bool, q_offset: jnp.ndarray | int = 0,
                   kv_len: Optional[jnp.ndarray] = None,
                   block_q: int = 512, block_kv: int = 1024,
                   batch_axes=None, seq_shard=None) -> jnp.ndarray:
    """Online-softmax blockwise attention (flash-attention dataflow in pure
    JAX; the Pallas kernel in repro.kernels.flash_attention implements the
    same algorithm with explicit VMEM tiles for the TPU target).

    Memory: O(bq * bkv) scores instead of O(Sq * Skv).  Non-divisible
    sequence lengths are padded to the block grid (the paddings are masked
    out via positions / kv_len) — whisper's 1500-frame encoder would
    otherwise degrade to 4-wide blocks.  ``seq_shard``: mesh axis to shard
    q-rows over (sequence-parallel attention for head-counts that don't
    divide tp).
    q: (B, Sq, H, hd); k/v: (B, Skv, Kv, hd).
    """
    B, Sq0, H, hd = q.shape
    Skv0, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    bq = min(block_q, Sq0)
    pad_q = (-Sq0) % bq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    Sq = Sq0 + pad_q
    bkv = min(block_kv, Skv0)
    pad_kv = (-Skv0) % bkv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = Skv0
    Skv = Skv0 + pad_kv
    nq, nkv = Sq // bq, Skv // bkv
    scale = hd ** -0.5
    qr = jnp.moveaxis(q.reshape(B, nq, bq, Kv, G, hd), 1, 0)   # (nq, B, ...)
    kr = jnp.moveaxis(k.reshape(B, nkv, bkv, Kv, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nkv, bkv, Kv, hd), 1, 0)

    def _cons(t, dims):
        if batch_axes is None and seq_shard is None:
            return t
        ba = (batch_axes if batch_axes is None or len(batch_axes) > 1
              else batch_axes[0])
        spec = [None] * t.ndim
        if ba is not None:
            spec[dims[0]] = ba
        if seq_shard is not None and t.shape[dims[1]] % 16 == 0:
            spec[dims[1]] = seq_shard
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.PartitionSpec(*spec))

    @jax.checkpoint
    def q_block(_, qin):
        qb, qi = qin                                   # (B, bq, Kv, G, hd)
        qb = _cons(qb, (0, 1))                         # seq-parallel q rows
        spos = qi * bq + jnp.arange(bq) + q_offset

        def kv_step(carry, kin):
            acc, m, l = carry
            kb, vb, kvi = kin
            s = jnp.einsum("bqkgh,btkh->bkgqt", qb, kb).astype(jnp.float32)
            s = s * scale                              # (B, Kv, G, bq, bkv)
            tpos = kvi * bkv + jnp.arange(bkv)
            mask = jnp.ones((bq, bkv), bool)
            if causal:
                mask = tpos[None, :] <= spos[:, None]
            if kv_len is not None:
                mask = mask & (tpos[None, :] < kv_len)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p, vb.astype(jnp.float32))
            return (acc_new, m_new, l_new), None

        init = (jnp.zeros((B, Kv, G, bq, hd), jnp.float32),
                jnp.full((B, Kv, G, bq), -1e30, jnp.float32),
                jnp.zeros((B, Kv, G, bq), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(
            kv_step, init, (kr, vr, jnp.arange(nkv)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B, Kv, G, bq, hd)
        return None, out

    _, outs = jax.lax.scan(q_block, None, (qr, jnp.arange(nq)))
    # (nq, B, Kv, G, bq, hd) -> (B, Sq, H*hd)
    outs = jnp.moveaxis(outs, 0, 1).transpose(0, 2, 3, 1, 4, 5)
    out = outs.reshape(B, H, Sq, hd).swapaxes(1, 2).reshape(B, Sq, H * hd)
    return out[:, :Sq0].astype(q.dtype)


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         causal: bool, q_offset: jnp.ndarray | int = 0,
         kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Grouped-query scaled dot-product attention.

    q: (B, Sq, H, hd); k/v: (B, Skv, Kv, hd).  H = G * Kv.
    ``q_offset``: absolute position of q[0] (for causal masking at decode).
    ``kv_len``: number of valid cache entries (masks the tail).
    """
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    tpos = jnp.arange(Skv)
    if causal:
        spos = jnp.arange(Sq) + q_offset
        mask = tpos[None, :] <= spos[:, None]           # (Sq, Skv)
    else:
        mask = jnp.ones((Sq, Skv), bool)
    if kv_len is not None:
        mask = mask & (tpos[None, :] < kv_len)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, Sq, H * hd)


#: above this many score elements (Sq*Skv), switch to blockwise attention
_BLOCKWISE_THRESHOLD = 1 << 21


def _dispatch_sdpa(q, k, v, causal, q_offset=0, kv_len=None, cfg=None):
    if q.shape[1] * k.shape[1] > _BLOCKWISE_THRESHOLD and q.shape[1] > 1:
        ba = cfg.mesh_batch_axes if cfg is not None else None
        seq = cfg.attn_seq_shard if cfg is not None else None
        return blockwise_sdpa(q, k, v, causal, q_offset, kv_len,
                              batch_axes=ba, seq_shard=seq)
    return sdpa(q, k, v, causal, q_offset, kv_len)


def attention(p, x_q, x_kv, cfg: ArchConfig, positions, causal=True,
              cache: Optional[Tuple] = None, cache_index=None,
              kv_positions=None):
    """Full attention; with ``cache=(K, V)`` performs in-place cache update
    at ``cache_index`` and attends over the cache (decode path)."""
    q, k, v = _project_qkv(p, x_q, x_kv, cfg)
    rope = apply_mrope if cfg.mrope else apply_rope
    if positions is not None:                          # rope'd archs
        q = rope(q, positions, cfg.rope_theta)
        kp = kv_positions if kv_positions is not None else positions
        k = rope(k, kp, cfg.rope_theta)
    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, cache_index, 0, 0))
        out = _dispatch_sdpa(q, ck, cv, causal=True,
                             q_offset=cache_index,
                             kv_len=cache_index + x_q.shape[1], cfg=cfg)
        return out @ p["wo"], (ck, cv)
    out = _dispatch_sdpa(q, k, v, causal=causal, cfg=cfg)
    return out @ p["wo"], None


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ArchConfig) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, cfg.d_ff, cfg),
        "w_up": dense_init(k2, cfg.d_model, cfg.d_ff, cfg),
        "w_down": dense_init(k3, cfg.d_ff, cfg.d_model, cfg,
                             scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }


def mlp_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (sort-based dispatch with static capacity — Megablocks-style on TPU)
# ---------------------------------------------------------------------------


def moe_init(rng, cfg: ArchConfig) -> Dict:
    E, d, f = cfg.moe_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 4)
    std = d ** -0.5
    mk = lambda k, shape: (jax.random.normal(k, shape, jnp.float32) * std
                           ).astype(_dtype(cfg))
    return {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * std,
        "w_gate": mk(ks[1], (E, d, f)),
        "w_up": mk(ks[2], (E, d, f)),
        "w_down": mk(ks[3], (E, f, d)),
    }


def _route_group(eidx: jnp.ndarray, E: int, C: int, Tg: int, k: int):
    """Per-group routing tables. eidx: (Tg, k) expert choices.

    Returns (table (E, C) of token ids [Tg = pad], lin (Tg*k,) linear slot
    per assignment [E*C = dropped], counts (E,))."""
    flat_e = eidx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(Tg), k)
    order = jnp.argsort(flat_e)                          # stable
    se, st = flat_e[order], flat_t[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(Tg * k) - starts[se]
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)
    table = jnp.full((E, C), Tg, jnp.int32)
    table = table.at[se, pos_c].set(jnp.where(keep, st, Tg).astype(jnp.int32))
    pos_un = jnp.zeros((Tg * k,), jnp.int32).at[order].set(
        pos_c.astype(jnp.int32))
    keep_un = jnp.zeros((Tg * k,), bool).at[order].set(keep)
    e_un = jnp.zeros((Tg * k,), jnp.int32).at[order].set(se)
    lin = jnp.where(keep_un, e_un * C + pos_un, E * C)
    return table, lin, counts


def moe_apply(p, x, cfg: ArchConfig):
    """Top-k routed SwiGLU experts, group-local dispatch, static capacity.

    Tokens route within ``cfg.moe_groups`` dp-local groups (per-group
    capacity), so the dispatch gather and the combine's backward scatter
    never cross data shards.  Experts shard over 'model' when
    ``cfg.moe_ep`` (EP), else each expert is tensor-parallel on d_ff.
    Overflow beyond capacity is dropped (GShard semantics); shapes static.
    """
    B, S, d = x.shape
    T = B * S
    E, k = cfg.moe_experts, cfg.moe_topk
    G = cfg.moe_groups if cfg.moe_groups and T % cfg.moe_groups == 0 else 1
    Tg = T // G
    C = max(8, int(cfg.moe_capacity_factor * k * Tg / E + 0.999) // 8 * 8)
    C = min(C, Tg)
    xt = x.reshape(G, Tg, d)

    def _cons(t, spec):
        if cfg.mesh_batch_axes is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.PartitionSpec(*spec))

    dp = (cfg.mesh_batch_axes if cfg.mesh_batch_axes
          and len(cfg.mesh_batch_axes) > 1
          else (cfg.mesh_batch_axes[0] if cfg.mesh_batch_axes else None))
    gdp = dp if G > 1 else None
    ep = "model" if cfg.moe_ep else None
    tpf = None if cfg.moe_ep else "model"

    logits = jnp.einsum("gtd,de->gte", xt,
                        p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                 # (G, Tg, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    table, lin, counts = jax.vmap(
        lambda e: _route_group(e, E, C, Tg, k))(eidx)    # (G,E,C) (G,Tg*k)

    xpad = jnp.concatenate([xt, jnp.zeros((G, 1, d), xt.dtype)], axis=1)
    xe = jax.vmap(lambda xp, tb: xp[tb])(xpad, table)    # (G, E, C, d)
    xe = _cons(xe, (gdp, ep, None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = _cons(h, (gdp, ep, None, tpf))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"]).astype(xt.dtype)
    # EP: re-shard expert outputs E@model -> d_model@model BEFORE the
    # combine.  The gather then stays local per d-shard and the cross-model
    # traffic is one all-to-all of ye (bf16, 1/tp width) instead of a
    # full-width fp32 all-reduce of the gathered (Tg*k, d) tensor
    # (measured on kimi prefill_32k: 15 GB -> ~1.5 GB per layer per device).
    comb_tp = "model" if (cfg.moe_ep and d % 16 == 0) else None
    ye = _cons(ye, (gdp, None if cfg.moe_ep else ep, None, comb_tp))
    # combine gather-side: token pulls its k slot outputs (local per group)
    ye_flat = jnp.concatenate(
        [ye.reshape(G, E * C, d), jnp.zeros((G, 1, d), ye.dtype)], axis=1)
    yk = jax.vmap(lambda yf, l: yf[l])(ye_flat, lin)     # (G, Tg*k, d)
    yk = yk.reshape(G, Tg, k, d)
    out = jnp.einsum("gtkd,gtk->gtd", yk, gate.astype(ye.dtype))
    out = _cons(out, (gdp, None, comb_tp))
    # auxiliary load-balancing loss (Switch-style)
    me = probs.mean((0, 1))
    ce = counts.sum(0).astype(jnp.float32) / (T * k)
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, d), aux
