"""Backend selection for the Pallas fast path.

Two independent knobs, both resolved lazily (importing this module must not
initialize the JAX backend):

* **backend** — whether a dwarf hot spot runs the hand-written Pallas kernel
  (``"pallas"``) or the stock XLA lowering (``"xla"``).  ``"auto"`` picks
  Pallas on accelerators and XLA on CPU, where the only Pallas execution
  path is the slow interpreter.  Per-edge override:
  ``ComponentParams.extra["backend"]``; process-wide override: the
  ``REPRO_BACKEND`` environment variable.
* **interpret** — whether ``pl.pallas_call`` runs under the Pallas
  interpreter (the debug path) instead of compiling for the platform.
  Auto-detected from ``jax.default_backend()`` (CPU has no Mosaic/Triton
  lowering, so it must interpret); ``REPRO_PALLAS_INTERPRET=0/1`` forces it.
"""

from __future__ import annotations

import contextlib
import os
import threading
from typing import Iterator, Optional

import jax

BACKENDS = ("auto", "pallas", "xla")

#: platforms with a real (non-interpreter) Pallas lowering
_PALLAS_PLATFORMS = ("tpu", "gpu")

#: graceful-degradation override: when the serving engine's circuit
#: breaker trips on a structure it re-dispatches with the stock XLA
#: lowering forced, beating *every* other knob (a component pinning
#: ``extra["backend"]="pallas"`` is exactly what must be overridden when
#: that kernel is the suspected fault).  Thread-local so a degraded
#: serving dispatch cannot leak the override into concurrent tuners.
_OVERRIDE = threading.local()


def backend_override() -> Optional[str]:
    """The active forced backend, or None.  Part of every compiled-
    executable cache key (:mod:`repro.api.stack`): a degraded dispatch
    must never be handed an executable traced with the failing backend."""
    return getattr(_OVERRIDE, "value", None)


@contextlib.contextmanager
def forced_backend(backend: Optional[str]) -> Iterator[None]:
    """Force every :func:`resolve_backend` call in this thread to
    ``backend`` for the duration (``None`` = no-op).  Used by the serving
    engine's degradation path; restores the previous override on exit."""
    if backend is not None and backend not in ("pallas", "xla"):
        raise ValueError(f"forced backend must be 'pallas', 'xla' or "
                         f"None, got {backend!r}")
    prev = backend_override()
    _OVERRIDE.value = backend
    try:
        yield
    finally:
        _OVERRIDE.value = prev


def default_interpret(platform: Optional[str] = None) -> bool:
    """True when Pallas kernels must run under the interpreter here.

    An empty ``REPRO_PALLAS_INTERPRET`` means *unset* (auto-detect), the
    same convention every other knob follows — CI matrix legs export the
    variable unconditionally with ``""`` for the default configuration."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env.strip() != "":
        return env not in ("0", "false", "False")
    p = platform or jax.default_backend()
    return p not in _PALLAS_PLATFORMS


def megakernel_enabled() -> bool:
    """Whether mega-eligible fused stages may lower to the one-kernel
    Pallas megakernel (``REPRO_MEGAKERNEL``; unset/empty = on).  Off, an
    eligible stage keeps the bit-identical ``fori_loop`` + ``lax.switch``
    path.  The flag only *arms* the megakernel — a stage still takes it
    only when every member's backend resolves to ``"pallas"``, so
    ``REPRO_BACKEND``, per-edge ``extra["backend"]`` pins and the
    :func:`forced_backend` degrade all demote it per dispatch.  Part of
    every ``Stack._exec_key``: flipping the knob can never hand a caller
    an executable traced for the other lowering."""
    env = os.environ.get("REPRO_MEGAKERNEL")
    if env is None or env.strip() == "":
        return True
    return env not in ("0", "false", "False")


def resolve_backend(requested: Optional[str] = None) -> str:
    """Resolve a backend request to a concrete ``"pallas"`` or ``"xla"``.

    Precedence: :func:`forced_backend` degradation override > explicit
    ``requested`` (a component's ``extra["backend"]``) > ``REPRO_BACKEND``
    env var > ``"auto"``.
    """
    forced = backend_override()
    if forced is not None:
        return forced
    b = requested or os.environ.get("REPRO_BACKEND") or "auto"
    if b not in BACKENDS:
        raise ValueError(f"unknown backend {b!r}; expected one of {BACKENDS}")
    if b == "auto":
        return "pallas" if jax.default_backend() in _PALLAS_PLATFORMS else "xla"
    return b
