"""murmur3-finalizer hash Pallas kernel — the logic dwarf's bit-ops hot spot.

Pure VPU integer ops (xor, shifts, multiplies) over 2-D VMEM tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_kernel(x_ref, o_ref, *, rounds: int):
    u = x_ref[...]
    for _ in range(rounds):
        u = u ^ (u >> 16)
        u = u * jnp.uint32(0x85EBCA6B)
        u = u ^ (u >> 13)
        u = u * jnp.uint32(0xC2B2AE35)
        u = u ^ (u >> 16)
    o_ref[...] = u


def hash_mix_kernel(x: jnp.ndarray, *, rounds: int = 2, block: int = 1024,
                    interpret: bool = True) -> jnp.ndarray:
    M, N = x.shape
    bm = min(block, M)
    assert M % bm == 0
    kern = functools.partial(_hash_kernel, rounds=rounds)
    return pl.pallas_call(
        kern,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.uint32),
        interpret=interpret,
    )(x)
