from .kernel import hash_mix_kernel
from .ops import hash_mix
from .ref import hash_mix_ref
