"""Pure-jnp oracle for the hash_mix kernel."""
import jax.numpy as jnp


def hash_mix_ref(x: jnp.ndarray, rounds: int = 2) -> jnp.ndarray:
    u = x
    for _ in range(rounds):
        u = u ^ (u >> 16)
        u = u * jnp.uint32(0x85EBCA6B)
        u = u ^ (u >> 13)
        u = u * jnp.uint32(0xC2B2AE35)
        u = u ^ (u >> 16)
    return u
