"""jit'd wrapper for hash_mix (flat input reshaped to lanes)."""
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import default_interpret
from .kernel import hash_mix_kernel


@functools.partial(jax.jit, static_argnames=("rounds", "lanes", "interpret"))
def hash_mix(x: jnp.ndarray, *, rounds: int = 2, lanes: int = 128,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % lanes
    xp = jnp.pad(flat, (0, pad)).reshape(-1, lanes)
    out = hash_mix_kernel(xp, rounds=rounds, interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)
