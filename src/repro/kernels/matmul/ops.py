"""Public wrapper with shape padding for the tiled matmul kernel.

Backend-dispatched through :mod:`repro.kernels.dispatch`: the resolved
backend / interpret flag are decided per call outside jit, so
``REPRO_BACKEND=xla`` and the circuit breaker's ``forced_backend`` degrade
actually turn the kernel off, and the resolved values key the jit cache.
"""
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import default_interpret, resolve_backend
from .kernel import matmul_kernel


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def _matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int,
                   block_n: int, block_k: int, interpret: bool) -> jnp.ndarray:
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    out = matmul_kernel(ap, bp, block_m=bm, block_n=bn, block_k=bk,
                        interpret=interpret)
    return out[:M, :N]


@jax.jit
def _matmul_xla(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    # f32 accumulation like the kernel's scratch; out dtype matches the kernel
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: Optional[bool] = None,
           backend: Optional[str] = None) -> jnp.ndarray:
    if resolve_backend(backend) != "pallas":
        return _matmul_xla(a, b)
    if interpret is None:
        interpret = default_interpret()
    return _matmul_pallas(a, b, block_m=block_m, block_n=block_n,
                          block_k=block_k, interpret=interpret)
