"""jit'd wrapper with shape padding for the tiled matmul kernel."""
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import default_interpret
from .kernel import matmul_kernel


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def matmul(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: Optional[bool] = None) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    out = matmul_kernel(ap, bp, block_m=bm, block_n=bn, block_k=bk,
                        interpret=interpret)
    return out[:M, :N]
