from .kernel import matmul_kernel
from .ops import matmul
from .ref import matmul_ref
