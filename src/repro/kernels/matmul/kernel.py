"""Tiled matmul Pallas TPU kernel — the matrix dwarf's MXU hot spot.

(bm, bn, bk) VMEM tiles, fp32 accumulator scratch, K as the innermost
sequential grid axis.  MXU-aligned defaults (128 multiples).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_kernel(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int = 128,
                  block_n: int = 128, block_k: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    return pl.pallas_call(
        _mm_kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
