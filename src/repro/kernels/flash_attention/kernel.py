"""Flash attention Pallas TPU kernel: blockwise online softmax.

Grid (B, H, nq, nkv); KV tiles stream HBM->VMEM; running (acc, m, l) live in
VMEM scratch across the nkv axis (innermost, sequential on TPU).  GQA is
handled in the K/V BlockSpec index maps (q-head h reads kv-head h // G) —
no materialized head repetition.  MXU-aligned tiles: bq/bkv multiples of
128 recommended, hd is the lane dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, bq: int, bkv: int, kv_len: int,
                  scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    cols = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    # skip fully-masked kv blocks (beyond the causal diagonal / kv_len)
    live = (kj * bkv < kv_len)
    if causal:
        live = jnp.logical_and(live, kj * bkv <= qi * bq + bq - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = cols < kv_len
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                              # (bq, 1)
        m_new = jnp.maximum(m_prev[:, 0], s.max(axis=1))[:, None]
        p = jnp.exp(s - m_new)                           # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, kv_len: int | None = None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, hd); k/v: (B, Kv, Skv, hd) — Sq % block_q == 0,
    Skv % block_kv == 0 (ops.py pads)."""
    B, H, Sq, hd = q.shape
    Kv, Skv = k.shape[1], k.shape[2]
    G = H // Kv
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    nq, nkv = Sq // bq, Skv // bkv
    if kv_len is None:
        kv_len = Skv
    kernel = functools.partial(
        _flash_kernel, causal=causal, bq=bq, bkv=bkv, kv_len=kv_len,
        scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
