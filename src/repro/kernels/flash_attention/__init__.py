from .kernel import flash_attention_kernel
from .ops import flash_attention
from .ref import attention_ref
