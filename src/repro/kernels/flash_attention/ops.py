"""jit'd public wrapper: layout handling, padding, GQA, interpret toggle."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import default_interpret
from .kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: Optional[bool] = None
                    ) -> jnp.ndarray:
    """(B, S, H, hd)-layout attention via the Pallas TPU kernel.

    Pads Sq/Skv to the block grid; padding is masked inside the kernel via
    ``kv_len`` and discarded on return.
    """
    if interpret is None:
        interpret = default_interpret()
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(block_q, max(Sq, 8))
    bkv = min(block_kv, max(Skv, 8))
    pq = (-Sq) % bq
    pkv = (-Skv) % bkv
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    out = flash_attention_kernel(qt, kt, vt, causal=causal, kv_len=Skv,
                                 block_q=bq, block_kv=bkv,
                                 interpret=interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
