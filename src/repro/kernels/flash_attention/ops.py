"""Public wrapper: backend dispatch, layout handling, padding, GQA.

Dispatch goes through :mod:`repro.kernels.dispatch` — the resolved backend
and interpret flag are decided *here*, per call, outside jit, so the
``forced_backend`` circuit-breaker override and ``REPRO_BACKEND`` pick the
execution path and the resolved values key the inner jit caches (a degrade
to XLA can never be handed a stale Pallas compilation).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import default_interpret, resolve_backend
from .kernel import flash_attention_kernel
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def _flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                            causal: bool, block_q: int, block_kv: int,
                            interpret: bool) -> jnp.ndarray:
    """(B, S, H, hd)-layout attention via the Pallas TPU kernel.

    Pads Sq/Skv to the block grid; padding is masked inside the kernel via
    ``kv_len`` and discarded on return.
    """
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(block_q, max(Sq, 8))
    bkv = min(block_kv, max(Skv, 8))
    pq = (-Sq) % bq
    pkv = (-Skv) % bkv
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    out = flash_attention_kernel(qt, kt, vt, causal=causal, kv_len=Skv,
                                 block_q=bq, block_kv=bkv,
                                 interpret=interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal",))
def _attention_xla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   causal: bool) -> jnp.ndarray:
    out = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal)
    return out.transpose(0, 2, 1, 3)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: Optional[bool] = None,
                    backend: Optional[str] = None) -> jnp.ndarray:
    """(B, S, H, hd)-layout attention, backend-dispatched.

    ``resolve_backend(backend)`` picks the Pallas kernel or the stock XLA
    lowering (``attention_ref``); pass ``backend="pallas"`` to request the
    kernel explicitly (the ``forced_backend`` degrade still wins).
    """
    if resolve_backend(backend) != "pallas":
        return _attention_xla(q, k, v, causal=causal)
    if interpret is None:
        interpret = default_interpret()
    return _flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                   block_kv=block_kv, interpret=interpret)
