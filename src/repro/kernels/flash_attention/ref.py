"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, kv_len: int | None = None
                  ) -> jnp.ndarray:
    """q: (B, H, Sq, hd); k/v: (B, Kv, Skv, hd)."""
    B, H, Sq, hd = q.shape
    Kv, Skv = k.shape[1], k.shape[2]
    G = H // Kv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    rows = jnp.arange(Sq)[:, None]
    cols = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = cols <= rows
    if kv_len is not None:
        mask = mask & (cols < kv_len)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
