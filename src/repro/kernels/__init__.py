# Pallas TPU kernels for the compute hot-spots the dwarf methodology owns:
# matrix dwarf (matmul), LM attention (flash_attention), sort dwarf /
# MoE router (topk), logic dwarf (hash_mix).  Each: kernel.py
# (pl.pallas_call + BlockSpec VMEM tiling) + ops.py (jit wrapper) + ref.py
# (pure-jnp oracle).  Validated with interpret=True on CPU; TPU is the
# compile target.
