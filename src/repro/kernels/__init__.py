# Pallas TPU kernels for the compute hot-spots the dwarf methodology owns:
# matrix dwarf (matmul), LM attention (flash_attention), sort dwarf /
# MoE router (topk), logic dwarf (hash_mix).  Each: kernel.py
# (pl.pallas_call + BlockSpec VMEM tiling) + ops.py (jit wrapper) + ref.py
# (pure-jnp oracle).  ``dispatch`` owns backend selection: interpret mode is
# auto-detected from the platform (CPU interprets, TPU/GPU compile) and the
# dwarf layer routes its hot spots here when the resolved backend is "pallas".
from .dispatch import BACKENDS, default_interpret, resolve_backend

__all__ = ["BACKENDS", "default_interpret", "resolve_backend"]
