"""Pure-jnp oracle for the bitonic sort network."""
import jax.numpy as jnp


def sort_rows_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sort(x, axis=1)
