"""jit'd wrapper for the bitonic sort network (row + length padding)."""
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import default_interpret
from .kernel import next_pow2, sort_net_kernel


def _pad_max(dtype) -> jnp.ndarray:
    """The dtype's maximum — ascending sort pushes pads past every real
    element, so slicing the first N columns recovers the sorted row."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def sort_rows(x: jnp.ndarray, *, block_m: int = 256,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sort each row of a (M, N) array ascending via the bitonic network;
    any M and N (rows pad to the block multiple, lengths to the next
    power of two)."""
    if interpret is None:
        interpret = default_interpret()
    M, N = x.shape
    bm = min(block_m, M)
    pm = (-M) % bm
    pn = next_pow2(N) - N
    xp = x
    if pn:
        xp = jnp.concatenate(
            [xp, jnp.full((M, pn), _pad_max(x.dtype), x.dtype)], axis=1)
    if pm:
        xp = jnp.concatenate(
            [xp, jnp.full((pm, xp.shape[1]), _pad_max(x.dtype), x.dtype)])
    out = sort_net_kernel(xp, block_m=bm, interpret=interpret)
    return out[:M, :N]
