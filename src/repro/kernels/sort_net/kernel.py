"""Row-wise bitonic sort network Pallas kernel — sort dwarf hot spot.

Each program owns a (bm, N) row tile in VMEM and sorts every row
ascending with a bitonic network: log2(N)·(log2(N)+1)/2 compare-exchange
stages, each a static reshape + min/max + select.  The network is
data-independent — no gathers, no data-dependent control flow — so it
lowers to the TPU vector unit directly, where XLA's variadic ``sort``
falls back to a serial comparator loop.

The network body (:func:`bitonic_sort_rows`) is pure jnp over values, not
refs, so the exact same comparator sequence also serves as the sort
segment body inside the :mod:`repro.kernels.megakernel` fused-stage
kernel (a nested ``pallas_call`` is not expressible there).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def next_pow2(n: int) -> int:
    """Smallest power of two >= n."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def bitonic_sort_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Sort each row of a (rows, n) array ascending; n must be a power of
    two (callers pad with the dtype's maximum so pads sink to the tail).

    Stage (k, j) pairs element i with i^j.  The reshape to
    (rows, n/(2j), 2, j) makes those partners adjacent without a gather,
    and because j <= k/2 the ascending/descending direction ``(i & k)``
    is constant within each 2j-group — one broadcast select per stage.
    """
    rows, n = x.shape
    if n & (n - 1):
        raise ValueError(f"bitonic_sort_rows needs a power-of-two row "
                         f"length, got {n}")
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            y = x.reshape(rows, n // (2 * j), 2, j)
            a, b = y[:, :, 0, :], y[:, :, 1, :]
            g = jnp.arange(n // (2 * j), dtype=jnp.int32) * (2 * j)
            asc = ((g & k) == 0)[None, :, None]
            lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
            x = jnp.stack([jnp.where(asc, lo, hi), jnp.where(asc, hi, lo)],
                          axis=2).reshape(rows, n)
            j //= 2
        k *= 2
    return x


def _sort_kernel(x_ref, o_ref):
    o_ref[...] = bitonic_sort_rows(x_ref[...])


def sort_net_kernel(x: jnp.ndarray, *, block_m: int = 256,
                    interpret: bool = True) -> jnp.ndarray:
    M, N = x.shape
    bm = min(block_m, M)
    assert M % bm == 0
    return pl.pallas_call(
        _sort_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x)
