from .kernel import bitonic_sort_rows, sort_net_kernel
from .ops import sort_rows
from .ref import sort_rows_ref
