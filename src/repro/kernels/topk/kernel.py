"""Row-wise top-k Pallas kernel — sort dwarf / MoE router hot spot.

Each program owns a (bm, N) row tile in VMEM and extracts k maxima with
k (max, mask) sweeps — vector-unit only, no data-dependent control flow,
so it lowers to TPU without a sort network.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -3.4e38


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)                  # (bm, N)
    bm, n = x.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 1)
    for j in range(k):
        m = x.max(axis=1)                               # (bm,)
        # first column achieving the max
        hit = (x == m[:, None])
        first = jnp.min(jnp.where(hit, cols, n), axis=1)
        vals_ref[:, j] = m.astype(vals_ref.dtype)
        idx_ref[:, j] = first.astype(jnp.int32)
        x = jnp.where(cols == first[:, None], NEG_INF, x)


def topk_kernel(x: jnp.ndarray, k: int, *, block_m: int = 256,
                interpret: bool = True):
    M, N = x.shape
    bm = min(block_m, M)
    assert M % bm == 0
    kern = functools.partial(_topk_kernel, k=k)
    return pl.pallas_call(
        kern,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((bm, k), lambda i: (i, 0)),
                   pl.BlockSpec((bm, k), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((M, k), x.dtype),
                   jax.ShapeDtypeStruct((M, k), jnp.int32)),
        interpret=interpret,
    )(x)
