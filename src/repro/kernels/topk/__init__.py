from .kernel import topk_kernel
from .ops import topk
from .ref import topk_ref
