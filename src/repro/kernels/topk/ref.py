"""Pure-jnp oracle for the top-k kernel."""
import jax
import jax.numpy as jnp


def topk_ref(x: jnp.ndarray, k: int):
    vals, idx = jax.lax.top_k(x, k)
    return vals, idx.astype(jnp.int32)
