"""jit'd wrapper for the top-k kernel (row padding)."""
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import default_interpret
from .kernel import topk_kernel


@functools.partial(jax.jit, static_argnames=("k", "block_m", "interpret"))
def topk(x: jnp.ndarray, k: int, *, block_m: int = 256,
         interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    M, N = x.shape
    bm = min(block_m, M)
    pm = (-M) % bm
    xp = jnp.pad(x, ((0, pm), (0, 0))) if pm else x
    vals, idx = topk_kernel(xp, k, block_m=bm, interpret=interpret)
    return vals[:M], idx[:M]
