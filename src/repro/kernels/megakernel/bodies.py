"""Registered in-kernel segment bodies for the FusedStage megakernel.

A *body* is a pure ``flat_f32 -> flat_f32`` function over the stage's
carry buffer replicating exactly one repeat of a dwarf component — the
same value :func:`repro.core.dag._edge_out`'s loop body produces — as
plain jnp ops traceable *inside* a Pallas kernel (no nested
``pallas_call``, no rng).  Only components whose ``apply`` ignores the
rng and whose per-repeat output is value-identical to the XLA lowering
may register here; that is what makes the one-kernel stage bit-identical
to the ``fori_loop`` + ``lax.switch`` path.

:func:`mega_body` returns ``None`` when a component has no registered
body **or** its params break the identity contract (a chunk-row body
whose ``parallelism`` lane split in ``DwarfComponent.__call__`` would
re-tile lanes, a non-divisible chunk, a dynamic kernel-static extra) —
``core/schedule.py`` then keeps the stage on the switch path.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

MegaBody = Callable[[jnp.ndarray], jnp.ndarray]

#: sentinel the top-k max-sweep masks claimed maxima with (must match
#: repro.kernels.topk.kernel.NEG_INF for bit-identity with the kernel)
_NEG_INF = -3.4e38


def _lane_split_clean(p) -> bool:
    """Mirror ``DwarfComponent.__call__``'s parallelism lane split: a
    chunk-row body is safe iff the split either does not engage or cuts
    the buffer into whole chunk rows (then vmap-over-lanes ≡ one rowwise
    pass over the full buffer)."""
    if p.parallelism <= 1:
        return True
    size = p.data_size
    lanes = min(p.parallelism, max(1, size // max(p.chunk_size, 8)))
    if lanes <= 1 or size % lanes != 0:
        return True                     # __call__ falls through to apply()
    return (size // lanes) % p.chunk_size == 0


def _static_int(v) -> Optional[int]:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return max(int(round(float(v))), 0)


def _body_hash(p) -> Optional[MegaBody]:
    rounds = _static_int(p.extra.get("rounds", 4))
    if rounds is None:
        return None

    def body(flat: jnp.ndarray) -> jnp.ndarray:
        from ...core.dwarfs.base import _mix32_round, as_u32, u32_to_f32
        u = as_u32(flat)
        for _ in range(rounds):
            u = _mix32_round(u)
        return u32_to_f32(u)

    return body


def _body_top_k(p) -> Optional[MegaBody]:
    c = p.chunk_size
    k = _static_int(p.extra.get("k", 32))
    if k is None or k < 1:
        return None
    k = min(k, c)

    def body(flat: jnp.ndarray) -> jnp.ndarray:
        x = flat.reshape(-1, c)
        rows = x.shape[0]
        cols = jax.lax.broadcasted_iota(jnp.int32, (rows, c), 1)
        vals = []
        for _ in range(k):        # the topk kernel's (max, mask) sweep
            m = x.max(axis=1)
            first = jnp.min(jnp.where(x == m[:, None], cols, c), axis=1)
            vals.append(m)
            x = jnp.where(cols == first[:, None], _NEG_INF, x)
        v = jnp.stack(vals, axis=1)
        reps = -(-c // k)
        return jnp.tile(v, (1, reps))[:, :c].reshape(-1)

    return body


def _body_full_sort(p) -> Optional[MegaBody]:
    # quick_sort sorts every chunk row; merge_sort merges two sorted
    # halves, which equals the full row sort whenever the chunk is even
    # (rounded chunks are multiples of 8, so always here)
    c = p.chunk_size
    if c % 2:
        return None

    def body(flat: jnp.ndarray) -> jnp.ndarray:
        from ..sort_net.kernel import bitonic_sort_rows, next_pow2
        rows = flat.reshape(-1, c)
        pn = next_pow2(c) - c
        if pn:
            rows = jnp.concatenate(
                [rows, jnp.full((rows.shape[0], pn), jnp.inf, rows.dtype)],
                axis=1)
        return bitonic_sort_rows(rows)[:, :c].reshape(-1)

    return body


def _body_min_max(p) -> Optional[MegaBody]:
    c = p.chunk_size

    def body(flat: jnp.ndarray) -> jnp.ndarray:
        rows = flat.reshape(-1, c)
        mn = rows.min(axis=1, keepdims=True)
        mx = rows.max(axis=1, keepdims=True)
        return ((rows - mn) / jnp.maximum(mx - mn, 1e-6)).reshape(-1)

    return body


#: component name -> body factory.  hash is elementwise; the rest view
#: the carry as (rows, chunk) and must survive the lane-split check.
_FACTORIES = {
    "hash": _body_hash,
    "top_k": _body_top_k,
    "quick_sort": _body_full_sort,
    "merge_sort": _body_full_sort,
    "min_max": _body_min_max,
}
_CHUNK_ROW = frozenset(("top_k", "quick_sort", "merge_sort", "min_max"))


def mega_body(component: str, p) -> Optional[MegaBody]:
    """The registered segment body for ``component`` under (rounded)
    params ``p``, or ``None`` when no bit-identical body exists."""
    factory = _FACTORIES.get(component)
    if factory is None:
        return None
    p = p.rounded()
    if p.data_size % p.chunk_size:          # rounded() guarantees this,
        return None                         # but never trust a caller
    if component in _CHUNK_ROW and not _lane_split_clean(p):
        return None
    return factory(p)


def mega_capable(component: str, p) -> bool:
    return mega_body(component, p) is not None
