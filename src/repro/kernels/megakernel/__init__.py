from .bodies import mega_body, mega_capable
from .kernel import CARRY_VMEM_BYTES, mega_stage_kernel
