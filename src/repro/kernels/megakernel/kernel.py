"""One-kernel FusedStage execution: the stage megakernel emitter.

The ``fori_loop`` + ``lax.switch`` fused-stage form re-materializes the
carry through XLA between every trip.  This emitter compiles the whole
member chain into **one** ``pl.pallas_call``:

* the **grid iterates the segments** of the stage's concatenated trip
  space — one grid step per member edge, in member order (TPU grid
  execution is sequential, which is what makes the scratch carry below
  sound);
* the **carry stays resident in VMEM scratch** across members: step 0
  copies the input block in, every step reads/writes the scratch, the
  last value is written to the output block — no per-member HBM
  round-trip;
* the **per-segment operand row** (the member's trip count) is a blocked
  input whose index map follows the segment index, so the Pallas
  pipeline keeps the *next* segment's operand load in flight while the
  current segment computes — the standard grid-pipelined double
  buffering (guide §17) with zero manual semaphores;
* each segment runs its member's registered body (see
  :mod:`.bodies`) ``weight`` times via an in-kernel ``fori_loop`` whose
  bound is read from the operand row — weights are *data*, so stepping a
  weight never retraces, exactly like the switch path.

Trip order is therefore member 0's repeats, then member 1's, … — the
same order ``_fused_out``'s segmented trip space executes — and every
body is value-identical to its XLA counterpart, so the whole kernel is
bit-identical to the switch path (the ``test_schedule`` megakernel
sweep's contract).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: cap on the resident carry (f32 bytes) — a stage whose buffer cannot
#: stay VMEM-resident next to the operand pipeline keeps the switch path
CARRY_VMEM_BYTES = 4 << 20


def _mega_kernel(w_ref, x_ref, o_ref, acc_ref, *, bodies, rows, lane):
    seg = pl.program_id(0)

    @pl.when(seg == 0)
    def _():
        acc_ref[...] = x_ref[...]

    carry = acc_ref[...]
    w = w_ref[0, 0]          # this segment's trip count (pipelined load)

    def branch(body):
        def run(c):
            return jax.lax.fori_loop(
                0, w, lambda _, f: body(f),
                c.reshape(rows * lane)).reshape(rows, lane)
        return run

    carry = jax.lax.switch(seg, [branch(b) for b in bodies], carry)
    acc_ref[...] = carry
    o_ref[...] = carry       # last grid step's write is the stage output


def mega_stage_kernel(x: jnp.ndarray, weights: jnp.ndarray,
                      bodies: Sequence, *, interpret: bool = True
                      ) -> jnp.ndarray:
    """Execute a fused stage as one kernel.

    ``x`` — flat f32 carry (the stage's ``data_size``); ``weights`` —
    (k,) i32 per-member trip counts (traced values, never statics);
    ``bodies`` — k registered segment bodies in member order.
    """
    size = x.shape[0]
    k = len(bodies)
    lane = 128 if size % 128 == 0 else 8     # rounded sizes are 8-aligned
    rows = size // lane
    kern = functools.partial(_mega_kernel, bodies=tuple(bodies),
                             rows=rows, lane=lane)
    out = pl.pallas_call(
        kern,
        grid=(k,),
        in_specs=[pl.BlockSpec((1, 1), lambda s: (s, 0)),
                  pl.BlockSpec((rows, lane), lambda s: (0, 0))],
        out_specs=pl.BlockSpec((rows, lane), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, lane), jnp.float32),
        scratch_shapes=[pltpu.VMEM((rows, lane), jnp.float32)],
        interpret=interpret,
    )(weights.astype(jnp.int32).reshape(k, 1), x.reshape(rows, lane))
    return out.reshape(size)
