"""Sharded checkpointing: manifest + per-leaf .npy, atomic rename, async
writer, restart-from-latest, and elastic resharding (restore onto any mesh).

Layout:
  <dir>/step_000100/MANIFEST.json       {"step": 100, "leaves": {name: meta}}
  <dir>/step_000100/<mangled-name>.npy
A checkpoint directory is visible only after the atomic rename from
``.tmp-step_000100`` — a killed writer never leaves a half checkpoint.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def save_checkpoint(tree: Any, ckpt_dir: str, step: int) -> str:
    """Synchronous atomic save. Returns the final directory path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp-step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten(tree)
    manifest: Dict[str, Any] = {"step": int(step), "leaves": {}}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"][name] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return str(final)


def available_steps(ckpt_dir: str):
    d = Path(ckpt_dir)
    if not d.exists():
        return []
    steps = []
    for child in d.iterdir():
        m = re.fullmatch(r"step_(\d+)", child.name)
        if m and (child / "MANIFEST.json").exists():
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(tree_like: Any, ckpt_dir: str,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching tree of NamedShardings — this is the
    **elastic** path: a checkpoint written on an NxM mesh restores onto any
    other mesh by placing each host array with the new sharding.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    leaves, treedef = _flatten(tree_like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    out = []
    for i, (path, leaf) in enumerate(leaves):
        name = _leaf_name(path)
        if name not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(d / f"{name}.npy")
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [v for v in out])


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (device_get happens in the
    caller; serialization happens on a writer thread)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, tree: Any, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save_checkpoint(host_tree, self.ckpt_dir, step)
            self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = available_steps(self.ckpt_dir)
        for s in steps[:-self.keep]:
            shutil.rmtree(Path(self.ckpt_dir) / f"step_{s:08d}",
                          ignore_errors=True)
