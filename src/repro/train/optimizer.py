"""AdamW with fp32 master weights — states shard exactly like params (ZeRO).

State pytree mirrors params: {mu, nu, master}; with the FSDP sharding rules
every state shard lives with its parameter shard, i.e. ZeRO-3 partitioning
of both params and optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "master": master}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads: Any, state: Dict[str, Any], params: Any,
                 step: jnp.ndarray, cfg: AdamWConfig):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, master, p):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        u = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        decay = cfg.weight_decay if master.ndim >= 2 else 0.0
        master = master - lr * (u + decay * master)
        return mu, nu, master, master.astype(p.dtype)

    out = jax.tree.map(upd, grads, state["mu"], state["nu"],
                       state["master"], params)
    mu = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.map(lambda o: o[3], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"mu": mu, "nu": nu, "master": master}, gnorm
