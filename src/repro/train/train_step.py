"""Training step: CE loss (+MoE aux, z-loss), microbatch accumulation,
int8 error-feedback gradient compression (optional), AdamW update.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.model import Model
from .optimizer import AdamWConfig, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: Dict[str, Any]
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    accum: int = 1                     # microbatch accumulation factor
    aux_weight: float = 0.01           # MoE load-balance loss weight
    z_weight: float = 1e-4             # logit z-loss
    compress_grads: bool = False       # int8 error-feedback DP compression
    batch_axes: Optional[Tuple[str, ...]] = None  # explicit batch sharding
    # XLA loses batch sharding through the (accum, B/accum) microbatch
    # reshape (measured: full activation replication); an explicit
    # with_sharding_constraint per microbatch restores it.


def init_state(model: Model, rng: jax.Array) -> TrainState:
    params = model.init(rng)
    return TrainState(params, adamw_init(params), jnp.zeros((), jnp.int32))


def _loss_fn(model: Model, params, batch: Dict[str, jnp.ndarray],
             opts: TrainOptions):
    kw = {k: batch[k] for k in
          ("vision_embeds", "mrope_positions", "frames") if k in batch}
    logits, aux = model.forward(params, batch["tokens"], **kw)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(nll)
    zl = jnp.mean(jax.scipy.special.logsumexp(
        logits.astype(jnp.float32), axis=-1) ** 2)
    loss = ce + opts.aux_weight * aux + opts.z_weight * zl
    return loss, {"ce": ce, "aux": aux}


def _compress_int8(g: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback int8 quantization applied before the DP all-reduce.

    The quantized+dequantized gradient is what crosses the network (XLA
    all-reduces the already-low-rank-noise tensor); the residual feeds back
    next step, preserving convergence (1-bit-Adam-style analysis applies).
    """
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    opts: TrainOptions = TrainOptions()) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). jit/pjit-able."""

    def constrain(tree):
        if opts.batch_axes is None:
            return tree
        ba = (opts.batch_axes if len(opts.batch_axes) > 1
              else opts.batch_axes[0])

        def c(x):
            if getattr(x, "ndim", 0) >= 1:
                spec = jax.sharding.PartitionSpec(ba, *([None] * (x.ndim - 1)))
                return jax.lax.with_sharding_constraint(x, spec)
            return x

        return jax.tree.map(c, tree)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: _loss_fn(model, p, constrain(batch), opts),
            has_aux=True)(params)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params = state.params
        if opts.accum > 1:
            def micro(carry, mb):
                acc, = carry
                (loss, aux), g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc,), (loss, aux["ce"])

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape(opts.accum, x.shape[0] // opts.accum,
                                    *x.shape[1:]), batch)
            (gacc,), (losses, ces) = jax.lax.scan(micro, (zero,), mbs)
            grads = jax.tree.map(lambda g: g / opts.accum, gacc)
            loss, ce = jnp.mean(losses), jnp.mean(ces)
        else:
            (loss, auxd), grads = grads_of(params, batch)
            ce = auxd["ce"]
        if opts.compress_grads:
            err = state.opt.get("ef_err")
            if err is None:
                err = jax.tree.map(
                    lambda g: jnp.zeros(g.shape, jnp.float32), grads)
            pairs = jax.tree.map(_compress_int8, grads, err)
            grads = jax.tree.map(lambda o: o[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_err = jax.tree.map(lambda o: o[1], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
        new_params, new_opt, gnorm = adamw_update(
            grads, {k: v for k, v in state.opt.items() if k != "ef_err"},
            params, state.step, opt_cfg)
        if opts.compress_grads:
            new_opt["ef_err"] = new_err
        metrics = {"loss": loss, "ce": ce, "grad_norm": gnorm,
                   "step": state.step}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
