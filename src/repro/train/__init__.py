from .optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from .train_step import TrainOptions, TrainState, init_state, make_train_step
