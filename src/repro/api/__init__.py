"""repro.api — the unified execution API of the dwarf methodology.

Four public surfaces, one contract:

* **Stack protocol** (:mod:`repro.api.stack`): ``get_stack(name).run(x)``
  executes any proxy DAG, workload, or raw fn on any software stack
  (openmp / mpi / spark / hadoop) and returns a uniform :class:`RunReport`;
  ``run_batch`` vmaps over rng batches and ``run_population`` evaluates a
  whole batch of dynamic-param candidates through the ExecutionPlan's
  weight-stratified bucket schedule (the batched-autotuning axis — one
  shared executable per bucket size, buckets sharded over the stack's
  mesh).
* **Versioned ProxySpec** (:mod:`repro.api.spec`): declarative,
  schema-validated JSON specs with a full ``to_json``/``from_json``
  round-trip.
* **Pytree parameter space** (:mod:`repro.api.params`): every tunable
  flattened into a named, bounded vector for the auto-tuner and for
  gradient-free vectorized tuners — ``sample``/``sample_dynamic`` draw
  candidate matrices, ``stack_candidates``/``unstack_candidates`` convert
  between matrices and the batched dyn pytrees the executables consume.
* **Distillation pipeline** (:mod:`repro.core.engine` /
  :mod:`repro.core.subset`): :func:`fingerprint` measures any workload —
  a jitted fn, a recorded :class:`RunReport`, a ``ServeReport`` — into
  the engine's channel basis; :func:`tune_structure` accepts the
  fingerprint directly as its target; :func:`subset_fingerprints` keeps
  the suite small by clustering fingerprints down to representatives.

Quickstart::

    from repro.api import ProxySpec, get_stack
    spec = ProxySpec.load("proxy_terasort.json")
    report = get_stack(spec.stack).run(spec)
    print(report.wall_s, report.io_bytes)

Distillation quickstart::

    from repro.api import fingerprint, tune_structure
    fp = fingerprint(my_step_fn, example_args)   # measure anything jitted
    result = tune_structure(seed_proxy, fp)      # synthesize its proxy
"""

from . import params as params  # imported first: no repro.core dependencies
from .params import (CORE_FIELDS, EXTRA_BOUNDS, FIELD_BOUNDS, INT_FIELDS,
                     ParamLeaf, ParamSpace, bounds_for)
from .spec import (SPEC_VERSION, ProxySpec, SpecError,
                   validate_fingerprint_json, validate_spec_json)
from .stack import (FAILURE_CLASSES, HadoopStack, MPIStack, OpenMPStack,
                    RunReport, SparkStack, Stack, cache_cap, cache_stats,
                    classify_failure, failure_is_retryable, get_stack,
                    list_stacks, register_stack, reset_cache_stats)
from ..core.engine import (FINGERPRINT_CHANNELS, FINGERPRINT_VERSION,
                           WorkloadFingerprint, fingerprint)
from ..core.pool import ExecutablePool, get_pool, pool_stats
from ..core.subset import (SubsetReport, normalize_fingerprints,
                           subset_fingerprints)
from ..faults import FaultPlan, InjectedFailure, default_fault_rate


def tune_structure(proxy, target_metrics, **kw):
    """Tune the full Fig.-3 design space of ``proxy`` — structure *and*
    weights — toward ``target_metrics``.

    ``proxy`` may be a ``ProxyBenchmark``, ``ProxySpec``, or ``ProxyDAG``;
    ``target_metrics`` is either a hand-declared Table-3 metric dict or
    any measurement with a ``metrics()`` method — in particular a
    :class:`WorkloadFingerprint` from :func:`fingerprint`, which distills
    a proxy straight from a measured workload.  Keyword args configure
    :class:`repro.core.structsearch.StructuralTuner`
    (``max_candidates`` total budget, ``structure_budget_frac`` split,
    ``components`` mutation pool, ``seed_structures``, ...).  Returns a
    :class:`~repro.core.structsearch.StructuralTuneResult` whose ``proxy``
    holds the best machine-generated structure with tuned weights — ready
    for ``ProxySpec.from_benchmark`` serialization or any ``get_stack``
    execution."""
    from ..core.dag import ProxyDAG
    from ..core.proxy import ProxyBenchmark
    from ..core.structsearch import StructuralTuner
    if isinstance(proxy, ProxyDAG):
        proxy = ProxyBenchmark(dag=proxy)
    elif hasattr(proxy, "to_benchmark"):            # ProxySpec
        proxy = proxy.to_benchmark()
    return StructuralTuner(target_metrics, **kw).tune(proxy)


def serve(trace, **kw):
    """Serve a request stream through the proxy serving engine and return
    its :class:`~repro.serve.engine.ServeReport` (P50/P95/P99 latency,
    time to first result, sustained throughput, retrace accounting).

    ``trace`` is an :class:`~repro.serve.engine.ArrivalTrace` — build one
    with :func:`repro.serve.poisson_trace` / :func:`repro.serve.burst_trace`
    — or a plain request list; keyword args configure the engine
    (``stack``, ``max_batch``, ``bucket_size``, ``clock``, ``mode``,
    ``warmup``, ``batch_wait_s`` partial-chunk flush, ``faults`` — a
    seeded :class:`~repro.faults.FaultPlan` for chaos runs — plus the
    retry/backoff/circuit-breaker knobs)."""
    from ..serve.engine import serve as _serve
    return _serve(trace, **kw)


__all__ = [
    "CORE_FIELDS", "EXTRA_BOUNDS", "FIELD_BOUNDS", "INT_FIELDS",
    "ParamLeaf", "ParamSpace", "bounds_for",
    "SPEC_VERSION", "ProxySpec", "SpecError", "validate_spec_json",
    "HadoopStack", "MPIStack", "OpenMPStack", "RunReport", "SparkStack",
    "Stack", "cache_cap", "cache_stats", "get_stack", "list_stacks",
    "register_stack", "reset_cache_stats", "tune_structure",
    "ExecutablePool", "get_pool", "pool_stats", "serve",
    "FAILURE_CLASSES", "classify_failure", "failure_is_retryable",
    "FaultPlan", "InjectedFailure", "default_fault_rate",
    "FINGERPRINT_CHANNELS", "FINGERPRINT_VERSION", "WorkloadFingerprint",
    "fingerprint", "validate_fingerprint_json",
    "SubsetReport", "normalize_fingerprints", "subset_fingerprints",
]
