"""Versioned, schema-validated proxy-benchmark specs.

A ``ProxySpec`` is the declarative, serializable form of a dwarf-DAG proxy
benchmark (paper §2.3): sources, weighted component edges, sink, plus the
software stack and scale it targets.  It round-trips losslessly through
JSON (``to_json`` / ``from_json``), replacing the seed's write-only
``ProxyBenchmark.save``.

Version history
---------------
* **v1** (implicit): the seed's bare ``ProxyDAG.to_json()`` dict —
  ``{name, sources, edges, sink}`` with no ``spec_version`` field.
  ``from_json`` still accepts it.
* **v2** (current): adds ``spec_version``, ``description``, ``stack``
  and ``scale`` so a spec states *where* and *at what size* it runs.
"""

from __future__ import annotations

import dataclasses
import json
import numbers
import warnings
from typing import Any, Dict, List, Optional

from ..core.dag import Edge, ProxyDAG
from ..core.dwarfs.base import REGISTRY

SPEC_VERSION = 2

_EDGE_NUMERIC = ("data_size", "chunk_size", "parallelism", "weight")


def _is_num(v: Any) -> bool:
    """Accept any real number — machine-generated specs (structure
    mutations, tuner-applied vectors) may carry numpy scalars, which are
    ``numbers.Real`` but not ``int``/``float``; ``Edge.to_json``
    normalizes them to JSON-native types on the way back out."""
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


class SpecError(ValueError):
    """A proxy spec failed schema validation."""


def _fail(path: str, msg: str) -> None:
    raise SpecError(f"proxy spec invalid at {path}: {msg}")


def _check_edge(i: int, e: Any) -> None:
    path = f"edges[{i}]"
    if not isinstance(e, dict):
        _fail(path, f"expected object, got {type(e).__name__}")
    for key in ("component", "src", "dst"):
        if key not in e:
            _fail(path, f"missing required key {key!r}")
    if not isinstance(e["component"], str):
        _fail(f"{path}.component", "expected string")
    if e["component"] not in REGISTRY:
        _fail(f"{path}.component",
              f"unknown dwarf component {e['component']!r}; "
              f"known: {sorted(REGISTRY)}")
    if (not isinstance(e["src"], (list, tuple)) or not e["src"]
            or not all(isinstance(s, str) for s in e["src"])):
        _fail(f"{path}.src", "expected non-empty list of node names")
    if not isinstance(e["dst"], str):
        _fail(f"{path}.dst", "expected string node name")
    for key in _EDGE_NUMERIC:
        v = e.get(key)
        if v is not None and not _is_num(v):
            _fail(f"{path}.{key}", f"expected number, got {type(v).__name__}")
    extra = e.get("extra", {})
    if not isinstance(extra, dict):
        _fail(f"{path}.extra", "expected object")
    for k, v in extra.items():
        if not isinstance(k, str):
            _fail(f"{path}.extra", f"non-string key {k!r}")
        if not (_is_num(v) or isinstance(v, (str, bool))):
            _fail(f"{path}.extra[{k!r}]",
                  f"expected JSON scalar, got {type(v).__name__}")


def validate_spec_json(d: Any) -> None:
    """Raise :class:`SpecError` with a precise path if ``d`` is malformed."""
    if not isinstance(d, dict):
        _fail("$", f"expected object, got {type(d).__name__}")
    version = d.get("spec_version", 1)
    if not isinstance(version, int):
        _fail("spec_version", "expected integer")
    if version > SPEC_VERSION:
        _fail("spec_version",
              f"spec_version {version} is newer than supported {SPEC_VERSION}")
    if not isinstance(d.get("name"), str) or not d.get("name"):
        _fail("name", "expected non-empty string")
    sources = d.get("sources")
    if not isinstance(sources, dict) or not sources:
        _fail("sources", "expected non-empty object of node -> element count")
    for k, v in sources.items():
        if not isinstance(k, str):
            _fail("sources", f"non-string node name {k!r}")
        if not _is_num(v) or v <= 0:
            _fail(f"sources[{k!r}]", "expected positive element count")
    edges = d.get("edges")
    if not isinstance(edges, list):
        _fail("edges", "expected list")
    for i, e in enumerate(edges):
        _check_edge(i, e)
    sink = d.get("sink")
    if sink is not None and not isinstance(sink, str):
        _fail("sink", "expected string or null")
    if version >= 2:
        stack = d.get("stack", "openmp")
        if not isinstance(stack, str):
            _fail("stack", "expected string stack name")
        from .stack import _STACKS
        if stack not in _STACKS:
            # warn, not fail: the stack registry is extensible at runtime
            warnings.warn(f"proxy spec names unregistered stack {stack!r} "
                          f"(known: {sorted(_STACKS)})", UserWarning,
                          stacklevel=3)
        scale = d.get("scale")
        if scale is not None and not isinstance(scale, (str, int)):
            _fail("scale", "expected string, integer, or null")
        if not isinstance(d.get("description", ""), str):
            _fail("description", "expected string")


def validate_fingerprint_json(d: Any) -> None:
    """Raise :class:`SpecError` with a precise path if ``d`` is not a valid
    serialized :class:`repro.core.engine.WorkloadFingerprint`."""
    from ..core.engine import FINGERPRINT_CHANNELS, FINGERPRINT_VERSION
    if not isinstance(d, dict):
        _fail("$", f"expected object, got {type(d).__name__}")
    version = d.get("fingerprint_version")
    if not isinstance(version, int) or isinstance(version, bool):
        _fail("fingerprint_version", "expected integer")
    if version > FINGERPRINT_VERSION:
        _fail("fingerprint_version",
              f"fingerprint_version {version} is newer than supported "
              f"{FINGERPRINT_VERSION}")
    if not isinstance(d.get("name"), str) or not d.get("name"):
        _fail("name", "expected non-empty string")
    if "source" in d and not isinstance(d["source"], str):
        _fail("source", "expected string")
    hb = d.get("host_bytes", 0.0)
    if not _is_num(hb) or hb < 0:
        _fail("host_bytes", "expected non-negative number")
    channels = d.get("channels")
    if not isinstance(channels, dict):
        _fail("channels", "expected object of channel -> value")
    for k in FINGERPRINT_CHANNELS:
        if k not in channels:
            _fail("channels", f"missing required channel {k!r}")
        if not _is_num(channels[k]):
            _fail(f"channels[{k!r}]",
                  f"expected number, got {type(channels[k]).__name__}")
    unknown = sorted(set(channels) - set(FINGERPRINT_CHANNELS))
    if unknown:
        _fail("channels", f"unknown channel(s) {unknown}; "
              f"known: {list(FINGERPRINT_CHANNELS)}")


@dataclasses.dataclass
class ProxySpec:
    """Declarative proxy benchmark: DAG + target stack + scale."""

    name: str
    sources: Dict[str, int]
    edges: List[Dict[str, Any]]            # normalized edge dicts
    sink: Optional[str] = None
    stack: str = "openmp"
    scale: Optional[Any] = None
    description: str = ""
    spec_version: int = SPEC_VERSION

    # -- JSON ----------------------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        return {
            "spec_version": SPEC_VERSION,
            "name": self.name,
            "description": self.description,
            "stack": self.stack,
            "scale": self.scale,
            "sources": {k: int(v) for k, v in self.sources.items()},
            "edges": [dict(e) for e in self.edges],
            "sink": self.sink,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ProxySpec":
        validate_spec_json(d)
        # Edge.from_json/to_json are the single source of edge defaults and
        # of the normalized (legal-value) edge-dict shape
        edges = [Edge.from_json(e).to_json() for e in d["edges"]]
        spec = cls(
            name=d["name"],
            sources={k: int(v) for k, v in d["sources"].items()},
            edges=edges,
            sink=d.get("sink"),
            stack=d.get("stack", "openmp"),
            scale=d.get("scale"),
            description=d.get("description", ""),
        )
        # surface topology errors at load time, not first run
        spec.to_dag().validate()
        return spec

    def dumps(self, indent: int = 2) -> str:
        return json.dumps(self.to_json(), indent=indent)

    @classmethod
    def loads(cls, text: str) -> "ProxySpec":
        return cls.from_json(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())

    @classmethod
    def load(cls, path: str) -> "ProxySpec":
        with open(path) as f:
            return cls.loads(f.read())

    # -- DAG interop ---------------------------------------------------------

    def to_dag(self) -> ProxyDAG:
        return ProxyDAG(
            name=self.name,
            sources={k: int(v) for k, v in self.sources.items()},
            edges=[Edge.from_json(e) for e in self.edges],
            sink=self.sink)

    @classmethod
    def from_dag(cls, dag: ProxyDAG, stack: str = "openmp",
                 scale: Optional[Any] = None,
                 description: str = "") -> "ProxySpec":
        return cls(
            name=dag.name,
            sources=dict(dag.sources),
            edges=[e.to_json() for e in dag.edges],
            sink=dag.sink,
            stack=stack, scale=scale, description=description)

    # -- benchmark interop ---------------------------------------------------

    def to_benchmark(self):
        from ..core.proxy import ProxyBenchmark
        return ProxyBenchmark(dag=self.to_dag(), description=self.description)

    @classmethod
    def from_benchmark(cls, proxy, stack: str = "openmp",
                       scale: Optional[Any] = None) -> "ProxySpec":
        return cls.from_dag(proxy.dag, stack=stack, scale=scale,
                            description=proxy.description)
