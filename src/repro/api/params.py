"""Pytree parameter space over a ProxyDAG's tunables.

The paper's auto-tuning tool adjusts per-component parameters (Table 2:
data size / chunk size / parallelism / weight, plus per-component input
parameters such as the centroid-set size).  The seed plumbed these through
stringly-typed ``(edge_idx, field)`` handles; this module flattens every
tunable into a *named pytree* with per-leaf bounds so tuners operate on a
plain vector — which is also the shape a gradient-free vectorized tuner
(CMA-ES, random search over ``ParamSpace.sample``) wants.

The space is purely structural: it is built once from a DAG's topology and
can then read/write the parameter vector of any clone with the same
topology.  No imports from ``repro.core`` — it only relies on the duck
interface ``dag.edges[i].component / .params``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

#: canonical Table-2 tunables present on every component
CORE_FIELDS = ("data_size", "chunk_size", "parallelism", "weight")

#: bounds for the canonical fields plus well-known extras
FIELD_BOUNDS: Dict[str, Tuple[float, float]] = {
    "data_size": (256.0, float(1 << 26)),
    "chunk_size": (8.0, float(1 << 20)),
    "parallelism": (1.0, 256.0),
    "weight": (0.0, 128.0),
    "fraction": (0.05, 1.0),
    "stride": (1.0, 64.0),
    # loop-count extras: execution cost is linear in these, so the generic
    # EXTRA_BOUNDS ceiling (4M) would let a random tuner draw candidates
    # that run for hours; real hash/mix pipelines use a handful of rounds
    "rounds": (1.0, 64.0),
    "mix_rounds": (1.0, 16.0),
    "hops": (1.0, 64.0),
    "levels": (1.0, 16.0),
    # AI-dwarf shape extras (static leaves: moving them recompiles).  The
    # generic EXTRA_BOUNDS ceiling (4M) would let a tuner draw a 4M-token
    # attention window (S^2 cost) or a 4M-wide SSM state — bound them to
    # the ranges core/dwarfs/ai.py sanitizes to.
    "seq_len": (8.0, 1024.0),
    "heads": (1.0, 16.0),
    "kv_heads": (1.0, 16.0),
    "state": (2.0, 64.0),
}

#: fallback bounds for numeric ``extra`` entries (centers, vertices, bins, ...)
EXTRA_BOUNDS: Tuple[float, float] = (1.0, float(1 << 22))

#: fields that must stay integral after a tuner step
INT_FIELDS = {"data_size", "chunk_size", "parallelism", "weight", "stride",
              "centers", "vertices", "bins", "groups", "buckets", "hops",
              "rounds", "levels", "k", "seq_len", "heads", "kv_heads",
              "state"}


def bounds_for(field: str) -> Tuple[float, float]:
    """(lo, hi) clamp range for a tunable field, ``EXTRA_BOUNDS`` for
    free-form ``extra`` keys not in ``FIELD_BOUNDS``."""
    return FIELD_BOUNDS.get(field, EXTRA_BOUNDS)


@dataclasses.dataclass(frozen=True)
class ParamLeaf:
    """One tunable: a named leaf of the parameter pytree."""

    name: str          # e.g. "e2.quick_sort.weight"
    edge_idx: int
    field: str         # ComponentParams field or numeric extra key
    lo: float
    hi: float
    integer: bool
    #: True for retrace-free tunables (weight + shape-free extras): stepping
    #: them re-runs the cached executable; static leaves change the
    #: structure key and recompile
    dynamic: bool = False

    @property
    def is_extra(self) -> bool:
        return self.field not in CORE_FIELDS

    def effective_bounds(self) -> Tuple[float, float]:
        """Bounds every clamped value must satisfy *after* integral
        rounding.  For integer leaves the interval tightens to the integers
        inside ``[lo, hi]`` — rounding a clamped value must never escape
        the nominal bounds (e.g. ``hi=7.5`` clamping 8.0 to 7.5 and then
        rounding back up to 8.0)."""
        if not self.integer:
            return (self.lo, self.hi)
        lo_i = math.ceil(self.lo)
        hi_i = math.floor(self.hi)
        if hi_i < lo_i:            # no integer inside: degenerate interval
            lo_i = hi_i = round((self.lo + self.hi) / 2.0)
        return (float(lo_i), float(hi_i))

    def clamp_value(self, v: float) -> float:
        """One value clamped into bounds, integral-safe (round *inside*
        the bounds, never out of them)."""
        lo, hi = self.effective_bounds()
        v = float(min(max(float(v), lo), hi))
        return float(min(max(round(v), lo), hi)) if self.integer else v


def _is_numeric(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


class ParamSpace:
    """Named, bounded, flat view over every tunable of a ProxyDAG."""

    def __init__(self, leaves: Sequence[ParamLeaf], dag_name: str = ""):
        self.leaves: List[ParamLeaf] = list(leaves)
        self.dag_name = dag_name
        self._index = {l.name: i for i, l in enumerate(self.leaves)}

    # -- construction --------------------------------------------------------

    @classmethod
    def from_dag(cls, dag) -> "ParamSpace":
        leaves: List[ParamLeaf] = []
        for i, e in enumerate(dag.edges):
            prefix = f"e{i}.{e.component}"
            # retrace-free fields per the duck interface (plain edge objects
            # without the static/dynamic split expose only weight)
            dyn = set(e.dynamic_fields()) if hasattr(e, "dynamic_fields") \
                else {"weight"}
            for f in CORE_FIELDS:
                lo, hi = bounds_for(f)
                leaves.append(ParamLeaf(f"{prefix}.{f}", i, f, lo, hi,
                                        f in INT_FIELDS, dynamic=f in dyn))
            for k in sorted(e.params.extra):
                if not _is_numeric(e.params.extra[k]):
                    continue
                lo, hi = bounds_for(k)
                leaves.append(ParamLeaf(f"{prefix}.{k}", i, k, lo, hi,
                                        k in INT_FIELDS, dynamic=k in dyn))
        return cls(leaves, dag_name=getattr(dag, "name", ""))

    # -- introspection -------------------------------------------------------

    def __len__(self) -> int:
        return len(self.leaves)

    @property
    def names(self) -> List[str]:
        return [l.name for l in self.leaves]

    def index_of(self, name: str) -> int:
        return self._index[name]

    def dynamic_names(self) -> List[str]:
        """Leaves steppable without an XLA retrace (the run-many axis)."""
        return [l.name for l in self.leaves if l.dynamic]

    def is_dynamic(self, name: str) -> bool:
        return self.leaves[self._index[name]].dynamic

    def handle(self, i: int) -> Tuple[int, str]:
        """Legacy ``(edge_idx, field)`` handle for leaf ``i`` (deprecated API)."""
        l = self.leaves[i]
        return (l.edge_idx, l.field)

    def lower(self) -> np.ndarray:
        return np.array([l.lo for l in self.leaves], dtype=np.float64)

    def upper(self) -> np.ndarray:
        return np.array([l.hi for l in self.leaves], dtype=np.float64)

    # -- read / write --------------------------------------------------------

    def _read_leaf(self, dag, l: ParamLeaf) -> float:
        p = dag.edges[l.edge_idx].params
        return float(p.extra[l.field] if l.is_extra else getattr(p, l.field))

    def values(self, dag) -> np.ndarray:
        """Current parameter vector of ``dag`` in this space's leaf order."""
        return np.array([self._read_leaf(dag, l) for l in self.leaves],
                        dtype=np.float64)

    def apply(self, dag, values: Sequence[float], clamp: bool = True) -> None:
        """Write a parameter vector back into ``dag``.

        Changed leaves are clamped to bounds (integral fields rounded);
        leaves whose requested value equals the dag's current value are
        left untouched, so writing back an unmodified vector is a no-op
        even when existing parameters sit outside the nominal bounds —
        a single-leaf probe must never silently rewrite its neighbours.

        ``clamp=False`` writes raw values: required when *restoring* a
        previously-read vector whose entries may lie outside the nominal
        bounds (a tuner revert must reproduce the exact prior state).
        """
        if len(values) != len(self.leaves):
            raise ValueError(f"expected {len(self.leaves)} values, "
                             f"got {len(values)}")
        for l, v in zip(self.leaves, values):
            v = float(v)
            if v == self._read_leaf(dag, l):
                continue
            if clamp:
                v = l.clamp_value(v)
            p = dag.edges[l.edge_idx].params
            if l.is_extra:
                p.extra[l.field] = v
            else:
                setattr(p, l.field, v)

    # -- pytree views --------------------------------------------------------

    def tree(self, dag) -> Dict[str, Dict[str, float]]:
        """Nested ``{edge: {field: value}}`` pytree of the current values."""
        out: Dict[str, Dict[str, float]] = {}
        for l in self.leaves:
            out.setdefault(f"e{l.edge_idx}.{dag.edges[l.edge_idx].component}",
                           {})[l.field] = self._read_leaf(dag, l)
        return out

    def bounds_tree(self) -> Dict[str, Dict[str, Tuple[float, float]]]:
        """Matching pytree of ``(lo, hi)`` bounds per leaf."""
        out: Dict[str, Dict[str, Tuple[float, float]]] = {}
        for l in self.leaves:
            out.setdefault(l.name.rsplit(".", 1)[0], {})[l.field] = (l.lo, l.hi)
        return out

    def apply_tree(self, dag, tree: Dict[str, Dict[str, float]]) -> None:
        vec = self.values(dag)
        for edge_key, fields in tree.items():
            for field, v in fields.items():
                vec[self._index[f"{edge_key}.{field}"]] = v
        self.apply(dag, vec)

    # -- vectorized-tuner support -------------------------------------------

    def dynamic_mask(self) -> np.ndarray:
        """Boolean mask over leaves: True for retrace-free tunables."""
        return np.array([l.dynamic for l in self.leaves], dtype=bool)

    def clamp(self, values: np.ndarray) -> np.ndarray:
        """Clamp a vector (or ``(n, len(self))`` matrix) of candidate
        values into bounds.  Integer leaves round *inside* their bounds:
        the result always satisfies ``lower() <= v <= upper()`` leaf-wise,
        even for fractional bounds where plain round-after-clamp would
        drift out (the population tuners rely on this invariant)."""
        v = np.minimum(np.maximum(np.asarray(values, np.float64),
                                  self.lower()), self.upper())
        ints = np.array([l.integer for l in self.leaves], dtype=bool)
        if ints.any():
            eff = np.array([l.effective_bounds() for l in self.leaves],
                           dtype=np.float64)
            lo_i, hi_i = eff[ints, 0], eff[ints, 1]
            v[..., ints] = np.minimum(np.maximum(
                np.round(np.minimum(np.maximum(v[..., ints], lo_i), hi_i)),
                lo_i), hi_i)
        return v

    def sample(self, n: int, seed: int = 0) -> np.ndarray:
        """(n, len(self)) log-uniform candidate vectors within bounds —
        the entry point for gradient-free vectorized tuners.  Deterministic
        for a fixed seed (``np.random.RandomState`` is specified to be
        stable across processes and platforms)."""
        rs = np.random.RandomState(seed)
        lo, hi = self.lower(), self.upper()
        llo = np.log(np.maximum(lo, 1e-3))
        lhi = np.log(np.maximum(hi, 1e-3))
        raw = np.exp(rs.uniform(llo, lhi, size=(n, len(self.leaves))))
        return self.clamp(raw)

    def sample_dynamic(self, n: int, base: Sequence[float],
                       seed: int = 0) -> np.ndarray:
        """(n, len(self)) candidates that resample only the *dynamic*
        leaves (log-uniform within bounds) and keep every static leaf at
        ``base`` — the population shares one compiled structure, so a
        whole batch evaluates through a single vmapped executable."""
        base = np.asarray(base, np.float64)
        if base.shape != (len(self.leaves),):
            raise ValueError(f"base must have shape ({len(self.leaves)},), "
                             f"got {base.shape}")
        out = np.tile(base, (n, 1))
        dyn = self.dynamic_mask()
        if dyn.any():
            out[:, dyn] = self.sample(n, seed=seed)[:, dyn]
        return out

    # -- population pytrees (stack/unstack between sample() matrices and
    #    the dyn pytrees ProxyDAG.build_population consumes) ----------------

    def _dynamic_columns(self, dag) -> List[Tuple[int, int, str]]:
        """(leaf_idx, edge_idx, field) for each dynamic leaf, ordered to
        match ``dag.dynamic_params()``'s per-edge dict layout."""
        cols = []
        for i, e in enumerate(dag.edges):
            prefix = f"e{i}.{e.component}"
            fields = e.dynamic_fields() if hasattr(e, "dynamic_fields") \
                else ("weight",)
            for f in fields:
                cols.append((self._index[f"{prefix}.{f}"], i, f))
        return cols

    def stack_candidates(self, dag, matrix: np.ndarray, strict: bool = True):
        """Stack ``(n, len(self))`` candidate rows into one batched
        dynamic-param pytree (the candidate axis leading every leaf) for
        :meth:`ProxyDAG.build_population` / ``Stack.run_population``.

        ``dag.dynamic_params()`` is the layout/dtype template: each dynamic
        leaf's matrix column becomes that leaf's stacked value.  Static
        columns cannot ride along — the whole population shares the dag's
        compiled structure — so ``strict=True`` (default) raises if any
        static column deviates from the dag's current value instead of
        silently ignoring it."""
        import jax.numpy as jnp   # lazy: this module stays numpy-importable

        matrix = np.asarray(matrix, np.float64)
        if matrix.ndim != 2 or matrix.shape[1] != len(self.leaves):
            raise ValueError(f"expected a (n, {len(self.leaves)}) candidate "
                             f"matrix, got shape {matrix.shape}")
        if strict:
            static = ~self.dynamic_mask()
            cur = self.values(dag)[static]
            bad = np.nonzero((matrix[:, static] != cur).any(axis=0))[0]
            if bad.size:
                names = [np.array(self.names)[static][b] for b in bad[:4]]
                raise ValueError(
                    f"candidate matrix changes static leaves {names} — a "
                    f"population shares one compiled structure; tune static "
                    f"leaves through the engine cost model instead (or pass "
                    f"strict=False to pin them to the dag's current values)")
        template = dag.dynamic_params()
        batched = [dict(d) for d in template]
        for li, ei, field in self._dynamic_columns(dag):
            col = matrix[:, li]
            tmpl = template[ei][field]
            if jnp.issubdtype(tmpl.dtype, jnp.integer):
                col = np.round(col)
            batched[ei][field] = jnp.asarray(col, tmpl.dtype)
        return tuple(batched)

    def unstack_candidates(self, batched) -> List[Tuple[Dict[str, Any], ...]]:
        """Split a stacked dyn pytree back into per-candidate pytrees
        (each shaped like ``dag.dynamic_params()``) — the sequential-
        evaluation form the population property tests loop over."""
        sizes = {int(v.shape[0]) for d in batched for v in d.values()}
        if len(sizes) > 1:
            raise ValueError(f"inconsistent candidate-axis sizes: {sizes}")
        n = sizes.pop() if sizes else 0
        return [tuple({k: v[i] for k, v in d.items()} for d in batched)
                for i in range(n)]
