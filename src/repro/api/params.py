"""Pytree parameter space over a ProxyDAG's tunables.

The paper's auto-tuning tool adjusts per-component parameters (Table 2:
data size / chunk size / parallelism / weight, plus per-component input
parameters such as the centroid-set size).  The seed plumbed these through
stringly-typed ``(edge_idx, field)`` handles; this module flattens every
tunable into a *named pytree* with per-leaf bounds so tuners operate on a
plain vector — which is also the shape a gradient-free vectorized tuner
(CMA-ES, random search over ``ParamSpace.sample``) wants.

The space is purely structural: it is built once from a DAG's topology and
can then read/write the parameter vector of any clone with the same
topology.  No imports from ``repro.core`` — it only relies on the duck
interface ``dag.edges[i].component / .params``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

#: canonical Table-2 tunables present on every component
CORE_FIELDS = ("data_size", "chunk_size", "parallelism", "weight")

#: bounds for the canonical fields plus well-known extras
FIELD_BOUNDS: Dict[str, Tuple[float, float]] = {
    "data_size": (256.0, float(1 << 26)),
    "chunk_size": (8.0, float(1 << 20)),
    "parallelism": (1.0, 256.0),
    "weight": (0.0, 128.0),
    "fraction": (0.05, 1.0),
    "stride": (1.0, 64.0),
}

#: fallback bounds for numeric ``extra`` entries (centers, vertices, bins, ...)
EXTRA_BOUNDS: Tuple[float, float] = (1.0, float(1 << 22))

#: fields that must stay integral after a tuner step
INT_FIELDS = {"data_size", "chunk_size", "parallelism", "weight", "stride",
              "centers", "vertices", "bins", "groups", "buckets", "hops",
              "rounds", "levels", "k"}


def bounds_for(field: str) -> Tuple[float, float]:
    return FIELD_BOUNDS.get(field, EXTRA_BOUNDS)


@dataclasses.dataclass(frozen=True)
class ParamLeaf:
    """One tunable: a named leaf of the parameter pytree."""

    name: str          # e.g. "e2.quick_sort.weight"
    edge_idx: int
    field: str         # ComponentParams field or numeric extra key
    lo: float
    hi: float
    integer: bool
    #: True for retrace-free tunables (weight + shape-free extras): stepping
    #: them re-runs the cached executable; static leaves change the
    #: structure key and recompile
    dynamic: bool = False

    @property
    def is_extra(self) -> bool:
        return self.field not in CORE_FIELDS


def _is_numeric(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


class ParamSpace:
    """Named, bounded, flat view over every tunable of a ProxyDAG."""

    def __init__(self, leaves: Sequence[ParamLeaf], dag_name: str = ""):
        self.leaves: List[ParamLeaf] = list(leaves)
        self.dag_name = dag_name
        self._index = {l.name: i for i, l in enumerate(self.leaves)}

    # -- construction --------------------------------------------------------

    @classmethod
    def from_dag(cls, dag) -> "ParamSpace":
        leaves: List[ParamLeaf] = []
        for i, e in enumerate(dag.edges):
            prefix = f"e{i}.{e.component}"
            # retrace-free fields per the duck interface (plain edge objects
            # without the static/dynamic split expose only weight)
            dyn = set(e.dynamic_fields()) if hasattr(e, "dynamic_fields") \
                else {"weight"}
            for f in CORE_FIELDS:
                lo, hi = bounds_for(f)
                leaves.append(ParamLeaf(f"{prefix}.{f}", i, f, lo, hi,
                                        f in INT_FIELDS, dynamic=f in dyn))
            for k in sorted(e.params.extra):
                if not _is_numeric(e.params.extra[k]):
                    continue
                lo, hi = bounds_for(k)
                leaves.append(ParamLeaf(f"{prefix}.{k}", i, k, lo, hi,
                                        k in INT_FIELDS, dynamic=k in dyn))
        return cls(leaves, dag_name=getattr(dag, "name", ""))

    # -- introspection -------------------------------------------------------

    def __len__(self) -> int:
        return len(self.leaves)

    @property
    def names(self) -> List[str]:
        return [l.name for l in self.leaves]

    def index_of(self, name: str) -> int:
        return self._index[name]

    def dynamic_names(self) -> List[str]:
        """Leaves steppable without an XLA retrace (the run-many axis)."""
        return [l.name for l in self.leaves if l.dynamic]

    def is_dynamic(self, name: str) -> bool:
        return self.leaves[self._index[name]].dynamic

    def handle(self, i: int) -> Tuple[int, str]:
        """Legacy ``(edge_idx, field)`` handle for leaf ``i`` (deprecated API)."""
        l = self.leaves[i]
        return (l.edge_idx, l.field)

    def lower(self) -> np.ndarray:
        return np.array([l.lo for l in self.leaves], dtype=np.float64)

    def upper(self) -> np.ndarray:
        return np.array([l.hi for l in self.leaves], dtype=np.float64)

    # -- read / write --------------------------------------------------------

    def _read_leaf(self, dag, l: ParamLeaf) -> float:
        p = dag.edges[l.edge_idx].params
        return float(p.extra[l.field] if l.is_extra else getattr(p, l.field))

    def values(self, dag) -> np.ndarray:
        """Current parameter vector of ``dag`` in this space's leaf order."""
        return np.array([self._read_leaf(dag, l) for l in self.leaves],
                        dtype=np.float64)

    def apply(self, dag, values: Sequence[float], clamp: bool = True) -> None:
        """Write a parameter vector back into ``dag``.

        Changed leaves are clamped to bounds (integral fields rounded);
        leaves whose requested value equals the dag's current value are
        left untouched, so writing back an unmodified vector is a no-op
        even when existing parameters sit outside the nominal bounds —
        a single-leaf probe must never silently rewrite its neighbours.

        ``clamp=False`` writes raw values: required when *restoring* a
        previously-read vector whose entries may lie outside the nominal
        bounds (a tuner revert must reproduce the exact prior state).
        """
        if len(values) != len(self.leaves):
            raise ValueError(f"expected {len(self.leaves)} values, "
                             f"got {len(values)}")
        for l, v in zip(self.leaves, values):
            v = float(v)
            if v == self._read_leaf(dag, l):
                continue
            if clamp:
                v = float(min(max(v, l.lo), l.hi))
                if l.integer:
                    v = float(round(v))
            p = dag.edges[l.edge_idx].params
            if l.is_extra:
                p.extra[l.field] = v
            else:
                setattr(p, l.field, v)

    # -- pytree views --------------------------------------------------------

    def tree(self, dag) -> Dict[str, Dict[str, float]]:
        """Nested ``{edge: {field: value}}`` pytree of the current values."""
        out: Dict[str, Dict[str, float]] = {}
        for l in self.leaves:
            out.setdefault(f"e{l.edge_idx}.{dag.edges[l.edge_idx].component}",
                           {})[l.field] = self._read_leaf(dag, l)
        return out

    def bounds_tree(self) -> Dict[str, Dict[str, Tuple[float, float]]]:
        """Matching pytree of ``(lo, hi)`` bounds per leaf."""
        out: Dict[str, Dict[str, Tuple[float, float]]] = {}
        for l in self.leaves:
            out.setdefault(l.name.rsplit(".", 1)[0], {})[l.field] = (l.lo, l.hi)
        return out

    def apply_tree(self, dag, tree: Dict[str, Dict[str, float]]) -> None:
        vec = self.values(dag)
        for edge_key, fields in tree.items():
            for field, v in fields.items():
                vec[self._index[f"{edge_key}.{field}"]] = v
        self.apply(dag, vec)

    # -- vectorized-tuner support -------------------------------------------

    def clamp(self, values: np.ndarray) -> np.ndarray:
        v = np.minimum(np.maximum(np.asarray(values, np.float64),
                                  self.lower()), self.upper())
        ints = np.array([l.integer for l in self.leaves])
        v[ints] = np.round(v[ints])
        return v

    def sample(self, n: int, seed: int = 0) -> np.ndarray:
        """(n, len(self)) log-uniform candidate vectors within bounds —
        the entry point for gradient-free vectorized tuners."""
        rs = np.random.RandomState(seed)
        lo, hi = self.lower(), self.upper()
        llo = np.log(np.maximum(lo, 1e-3))
        lhi = np.log(np.maximum(hi, 1e-3))
        raw = np.exp(rs.uniform(llo, lhi, size=(n, len(self.leaves))))
        return np.stack([self.clamp(r) for r in raw])
