"""Software-stack execution protocol (paper §2.2.2, unified API).

The paper implements every dwarf component on OpenMP / MPI / Hadoop / Spark
because "software stack has great influences on workload behaviors".  The
seed exposed four ad-hoc functions with different signatures; this module
redesigns that axis around one contract:

    stack = get_stack("hadoop")
    report = stack.run(executable, *args)     # -> RunReport

where ``executable`` may be a raw jit-able function, a ``ProxyDAG``, a
``ProxyBenchmark``, a ``ProxySpec``, or a ``Workload`` — the stack coerces
it and reports result, wall time and host<->device traffic uniformly.
``run_batch`` vmaps rng-driven executables over a batch of keys for
high-throughput proxy serving.

JAX-native execution models:

  * ``openmp``  — single-process jit; XLA intra-op threading = OpenMP threads.
  * ``mpi``     — explicit SPMD via shard_map over a device mesh with the
                  collectives spelled out (the MPI execution model).
  * ``spark``   — global-view jit with input sharding constraints;
                  intermediates stay device-resident ("in-memory RDD").
  * ``hadoop``  — staged execution: every intermediate DAG node is
                  materialized through *host* memory ("HDFS spill"), which is
                  the disk-I/O behaviour the paper measures for Hadoop jobs.
"""

from __future__ import annotations

import abc
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax>=0.4.30 experimental location; stubbed out if unavailable
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # pragma: no cover - depends on container jax build
    _shard_map = None

from ..core.dag import ProxyDAG


# ---------------------------------------------------------------------------
# RunReport
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunReport:
    """Uniform result of ``Stack.run`` across every software stack."""

    stack: str                   # registry name of the executing stack
    wall_s: float                # end-to-end wall time (incl. compile)
    io_bytes: float              # host<->device traffic ("disk I/O" analog)
    result: Any = None           # the executable's output pytree
    batch: int = 1               # number of rng instances executed
    result_bytes: float = 0.0    # size of the output pytree

    @property
    def throughput(self) -> float:
        """Executions per second (batched proxy serving metric)."""
        return self.batch / max(self.wall_s, 1e-12)

    @property
    def io_bandwidth(self) -> float:
        """Host-traffic bandwidth in bytes/s (paper Fig. 7 analog)."""
        return self.io_bytes / max(self.wall_s, 1e-12)

    def to_json(self) -> Dict[str, float]:
        return {"stack": self.stack, "wall_s": self.wall_s,
                "io_bytes": self.io_bytes, "batch": self.batch,
                "result_bytes": self.result_bytes,
                "throughput": self.throughput}


def _tree_bytes(out: Any) -> float:
    # jax/np arrays expose .nbytes without a device-to-host transfer;
    # only Python scalars need materializing
    total = 0.0
    for x in jax.tree_util.tree_leaves(out):
        nbytes = getattr(x, "nbytes", None)
        total += float(np.asarray(x).nbytes if nbytes is None else nbytes)
    return total


# ---------------------------------------------------------------------------
# Executable coercion
# ---------------------------------------------------------------------------


def _extract_dag(executable: Any) -> Optional[ProxyDAG]:
    if isinstance(executable, ProxyDAG):
        return executable
    dag = getattr(executable, "dag", None)          # ProxyBenchmark
    if isinstance(dag, ProxyDAG):
        return dag
    if hasattr(executable, "to_dag"):               # ProxySpec
        return executable.to_dag()
    return None


def _as_fn(executable: Any, args: Tuple) -> Tuple[Callable, Tuple]:
    """Coerce (executable, args) -> (jit-able fn, concrete args)."""
    if callable(executable) and not hasattr(executable, "make_inputs"):
        return executable, args
    if hasattr(executable, "make_inputs"):          # core.workloads.Workload
        from ..core.workloads import workload_step_fn
        scale = args[0] if args else "tiny"
        return workload_step_fn(executable.name, scale)
    raise TypeError(f"cannot execute object of type "
                    f"{type(executable).__name__} on a Stack; expected a "
                    f"callable, ProxyDAG, ProxyBenchmark, ProxySpec, or "
                    f"Workload")


def _default_rng(rng: Optional[jax.Array]) -> jax.Array:
    return jax.random.PRNGKey(0) if rng is None else rng


# ---------------------------------------------------------------------------
# Stack protocol
# ---------------------------------------------------------------------------


class Stack(abc.ABC):
    """One software-stack execution model.  Subclasses implement
    ``_execute(fn, args) -> (result, io_bytes)``; everything else —
    executable coercion, timing, batching, reporting — is shared."""

    name: str = "abstract"

    @abc.abstractmethod
    def _execute(self, fn: Callable, args: Tuple) -> Tuple[Any, float]:
        """Run ``fn(*args)`` under this execution model.
        Returns ``(result, io_bytes)``."""

    def _execute_dag(self, dag: ProxyDAG, fn: Callable, args: Tuple
                     ) -> Tuple[Any, float]:
        """DAG-aware execution hook; default = treat the built fn opaquely."""
        return self._execute(fn, args)

    # -- public API ----------------------------------------------------------

    def run(self, executable: Any, *args,
            rng: Optional[jax.Array] = None) -> RunReport:
        """Execute anything on this stack and report uniformly."""
        dag = _extract_dag(executable)
        t0 = time.perf_counter()
        if dag is not None:
            if args:
                raise TypeError(
                    f"{type(executable).__name__} executables take no "
                    f"positional args; pass the PRNG key as rng=...")
            fargs = (_default_rng(rng),)
            result, io_bytes = self._execute_dag(dag, dag.build(), fargs)
        else:
            fn, fargs = _as_fn(executable, args)
            if rng is not None:
                if hasattr(executable, "make_inputs"):
                    raise TypeError("Workload executables generate their own "
                                    "inputs; rng= only applies to DAG or "
                                    "rng-driven fn executables")
                fargs = (*fargs, rng)    # fn(*args, rng) convention
            result, io_bytes = self._execute(fn, fargs)
        wall = time.perf_counter() - t0
        return RunReport(stack=self.name, wall_s=wall, io_bytes=io_bytes,
                         result=result, batch=1,
                         result_bytes=_tree_bytes(result))

    def run_batch(self, executable: Any,
                  rngs: jax.Array) -> RunReport:
        """Vectorized execution of an rng-driven executable over a batch of
        PRNG keys (high-throughput proxy serving)."""
        dag = _extract_dag(executable)
        if dag is not None:
            fn = dag.build()
        elif callable(executable):
            fn = executable
        else:
            raise TypeError("run_batch needs an rng-driven executable "
                            "(ProxyDAG/ProxyBenchmark/ProxySpec or fn(rng))")
        batch = int(rngs.shape[0])
        t0 = time.perf_counter()
        result, io_bytes = self._execute_batch(fn, rngs)
        wall = time.perf_counter() - t0
        return RunReport(stack=self.name, wall_s=wall, io_bytes=io_bytes,
                         result=result, batch=batch,
                         result_bytes=_tree_bytes(result))

    def _execute_batch(self, fn: Callable, rngs: jax.Array
                       ) -> Tuple[Any, float]:
        return self._execute(jax.vmap(fn), (rngs,))

    def __repr__(self) -> str:
        return f"<Stack:{self.name}>"


# ---------------------------------------------------------------------------
# Implementations
# ---------------------------------------------------------------------------


def _default_mesh(axis: str) -> Mesh:
    return Mesh(np.array(jax.devices()), (axis,))


class OpenMPStack(Stack):
    """Single-process jit: XLA intra-op threads are the OpenMP threads."""

    name = "openmp"

    def _execute(self, fn, args):
        out = jax.jit(fn)(*args)
        jax.block_until_ready(out)
        return out, 0.0


class MPIStack(Stack):
    """Explicit SPMD over a device mesh with collectives spelled out.

    Single runs are replicated across ranks and combined with an
    all-reduce mean (identical per-rank inputs keep results bit-stable
    across any rank count); batched runs shard the rng batch over ranks.
    """

    name = "mpi"

    def __init__(self, mesh: Optional[Mesh] = None, axis: str = "rank"):
        self.axis = axis
        self._mesh = mesh          # built lazily: importing repro.api must
                                   # not initialize the JAX backend

    @property
    def mesh(self) -> Mesh:
        if self._mesh is None:
            self._mesh = _default_mesh(self.axis)
        return self._mesh

    def _pmean_floats(self, out):
        return jax.tree_util.tree_map(
            lambda x: (jax.lax.pmean(x, self.axis)
                       if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
                       else x), out)

    def _execute(self, fn, args):
        if _shard_map is None:  # pragma: no cover - jax without shard_map
            out = jax.jit(fn)(*args)
            jax.block_until_ready(out)
            return out, 0.0
        spmd = _shard_map(lambda *a: self._pmean_floats(fn(*a)),
                          mesh=self.mesh, in_specs=P(), out_specs=P(),
                          check_rep=False)
        out = jax.jit(spmd)(*args)
        jax.block_until_ready(out)
        return out, 0.0

    def _execute_batch(self, fn, rngs):
        n = self.mesh.devices.size
        batch = int(rngs.shape[0])
        if _shard_map is None or batch % n != 0:  # pragma: no cover
            return self._execute(jax.vmap(fn), (rngs,))
        spmd = _shard_map(jax.vmap(fn), mesh=self.mesh,
                          in_specs=P(self.axis), out_specs=P(self.axis),
                          check_rep=False)
        out = jax.jit(spmd)(rngs)
        jax.block_until_ready(out)
        return out, 0.0


class SparkStack(Stack):
    """Global-view jit with input sharding constraints; intermediates stay
    device-resident (the "in-memory RDD" model)."""

    name = "spark"

    def __init__(self, mesh: Optional[Mesh] = None, axis: str = "worker"):
        self.axis = axis
        self._mesh = mesh          # lazy for the same reason as MPIStack

    @property
    def mesh(self) -> Mesh:
        if self._mesh is None:
            self._mesh = _default_mesh(self.axis)
        return self._mesh

    def _spec_for(self, a: Any) -> P:
        shape = getattr(a, "shape", ())
        n = self.mesh.devices.size
        if len(shape) >= 1 and shape[0] > 0 and shape[0] % n == 0:
            return P(self.axis)
        return P()

    def _execute(self, fn, args):
        with self.mesh:
            placed = tuple(
                jax.device_put(a, NamedSharding(self.mesh, self._spec_for(a)))
                if hasattr(a, "shape") else a
                for a in args)
            out = jax.jit(fn)(*placed)
            jax.block_until_ready(out)
        return out, 0.0


class HadoopStack(Stack):
    """Staged map -> host-materialized intermediate ("HDFS spill") ->
    reduce.  DAG executables run edge-by-edge with every intermediate node
    round-tripped through host memory; ``io_bytes`` counts both directions
    (the paper's disk-I/O bandwidth analog)."""

    name = "hadoop"

    def __init__(self, n_chunks: int = 8):
        self.n_chunks = n_chunks

    def _execute(self, fn, args):
        # opaque fn: run, then spill the result through host memory
        out = jax.jit(fn)(*args)
        jax.block_until_ready(out)
        hosts = jax.tree_util.tree_map(lambda x: np.asarray(x), out)
        io_bytes = _tree_bytes(hosts) * 2.0          # write + read back
        result = jax.tree_util.tree_map(jnp.asarray, hosts)
        return result, io_bytes

    def _execute_dag(self, dag, fn, fargs):
        return self._run_stages(dag, fargs[0], vmap=False)

    def run_batch(self, executable, rngs):
        dag = _extract_dag(executable)
        if dag is None:
            # raw fn: base path (vmap + single spill via _execute)
            return super().run_batch(executable, rngs)
        t0 = time.perf_counter()
        result, io_bytes = self._run_stages(dag, rngs, vmap=True)
        wall = time.perf_counter() - t0
        return RunReport(stack=self.name, wall_s=wall, io_bytes=io_bytes,
                         result=result, batch=int(rngs.shape[0]),
                         result_bytes=_tree_bytes(result))

    def _run_stages(self, dag: ProxyDAG, rng: jax.Array, vmap: bool
                    ) -> Tuple[Any, float]:
        init, stages, finalize = dag.build_stages()
        jinit = jax.jit(jax.vmap(init) if vmap else init)
        sources = jinit(rng)
        io_bytes = 0.0
        nodes: Dict[str, np.ndarray] = {}
        for k, v in sources.items():                 # "HDFS read" of inputs
            host = np.asarray(v)
            io_bytes += host.nbytes
            nodes[k] = host
        for srcs, dst, stage in stages:              # map tasks
            xs = [jnp.asarray(nodes[s]) for s in srcs]
            prev = jnp.asarray(nodes[dst]) if dst in nodes else None
            sfn = jax.vmap(stage, in_axes=(0, 0, None if prev is None else 0)
                           ) if vmap else stage
            out = jax.jit(sfn)(rng, xs, prev)
            host = np.asarray(out)                   # spill to "disk"
            io_bytes += host.nbytes * 2.0            # write + read back
            nodes[dst] = host
        jfin = jax.jit(jax.vmap(finalize) if vmap else finalize)
        result = jfin({k: jnp.asarray(v) for k, v in nodes.items()})
        jax.block_until_ready(result)
        return result, io_bytes

    # -- seed-compatible chunked map/reduce ---------------------------------

    def map_reduce(self, map_fn: Callable, reduce_fn: Callable,
                   data: jax.Array, n_chunks: Optional[int] = None
                   ) -> RunReport:
        """Chunked map -> host-spilled shuffle -> reduce (the seed's
        ``hadoop()`` execution shape, now reporting uniformly)."""
        n_chunks = n_chunks or self.n_chunks
        t0 = time.perf_counter()
        n = data.shape[0] // n_chunks * n_chunks
        chunks = np.asarray(data[:n]).reshape(n_chunks, -1, *data.shape[1:])
        jmap = jax.jit(map_fn)
        io_bytes = 0.0
        intermediates: List[np.ndarray] = []
        for c in chunks:                              # map tasks
            out = jmap(jnp.asarray(c))
            host = np.asarray(out)                    # spill to "disk"
            io_bytes += host.nbytes * 2.0             # write + read back
            intermediates.append(host)
        shuffled = jnp.asarray(
            np.concatenate([i.reshape(-1) for i in intermediates]))
        result = jax.jit(reduce_fn)(shuffled)         # reduce task
        jax.block_until_ready(result)
        wall = time.perf_counter() - t0
        return RunReport(stack=self.name, wall_s=wall, io_bytes=io_bytes,
                         result=result, batch=1,
                         result_bytes=_tree_bytes(result))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


_STACKS: Dict[str, Stack] = {}


def register_stack(stack: Stack) -> Stack:
    """Register a Stack instance under its ``name``."""
    _STACKS[stack.name] = stack
    return stack


def get_stack(name: str) -> Stack:
    if name not in _STACKS:
        raise KeyError(f"unknown stack {name!r}; known: {sorted(_STACKS)}")
    return _STACKS[name]


def list_stacks() -> List[str]:
    return sorted(_STACKS)


register_stack(OpenMPStack())
register_stack(MPIStack())
register_stack(SparkStack())
register_stack(HadoopStack())
