"""Software-stack execution protocol (paper §2.2.2, unified API).

The paper implements every dwarf component on OpenMP / MPI / Hadoop / Spark
because "software stack has great influences on workload behaviors".  The
seed exposed four ad-hoc functions with different signatures; this module
redesigns that axis around one contract:

    stack = get_stack("hadoop")
    report = stack.run(executable, *args)     # -> RunReport

where ``executable`` may be a raw jit-able function, a ``ProxyDAG``, a
``ProxyBenchmark``, a ``ProxySpec``, or a ``Workload`` — the stack coerces
it and reports result, wall time and host<->device traffic uniformly.
``run_batch`` vmaps rng-driven executables over a batch of keys for
high-throughput proxy serving.

JAX-native execution models:

  * ``openmp``  — single-process jit; XLA intra-op threading = OpenMP threads.
  * ``mpi``     — explicit SPMD via shard_map over a device mesh with the
                  collectives spelled out (the MPI execution model).
  * ``spark``   — global-view jit with input sharding constraints;
                  intermediates stay device-resident ("in-memory RDD").
  * ``hadoop``  — staged execution: every intermediate DAG node is
                  materialized through *host* memory ("HDFS spill"), which is
                  the disk-I/O behaviour the paper measures for Hadoop jobs.
"""

from __future__ import annotations

import abc
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax>=0.4.30 experimental location; stubbed out if unavailable
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # pragma: no cover - depends on container jax build
    _shard_map = None

from ..core import schedule as plans
from ..core.cachetools import hit_rate
from ..core.dag import ProxyDAG
from ..core.pool import get_pool
from ..kernels.dispatch import backend_override, megakernel_enabled


# ---------------------------------------------------------------------------
# Compiled-executable cache (compile-once/run-many)
# ---------------------------------------------------------------------------
#
# DAG executables lower through ``repro.core.schedule.lower`` into an
# ExecutionPlan and compile from the plan's *parametric* form: weights and
# shape-free extras enter as jitted arguments, so one executable serves
# every dynamic-param setting of a structure.  Each stack keeps its own
# cache (its execution model is part of the compiled program) keyed on
# ``ExecutionPlan.structure_key()`` — the DAG structure *plus* the fusion
# partition, so a ``REPRO_FUSION_THRESHOLD`` change never hits an
# executable compiled for another grouping; population executables add the
# bucket size (``(plan.structure_key(), bucket_size)``).  These
# module-level counters expose hit/miss/trace activity for the no-retrace
# tests and the engine benchmarks.

CACHE_STATS = {"hits": 0, "misses": 0, "traces": 0, "evictions": 0}

#: executables retained per stack (FIFO eviction; a long-lived tuning or
#: serving process sweeping *structural* params must not accumulate
#: compiled programs without bound).  A structural search proposes many
#: distinct structures, so the cap is tunable (``REPRO_EXEC_CACHE_CAP``)
#: and the ``evictions`` counter exposes thrash: evictions growing while
#: the same structures keep re-running means the cap is too tight and
#: every revisit re-compiles.
CACHE_CAP = 256


def cache_cap() -> int:
    """Resolve the per-stack executable-cache cap
    (``REPRO_EXEC_CACHE_CAP`` env var; default :data:`CACHE_CAP`)."""
    import os
    raw = os.environ.get("REPRO_EXEC_CACHE_CAP")
    if raw is None or raw.strip() == "":
        return CACHE_CAP
    return max(1, int(raw))


def cache_stats() -> Dict[str, float]:
    """Aggregate executable-cache counters across every stack instance
    (mirrored from the per-instance pool domains), plus the warm-serving
    ``hit_rate`` the serving bench reports; per-domain breakdowns live in
    ``repro.core.pool.get_pool().stats()``."""
    stats: Dict[str, float] = dict(CACHE_STATS)
    stats["hit_rate"] = hit_rate(stats)
    return stats


def reset_cache_stats() -> None:
    """Zero the process-wide executable-cache counters."""
    for k in CACHE_STATS:
        CACHE_STATS[k] = 0


def _donate_argnums() -> Tuple[int, ...]:
    # donate the dynamic-param buffers (rebuilt fresh per call); CPU has no
    # donation support, so skip it there to avoid per-compile warnings
    return () if jax.default_backend() == "cpu" else (1,)


# ---------------------------------------------------------------------------
# Failure classification (the serving engine's retry policy input)
# ---------------------------------------------------------------------------

#: classes ``classify_failure`` can return; everything but "fatal" is
#: retryable (a re-dispatch can plausibly succeed)
FAILURE_CLASSES = ("injected", "resource", "fatal", "transient")


def classify_failure(exc: BaseException) -> str:
    """Classify an executable-dispatch exception for the retry policy.

    * ``"injected"`` — a :class:`repro.faults.InjectedFailure` (chaos
      testing); retryable by construction.
    * ``"resource"``  — allocation / OOM-shaped runtime errors; retryable
      after degradation (smaller chunks, evicted executables).
    * ``"fatal"``     — caller bugs (bad types/shapes/keys); retrying the
      identical dispatch cannot succeed, fail the request terminally.
    * ``"transient"`` — everything else (backend hiccups); retryable.
    """
    from ..faults import InjectedFailure
    if isinstance(exc, InjectedFailure):
        return "injected"
    msg = str(exc).upper()
    if ("RESOURCE_EXHAUSTED" in msg or "OUT OF MEMORY" in msg
            or "OOM" in msg or isinstance(exc, MemoryError)):
        return "resource"
    if isinstance(exc, (TypeError, ValueError, KeyError, IndexError,
                        AttributeError)):
        return "fatal"
    return "transient"


def failure_is_retryable(exc: BaseException) -> bool:
    """True when ``classify_failure`` deems the exception transient —
    the serving engine's retry/bisection policies key off this."""
    return classify_failure(exc) != "fatal"


# ---------------------------------------------------------------------------
# RunReport
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunReport:
    """Uniform result of ``Stack.run`` across every software stack."""

    stack: str                   # registry name of the executing stack
    wall_s: float                # end-to-end wall time (incl. compile)
    io_bytes: float              # host<->device traffic ("disk I/O" analog)
    result: Any = None           # the executable's output pytree
    batch: int = 1               # number of rng instances executed
    result_bytes: float = 0.0    # size of the output pytree
    #: the executed ProxyDAG when the run came from a DAG-bearing
    #: executable (None for raw callables) — lets
    #: ``repro.api.fingerprint(report)`` recover the measured channel
    #: vector without re-running anything
    dag: Any = dataclasses.field(default=None, repr=False)

    @property
    def throughput(self) -> float:
        """Executions per second (batched proxy serving metric)."""
        return self.batch / max(self.wall_s, 1e-12)

    @property
    def io_bandwidth(self) -> float:
        """Host-traffic bandwidth in bytes/s (paper Fig. 7 analog)."""
        return self.io_bytes / max(self.wall_s, 1e-12)

    def to_json(self) -> Dict[str, float]:
        return {"stack": self.stack, "wall_s": self.wall_s,
                "io_bytes": self.io_bytes, "batch": self.batch,
                "result_bytes": self.result_bytes,
                "throughput": self.throughput}


def _tree_bytes(out: Any) -> float:
    # jax/np arrays expose .nbytes without a device-to-host transfer;
    # only Python scalars need materializing
    total = 0.0
    for x in jax.tree_util.tree_leaves(out):
        nbytes = getattr(x, "nbytes", None)
        total += float(np.asarray(x).nbytes if nbytes is None else nbytes)
    return total


# ---------------------------------------------------------------------------
# Executable coercion
# ---------------------------------------------------------------------------


def _extract_dag(executable: Any) -> Optional[ProxyDAG]:
    if isinstance(executable, ProxyDAG):
        return executable
    dag = getattr(executable, "dag", None)          # ProxyBenchmark
    if isinstance(dag, ProxyDAG):
        return dag
    if hasattr(executable, "to_dag"):               # ProxySpec
        return executable.to_dag()
    return None


def _as_fn(executable: Any, args: Tuple) -> Tuple[Callable, Tuple]:
    """Coerce (executable, args) -> (jit-able fn, concrete args)."""
    if callable(executable) and not hasattr(executable, "make_inputs"):
        return executable, args
    if hasattr(executable, "make_inputs"):          # core.workloads.Workload
        from ..core.workloads import workload_step_fn
        scale = args[0] if args else "tiny"
        return workload_step_fn(executable.name, scale)
    raise TypeError(f"cannot execute object of type "
                    f"{type(executable).__name__} on a Stack; expected a "
                    f"callable, ProxyDAG, ProxyBenchmark, ProxySpec, or "
                    f"Workload")


def _default_rng(rng: Optional[jax.Array]) -> jax.Array:
    return jax.random.PRNGKey(0) if rng is None else rng


def _take_candidates(dynb: Tuple, indices) -> Tuple:
    """Gather one bucket's slice of a stacked dyn pytree (leading
    candidate axis) — shapes depend only on the bucket size, so every
    same-size bucket reuses one compiled executable."""
    sel = jnp.asarray(np.asarray(indices), jnp.int32)
    return jax.tree_util.tree_map(lambda v: v[sel], dynb)


# ---------------------------------------------------------------------------
# Stack protocol
# ---------------------------------------------------------------------------


class Stack(abc.ABC):
    """One software-stack execution model.

    Subclasses implement ``_execute(fn, args) -> (result, io_bytes)`` for
    raw-fn/workload executables; coercion, timing, batching and reporting
    are shared.  DAG executables take the compile-once fast path instead:
    they lower to an ``ExecutionPlan`` (``repro.core.schedule.lower`` —
    fused stages under the live ``REPRO_FUSION_THRESHOLD``) and
    ``run``/``run_batch`` fetch a cached parametric executable via
    ``_compiled_plan``, so a stack that needs its execution model applied
    to DAG runs overrides ``_wrap_parametric`` (bake the model into the
    compiled fn — see ``MPIStack``) and/or ``_dag_run``/``_dag_run_batch``
    (placement and io accounting — see ``SparkStack``/``HadoopStack``).
    ``run_population`` executes a plan's weight-stratified
    ``BucketSchedule``: one vmapped call per bucket, every bucket sharing
    the one ``(plan, bucket_size)`` executable."""

    name: str = "abstract"

    @abc.abstractmethod
    def _execute(self, fn: Callable, args: Tuple) -> Tuple[Any, float]:
        """Run ``fn(*args)`` under this execution model.
        Returns ``(result, io_bytes)``."""

    # -- compiled plan executables ------------------------------------------

    def exec_domain(self):
        """This instance's compiled-executable domain in the process-wide
        :class:`~repro.core.pool.ExecutablePool`.  Registered lazily and
        per *instance* (a fresh stack starts cold — the compile-accounting
        tests and benchmarks rely on that), auto-unregistered when the
        instance dies; lookups mirror into the module-level
        :data:`CACHE_STATS` so the aggregate counters keep working."""
        dom = self.__dict__.get("_pool_domain")
        if dom is None:
            dom = get_pool().register_instance(
                self, f"stack:{self.name}", kind="executable",
                mirror=CACHE_STATS)
            self.__dict__["_pool_domain"] = dom
            self.__dict__["_dag_cache"] = dom.cache
        dom.cap = cache_cap()    # live env resolution, as cached_get did
        return dom

    def _exec_key(self, *parts) -> Tuple:
        """Executable cache key: the caller's parts plus the live
        degradation backend override (:func:`repro.kernels.dispatch.
        backend_override`) — ``None`` in normal operation, so warm keys
        are unchanged; a degraded dispatch with XLA forced must compile
        (and cache) its own executable rather than be handed one traced
        with the failing backend — and the live megakernel arming flag
        (:func:`repro.kernels.dispatch.megakernel_enabled`), since a
        MegaStage traces a different program per flag setting."""
        return (*parts, backend_override(), megakernel_enabled())

    @staticmethod
    def _plan_cost(plan) -> float:
        """Recompile cost of one plan's executable under the lowering cost
        model — what the pool's ``"cost"`` eviction policy minimizes
        keeping (:func:`repro.core.pool.pool_policy`)."""
        return float(sum(s.cost for s in plan.stages))

    def _compiled_plan(self, plan, batch: bool) -> Callable:
        """Cached jitted ``fn(rng, dyn)`` for this stack's execution model.
        One compile per (stack, plan structure key, batch-ness); every
        dynamic-param setting of the structure reuses it."""
        return get_pool().get(
            self.exec_domain(), self._exec_key(batch, plan.structure_key()),
            lambda: self._wrap_parametric(plan.build_parametric(), batch),
            cost=self._plan_cost(plan))

    def _wrap_parametric(self, pfn: Callable, batch: bool) -> Callable:
        """Bake this stack's execution model into a jitted parametric fn."""
        if batch:
            def f(rngs, dyn):
                CACHE_STATS["traces"] += 1
                return jax.vmap(lambda r: pfn(r, dyn))(rngs)
        else:
            def f(rng, dyn):
                CACHE_STATS["traces"] += 1
                return pfn(rng, dyn)
        return jax.jit(f, donate_argnums=_donate_argnums())

    def _dag_run(self, dag: ProxyDAG, rng: jax.Array) -> Tuple[Any, float]:
        plan = plans.lower(dag)
        out = self._compiled_plan(plan, batch=False)(rng,
                                                     dag.dynamic_params())
        jax.block_until_ready(out)
        return out, 0.0

    def _dag_run_batch(self, dag: ProxyDAG, rngs: jax.Array
                       ) -> Tuple[Any, float]:
        plan = plans.lower(dag)
        out = self._compiled_plan(plan, batch=True)(rngs,
                                                    dag.dynamic_params())
        jax.block_until_ready(out)
        return out, 0.0

    # -- population evaluation (one compiled call per weight bucket) ---------

    def _compiled_plan_population(self, plan, n: int) -> Callable:
        """Cached jitted ``fn(rng, dyn_batched)`` evaluating ``n``
        dynamic-param candidates of one plan in a single vmapped call.
        Keyed on ``(plan structure key, bucket size)``: every same-size
        bucket of every sweep reuses it — at most one executable per
        bucket signature, zero retraces per candidate."""
        return get_pool().get(
            self.exec_domain(),
            self._exec_key(("population", n), plan.structure_key()),
            lambda: self._wrap_population(plan, n),
            cost=n * self._plan_cost(plan))

    # -- serving micro-batches (one compiled call per request chunk) ---------

    def _compiled_plan_serve(self, plan, n: int) -> Callable:
        """Cached jitted ``fn(rngs, dynb)`` executing ``n`` heterogeneous
        *requests* of one structure in a single vmapped call.  Unlike the
        population form (one shared rng, candidate-batched dyn), every
        request carries its own rng — the serving micro-batch axis.  Keyed
        on ``(("serve", n), plan.structure_key())``: every same-size
        micro-batch of every stream reuses one executable, so steady-state
        serving compiles at most once per (structure, chunk size)."""
        return get_pool().get(
            self.exec_domain(),
            self._exec_key(("serve", n), plan.structure_key()),
            lambda: self._wrap_serve(plan, n),
            cost=n * self._plan_cost(plan))

    def _wrap_serve(self, plan, n: int) -> Callable:
        """Bake this stack's execution model into the request-batched
        serving form: vmap over *paired* (rng, dyn) request axes.  No
        buffer donation — the serving engine may replay a trace."""
        pfn = plan.build_parametric()

        def f(rngs, dynb):
            CACHE_STATS["traces"] += 1
            return jax.vmap(pfn)(rngs, dynb)

        return jax.jit(f)

    def _serve_call(self, fn: Callable, rngs: jax.Array,
                    dynb: Tuple) -> Any:
        """One serving micro-batch call (placement hook — see SparkStack).
        Not synced: the serving loop's latency accounting blocks."""
        return fn(rngs, dynb)

    def _wrap_population(self, plan, n: int) -> Callable:
        """Bake this stack's execution model into the canonical vmapped
        population form (``ExecutionPlan.build_population``).  No buffer
        donation: callers may reuse a stacked dyn pytree across calls."""
        pop = plan.build_population()

        def f(rng, dynb):
            CACHE_STATS["traces"] += 1
            return pop(rng, dynb)

        return jax.jit(f)

    def _population_call(self, fn: Callable, rng: jax.Array,
                         dynb: Tuple) -> Tuple[Any, float]:
        """One bucket's executable call (placement hook — see SparkStack).
        Deliberately *not* synced: the bucket loop dispatches every
        stratum and lets the assembly's host transfer force completion,
        overlapping per-bucket Python overhead with device compute."""
        return fn(rng, dynb), 0.0

    def _dag_run_population(self, dag: ProxyDAG, rng: jax.Array,
                            dynb: Tuple, n: int,
                            bucket_size: Optional[int] = None
                            ) -> Tuple[Any, float]:
        """Bucketed population execution: candidates stratified by total
        weighted cost run one vmapped call per bucket, so each bucket's
        batched ``while`` trips only to its own maximum instead of the
        population-wide straggler — recovering the sequential-sum cost
        model while keeping per-lane results bit-identical (vmap lanes
        are batch-composition independent).  Population plans lower
        unfused (``plans.lower_population``): per-edge loops give the
        schedule its per-edge trip bounds, and a fused switch under a
        batched candidate axis would execute every branch per trip."""
        plan = plans.lower_population(dag)
        sched = plan.bucket_schedule(dynb, bucket_size)
        if sched.bucket_size == 1:
            # fully stratified schedule (the single-device default): every
            # candidate runs exactly its own trips through an *unbatched*
            # parametric executable (no batched-while masking overhead),
            # strata dispatched over a small host thread pool — the CPU
            # analogue of sharding the candidate axis over a mesh
            fn = self._compiled_plan(plan, batch=False)
            host_dynb = jax.tree_util.tree_map(np.asarray, dynb)

            def one(i: int):
                dyn_i = jax.tree_util.tree_map(
                    lambda v: jnp.asarray(v[i]), host_dynb)
                return self._population_call(fn, rng, dyn_i)

            order = [int(b.indices[0]) for b in sched.buckets]
            workers = plans.population_workers()
            if (workers > 1 and len(order) > 1 and
                    type(self)._population_call is Stack._population_call):
                from concurrent.futures import ThreadPoolExecutor
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    results = list(zip(order, pool.map(one, order)))
            else:
                results = [(i, one(i)) for i in order]
            out_np = None
            io_bytes = 0.0
            for i, (res, io_b) in results:     # host transfer = the sync
                io_bytes += io_b
                host = np.asarray(res)
                if out_np is None:
                    out_np = np.empty((sched.n,) + host.shape, host.dtype)
                out_np[i] = host
            return jnp.asarray(out_np), io_bytes
        fn = self._compiled_plan_population(plan, sched.bucket_size)
        results, io_bytes = [], 0.0
        for b in sched.buckets:
            res, io_b = self._population_call(
                fn, rng, _take_candidates(dynb, b.indices))
            io_bytes += io_b
            results.append((b, res))
        out_np = None
        for b, res in results:                 # host transfer = the sync
            host = np.asarray(res)
            if out_np is None:
                out_np = np.empty((sched.n,) + host.shape[1:], host.dtype)
            out_np[b.indices[:b.valid]] = host[:b.valid]
        return jnp.asarray(out_np), io_bytes

    def _coerce_population(self, dag: ProxyDAG, candidates: Any,
                           space: Any) -> Tuple[Tuple, int]:
        """Coerce a ``(n, len(space))`` candidate matrix (or an already
        stacked dyn pytree) into the batched dyn pytree + its size."""
        if getattr(candidates, "ndim", None) == 2:
            if space is None:
                from .params import ParamSpace
                space = ParamSpace.from_dag(dag)
            dynb = space.stack_candidates(dag, candidates)
        else:
            dynb = candidates
        sizes = {int(v.shape[0]) if len(v.shape) else None
                 for d in dynb for v in d.values()}
        if len(sizes) != 1 or None in sizes:
            raise ValueError(
                f"cannot infer the population size from candidate-axis "
                f"sizes {sorted(sizes, key=str)}: pass a (n, len(space)) "
                f"matrix or a pytree stacked by ParamSpace.stack_candidates "
                f"(an unbatched dynamic_params() pytree, or a DAG without "
                f"dynamic params, has no population axis)")
        return dynb, sizes.pop()

    # -- public API ----------------------------------------------------------

    def run(self, executable: Any, *args,
            rng: Optional[jax.Array] = None) -> RunReport:
        """Execute anything on this stack and report uniformly."""
        dag = _extract_dag(executable)
        t0 = time.perf_counter()
        if dag is not None:
            if args:
                raise TypeError(
                    f"{type(executable).__name__} executables take no "
                    f"positional args; pass the PRNG key as rng=...")
            result, io_bytes = self._dag_run(dag, _default_rng(rng))
        else:
            fn, fargs = _as_fn(executable, args)
            if rng is not None:
                if hasattr(executable, "make_inputs"):
                    raise TypeError("Workload executables generate their own "
                                    "inputs; rng= only applies to DAG or "
                                    "rng-driven fn executables")
                fargs = (*fargs, rng)    # fn(*args, rng) convention
            result, io_bytes = self._execute(fn, fargs)
        wall = time.perf_counter() - t0
        return RunReport(stack=self.name, wall_s=wall, io_bytes=io_bytes,
                         result=result, batch=1,
                         result_bytes=_tree_bytes(result), dag=dag)

    def run_batch(self, executable: Any,
                  rngs: jax.Array) -> RunReport:
        """Vectorized execution of an rng-driven executable over a batch of
        PRNG keys (high-throughput proxy serving)."""
        dag = _extract_dag(executable)
        if dag is None and not callable(executable):
            raise TypeError("run_batch needs an rng-driven executable "
                            "(ProxyDAG/ProxyBenchmark/ProxySpec or fn(rng))")
        batch = int(rngs.shape[0])
        t0 = time.perf_counter()
        if dag is not None:
            result, io_bytes = self._dag_run_batch(dag, rngs)
        else:
            result, io_bytes = self._execute_batch(executable, rngs)
        wall = time.perf_counter() - t0
        return RunReport(stack=self.name, wall_s=wall, io_bytes=io_bytes,
                         result=result, batch=batch,
                         result_bytes=_tree_bytes(result), dag=dag)

    def run_population(self, executable: Any, candidates: Any, *,
                       rng: Optional[jax.Array] = None,
                       space: Any = None,
                       bucket_size: Optional[int] = None) -> RunReport:
        """Evaluate a *population* of dynamic-param candidates of one DAG
        structure through its weight-stratified bucket schedule (the
        batched-autotuning axis).

        ``candidates`` is either a ``(n, len(space))`` matrix from
        ``ParamSpace.sample``/``sample_dynamic`` (``space`` optional — built
        from the DAG when omitted) or an already-stacked dyn pytree from
        ``ParamSpace.stack_candidates``.  All candidates share the rng;
        the plan's ``BucketSchedule`` strata (``bucket_size`` — default
        ``ceil(n / REPRO_POP_BUCKETS)``) each execute as one vmapped call
        of a single shared executable — one compile per (plan, bucket
        size), zero retraces per candidate — and the candidate axis shards
        over the stack's device mesh where the execution model has one.
        ``result`` holds the per-candidate output stacked on axis 0 in the
        caller's candidate order.
        """
        dag = _extract_dag(executable)
        if dag is None:
            raise TypeError(
                f"run_population needs a DAG executable (ProxyDAG / "
                f"ProxyBenchmark / ProxySpec), got "
                f"{type(executable).__name__}")
        dynb, n = self._coerce_population(dag, candidates, space)
        t0 = time.perf_counter()
        result, io_bytes = self._dag_run_population(
            dag, _default_rng(rng), dynb, n, bucket_size=bucket_size)
        wall = time.perf_counter() - t0
        return RunReport(stack=self.name, wall_s=wall, io_bytes=io_bytes,
                         result=result, batch=n,
                         result_bytes=_tree_bytes(result), dag=dag)

    def _execute_batch(self, fn: Callable, rngs: jax.Array
                       ) -> Tuple[Any, float]:
        return self._execute(jax.vmap(fn), (rngs,))

    def __repr__(self) -> str:
        return f"<Stack:{self.name}>"


# ---------------------------------------------------------------------------
# Implementations
# ---------------------------------------------------------------------------


def _default_mesh(axis: str) -> Mesh:
    return Mesh(np.array(jax.devices()), (axis,))


class OpenMPStack(Stack):
    """Single-process jit: XLA intra-op threads are the OpenMP threads."""

    name = "openmp"

    def _execute(self, fn, args):
        out = jax.jit(fn)(*args)
        jax.block_until_ready(out)
        return out, 0.0


class MPIStack(Stack):
    """Explicit SPMD over a device mesh with collectives spelled out.

    Single runs are replicated across ranks and combined with an
    all-reduce mean (identical per-rank inputs keep results bit-stable
    across any rank count); batched runs shard the rng batch over ranks.
    """

    name = "mpi"

    def __init__(self, mesh: Optional[Mesh] = None, axis: str = "rank"):
        self.axis = axis
        self._mesh = mesh          # built lazily: importing repro.api must
                                   # not initialize the JAX backend

    @property
    def mesh(self) -> Mesh:
        if self._mesh is None:
            self._mesh = _default_mesh(self.axis)
        return self._mesh

    def _pmean_floats(self, out):
        return jax.tree_util.tree_map(
            lambda x: (jax.lax.pmean(x, self.axis)
                       if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
                       else x), out)

    def _execute(self, fn, args):
        if _shard_map is None:  # pragma: no cover - jax without shard_map
            out = jax.jit(fn)(*args)
            jax.block_until_ready(out)
            return out, 0.0
        spmd = _shard_map(lambda *a: self._pmean_floats(fn(*a)),
                          mesh=self.mesh, in_specs=P(), out_specs=P(),
                          check_rep=False)
        out = jax.jit(spmd)(*args)
        jax.block_until_ready(out)
        return out, 0.0

    def _execute_batch(self, fn, rngs):
        n = self.mesh.devices.size
        batch = int(rngs.shape[0])
        if _shard_map is None or batch % n != 0:  # pragma: no cover
            return self._execute(jax.vmap(fn), (rngs,))
        spmd = _shard_map(jax.vmap(fn), mesh=self.mesh,
                          in_specs=P(self.axis), out_specs=P(self.axis),
                          check_rep=False)
        out = jax.jit(spmd)(rngs)
        jax.block_until_ready(out)
        return out, 0.0

    def _wrap_parametric(self, pfn, batch):
        if _shard_map is None:  # pragma: no cover - jax without shard_map
            return super()._wrap_parametric(pfn, batch)
        n = self.mesh.devices.size
        if batch:
            def f(rngs, dyn):
                CACHE_STATS["traces"] += 1
                vm = lambda rs, d: jax.vmap(lambda r: pfn(r, d))(rs)
                if rngs.shape[0] % n != 0:  # pragma: no cover
                    return vm(rngs, dyn)
                return _shard_map(vm, mesh=self.mesh,
                                  in_specs=(P(self.axis), P()),
                                  out_specs=P(self.axis),
                                  check_rep=False)(rngs, dyn)
        else:
            def f(rng, dyn):
                CACHE_STATS["traces"] += 1
                spmd = _shard_map(
                    lambda r, d: self._pmean_floats(pfn(r, d)),
                    mesh=self.mesh, in_specs=(P(), P()), out_specs=P(),
                    check_rep=False)
                return spmd(rng, dyn)
        return jax.jit(f, donate_argnums=_donate_argnums())

    def _wrap_population(self, plan, n):
        """Shard each bucket's candidate axis over the ranks: every rank
        vmaps its own slice of the bucket (SPMD tuner sweep — ROADMAP's
        multi-device dynamic-param batch, now at bucket granularity)."""
        from ..distributed.sharding import candidate_spec_axis
        if _shard_map is None or candidate_spec_axis(
                self.mesh, n, prefer=(self.axis,)) is None:
            return super()._wrap_population(plan, n)  # pragma: no cover
        pop = plan.build_population()

        def f(rng, dynb):
            CACHE_STATS["traces"] += 1
            return _shard_map(pop, mesh=self.mesh,
                              in_specs=(P(), P(self.axis)),
                              out_specs=P(self.axis),
                              check_rep=False)(rng, dynb)

        return jax.jit(f)

    def _wrap_serve(self, plan, n):
        """Shard the serving micro-batch over the ranks: request rngs and
        dyn params shard together on the request axis, each rank vmapping
        its own slice of the chunk."""
        from ..distributed.sharding import candidate_spec_axis
        if _shard_map is None or candidate_spec_axis(
                self.mesh, n, prefer=(self.axis,)) is None:
            return super()._wrap_serve(plan, n)  # pragma: no cover
        pfn = plan.build_parametric()

        def f(rngs, dynb):
            CACHE_STATS["traces"] += 1
            return _shard_map(jax.vmap(pfn), mesh=self.mesh,
                              in_specs=(P(self.axis), P(self.axis)),
                              out_specs=P(self.axis),
                              check_rep=False)(rngs, dynb)

        return jax.jit(f)


class SparkStack(Stack):
    """Global-view jit with input sharding constraints; intermediates stay
    device-resident (the "in-memory RDD" model)."""

    name = "spark"

    def __init__(self, mesh: Optional[Mesh] = None, axis: str = "worker"):
        self.axis = axis
        self._mesh = mesh          # lazy for the same reason as MPIStack

    @property
    def mesh(self) -> Mesh:
        if self._mesh is None:
            self._mesh = _default_mesh(self.axis)
        return self._mesh

    def _spec_for(self, a: Any) -> P:
        shape = getattr(a, "shape", ())
        n = self.mesh.devices.size
        if len(shape) >= 1 and shape[0] > 0 and shape[0] % n == 0:
            return P(self.axis)
        return P()

    def _execute(self, fn, args):
        with self.mesh:
            placed = tuple(
                jax.device_put(a, NamedSharding(self.mesh, self._spec_for(a)))
                if hasattr(a, "shape") else a
                for a in args)
            out = jax.jit(fn)(*placed)
            jax.block_until_ready(out)
        return out, 0.0

    def _dag_run(self, dag, rng):
        fn = self._compiled_plan(plans.lower(dag), batch=False)
        with self.mesh:
            rng = jax.device_put(rng, NamedSharding(self.mesh, P()))
            out = fn(rng, dag.dynamic_params())
            jax.block_until_ready(out)
        return out, 0.0

    def _dag_run_batch(self, dag, rngs):
        fn = self._compiled_plan(plans.lower(dag), batch=True)
        with self.mesh:
            # shard the rng batch over the workers (the "RDD partitions")
            rngs = jax.device_put(
                rngs, NamedSharding(self.mesh, self._spec_for(rngs)))
            out = fn(rngs, dag.dynamic_params())
            jax.block_until_ready(out)
        return out, 0.0

    def _population_call(self, fn, rng, dynb):
        from ..distributed.sharding import bucket_shardings
        with self.mesh:
            # place each bucket over the workers: every worker evaluates
            # its partition of the bucket's candidate slice (no sync —
            # the assembly's host transfer forces completion)
            dynb = jax.device_put(
                dynb, bucket_shardings(self.mesh, dynb,
                                       prefer=(self.axis,)))
            rng = jax.device_put(rng, NamedSharding(self.mesh, P()))
            out = fn(rng, dynb)
        return out, 0.0

    def _serve_call(self, fn, rngs, dynb):
        from ..distributed.sharding import serve_shardings
        with self.mesh:
            # place the micro-batch over the workers: the paired request
            # axes of rngs and dyn params partition together
            rng_s, dyn_s = serve_shardings(self.mesh, rngs, dynb,
                                           prefer=(self.axis,))
            out = fn(jax.device_put(rngs, rng_s),
                     jax.device_put(dynb, dyn_s))
        return out


class HadoopStack(Stack):
    """Staged map -> host-materialized intermediate ("HDFS spill") ->
    reduce.  DAG executables run edge-by-edge with every intermediate node
    round-tripped through host memory; ``io_bytes`` counts both directions
    (the paper's disk-I/O bandwidth analog)."""

    name = "hadoop"

    def __init__(self, n_chunks: int = 8):
        self.n_chunks = n_chunks

    def _execute(self, fn, args):
        # opaque fn: run, then spill the result through host memory
        out = jax.jit(fn)(*args)
        jax.block_until_ready(out)
        hosts = jax.tree_util.tree_map(lambda x: np.asarray(x), out)
        io_bytes = _tree_bytes(hosts) * 2.0          # write + read back
        result = jax.tree_util.tree_map(jnp.asarray, hosts)
        return result, io_bytes

    def _dag_run(self, dag, rng):
        return self._run_stages(dag, rng, vmap=False)

    def _dag_run_batch(self, dag, rngs):
        return self._run_stages(dag, rngs, vmap=True)

    def _dag_run_population(self, dag, rng, dynb, n, bucket_size=None):
        """Staged population sweep over the plan's bucket schedule: every
        candidate's intermediates spill through host memory per *fused
        stage* (the population multiplies the "HDFS" traffic — at stage,
        not edge, granularity), each bucket executing its stratum in one
        vmapped call per stage so the staged trip bounds follow the
        bucket's own maxima.  Sources are generated once and shared —
        candidates differ only in dynamic params, so source nodes stay
        unbatched until a stage first writes a node."""
        plan = plans.lower(dag)
        sched = plan.bucket_schedule(dynb, bucket_size)
        nb = sched.bucket_size
        init, stages, finalize = plan.stages_parametric()
        pkey = plan.structure_key()
        src_key = tuple(sorted(plan.sources.items()))
        jinit = self._cached_stage(("init", False, src_key), lambda: init)
        io_bytes = 0.0
        shared: Dict[str, np.ndarray] = {}
        for k, v in jinit(rng).items():              # shared "HDFS read"
            host = np.asarray(v)
            io_bytes += host.nbytes
            shared[k] = host
        out_np: Optional[np.ndarray] = None
        for b in sched.buckets:
            sub = _take_candidates(dynb, b.indices)
            stage_dyns = plan.stage_dyn_tuples(sub)
            nodes: Dict[str, np.ndarray] = dict(shared)
            batched: Dict[str, bool] = {}
            for si, (srcs, dst, stage, stage_key) in enumerate(stages):
                xs = [jnp.asarray(nodes[s]) for s in srcs]
                x_axes = [0 if batched.get(s) else None for s in srcs]
                prev = jnp.asarray(nodes[dst]) if dst in nodes else None
                prev_ax = 0 if batched.get(dst) else None
                sfn = self._cached_stage(
                    ("pstage", nb, tuple(x_axes), prev is None, prev_ax,
                     stage_key),
                    lambda s=stage, xa=tuple(x_axes), hp=prev is None,
                    pa=prev_ax: jax.vmap(
                        s, in_axes=(None, list(xa), None if hp else pa, 0)))
                out = sfn(rng, xs, prev, stage_dyns[si])
                host = np.asarray(out)               # per-candidate spill
                io_bytes += host.nbytes * 2.0        # write + read back
                nodes[dst] = host
                batched[dst] = True
            fin_axes = {k: 0 if batched.get(k) else None for k in nodes}
            jfin = self._cached_stage(
                ("pfinalize", nb, tuple(sorted(fin_axes.items())), pkey),
                lambda ax=fin_axes: jax.vmap(finalize, in_axes=(ax,)))
            res = jfin({k: jnp.asarray(v) for k, v in nodes.items()})
            jax.block_until_ready(res)
            host = np.asarray(res)
            if out_np is None:
                out_np = np.empty((sched.n,) + host.shape[1:], host.dtype)
            out_np[b.indices[:b.valid]] = host[:b.valid]
        return jnp.asarray(out_np), io_bytes

    def _cached_stage(self, key: Tuple, make: Callable,
                      cost: float = 0.0) -> Callable:
        # staged executables share this instance's pool domain with the
        # whole-plan executables (keys cannot collide: stage keys lead
        # with a string tag), so the eviction cap bounds both together
        def build() -> Callable:
            def counted(*args, _f=make()):
                CACHE_STATS["traces"] += 1
                return _f(*args)

            return jax.jit(counted)

        return get_pool().get(self.exec_domain(), self._exec_key(key), build,
                              cost=cost)

    def _run_stages(self, dag: ProxyDAG, rng: jax.Array, vmap: bool
                    ) -> Tuple[Any, float]:
        """Stage-by-stage execution with host-spilled intermediates at
        *fused-stage* granularity: a fused chain of low-weight edges
        spills once, not once per edge — the plan lowering cuts the
        "HDFS" round-trip volume.  Each stage's jitted form is cached
        under its structural key, so repeated runs — and dynamic-param
        sweeps — reuse every per-stage compile."""
        plan = plans.lower(dag)
        init, stages, finalize = plan.stages_parametric()
        pkey = plan.structure_key()
        stage_dyns = plan.stage_dyn_tuples(dag.dynamic_params())
        src_key = tuple(sorted(plan.sources.items()))
        jinit = self._cached_stage(
            ("init", vmap, src_key),
            lambda: jax.vmap(init) if vmap else init)
        sources = jinit(rng)
        io_bytes = 0.0
        nodes: Dict[str, np.ndarray] = {}
        for k, v in sources.items():                 # "HDFS read" of inputs
            host = np.asarray(v)
            io_bytes += host.nbytes
            nodes[k] = host
        for si, (srcs, dst, stage, stage_key) in enumerate(stages):  # map tasks
            xs = [jnp.asarray(nodes[s]) for s in srcs]
            prev = jnp.asarray(nodes[dst]) if dst in nodes else None
            sfn = self._cached_stage(
                ("stage", vmap, prev is None, stage_key),
                lambda s=stage, hp=prev is None: (
                    jax.vmap(s, in_axes=(0, 0, None if hp else 0, None))
                    if vmap else s),
                cost=float(plan.stages[si].cost))
            out = sfn(rng, xs, prev, stage_dyns[si])
            host = np.asarray(out)                   # spill to "disk"
            io_bytes += host.nbytes * 2.0            # write + read back
            nodes[dst] = host
        jfin = self._cached_stage(
            ("finalize", vmap, pkey),
            lambda: jax.vmap(finalize) if vmap else finalize)
        result = jfin({k: jnp.asarray(v) for k, v in nodes.items()})
        jax.block_until_ready(result)
        return result, io_bytes

    # -- seed-compatible chunked map/reduce ---------------------------------

    def map_reduce(self, map_fn: Callable, reduce_fn: Callable,
                   data: jax.Array, n_chunks: Optional[int] = None
                   ) -> RunReport:
        """Chunked map -> host-spilled shuffle -> reduce (the seed's
        ``hadoop()`` execution shape, now reporting uniformly)."""
        n_chunks = n_chunks or self.n_chunks
        t0 = time.perf_counter()
        n = data.shape[0] // n_chunks * n_chunks
        chunks = np.asarray(data[:n]).reshape(n_chunks, -1, *data.shape[1:])
        jmap = jax.jit(map_fn)
        io_bytes = 0.0
        intermediates: List[np.ndarray] = []
        for c in chunks:                              # map tasks
            out = jmap(jnp.asarray(c))
            host = np.asarray(out)                    # spill to "disk"
            io_bytes += host.nbytes * 2.0             # write + read back
            intermediates.append(host)
        shuffled = jnp.asarray(
            np.concatenate([i.reshape(-1) for i in intermediates]))
        result = jax.jit(reduce_fn)(shuffled)         # reduce task
        jax.block_until_ready(result)
        wall = time.perf_counter() - t0
        return RunReport(stack=self.name, wall_s=wall, io_bytes=io_bytes,
                         result=result, batch=1,
                         result_bytes=_tree_bytes(result))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


_STACKS: Dict[str, Stack] = {}


def register_stack(stack: Stack) -> Stack:
    """Register a Stack instance under its ``name``."""
    _STACKS[stack.name] = stack
    return stack


def get_stack(name: str) -> Stack:
    """Look up a registered software stack (``KeyError`` on unknown)."""
    if name not in _STACKS:
        raise KeyError(f"unknown stack {name!r}; known: {sorted(_STACKS)}")
    return _STACKS[name]


def list_stacks() -> List[str]:
    """Registered stack names, sorted."""
    return sorted(_STACKS)


register_stack(OpenMPStack())
register_stack(MPIStack())
register_stack(SparkStack())
register_stack(HadoopStack())
