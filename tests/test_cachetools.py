"""Thread-safety + accounting contract of the shared cache plumbing.

The population path dispatches strata over the ``REPRO_POP_WORKERS`` host
thread pool and the serving engine admits requests concurrently, so
``cached_get``/``evict_oldest`` must be atomic: one build per key under
racing misses, coherent stats, no double-pop on eviction."""

import time
from concurrent.futures import ThreadPoolExecutor

from repro.core.cachetools import cached_get, evict_oldest, hit_rate


def test_concurrent_cached_get_builds_once_per_key():
    cache, stats, built = {}, {}, []

    def make(key):
        def _build():
            built.append(key)           # append is atomic; order irrelevant
            time.sleep(0.002)           # widen the would-be race window
            return ("value", key)
        return _build

    keys = [f"k{i}" for i in range(8)]
    with ThreadPoolExecutor(max_workers=16) as pool:
        futs = [pool.submit(cached_get, cache, keys[i % 8],
                            make(keys[i % 8]), stats)
                for i in range(400)]
        results = [f.result() for f in futs]

    # every key built exactly once despite 50 racing lookups apiece
    assert sorted(built) == sorted(keys)
    assert len(cache) == 8
    assert all(results[i] == ("value", keys[i % 8]) for i in range(400))
    assert stats["misses"] == 8
    assert stats["hits"] == 400 - 8
    assert hit_rate(stats) == (400 - 8) / 400


def test_concurrent_eviction_under_cap_stays_coherent():
    cache, stats = {}, {}

    def lookup(i):
        key = f"k{i}"
        return cached_get(cache, key, lambda: i, stats, cap=16)

    with ThreadPoolExecutor(max_workers=16) as pool:
        list(pool.map(lookup, range(300)))

    # cap respected, and the books balance: every insert beyond the
    # retained set was evicted exactly once
    assert len(cache) <= 16
    assert stats["misses"] == 300
    assert stats["evictions"] == 300 - len(cache)


def test_evict_oldest_drops_fifo_and_counts():
    cache = {k: k for k in "abcdef"}
    stats = {}
    dropped = evict_oldest(cache, 2, stats)
    assert dropped == 4
    assert list(cache) == ["e", "f"]    # oldest-inserted went first
    assert stats["evictions"] == 4
    assert evict_oldest(cache, None, stats) == 0   # uncapped: no-op


def test_hit_rate_edge_cases():
    assert hit_rate({}) == 0.0
    assert hit_rate({"hits": 3, "misses": 1}) == 0.75
