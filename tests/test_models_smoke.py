"""Per-arch reduced-config smoke: forward + one train step on CPU.

(The FULL configs are exercised AOT-only via the dry-run.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import Model
from repro.train import AdamWConfig, TrainOptions, init_state, make_train_step


def _batch(cfg, rng, B=2, S=32):
    s_text = S
    batch = {}
    if cfg.vision_tokens:
        s_text = S - cfg.vision_tokens if S > cfg.vision_tokens else S
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(rng, 2),
            (B, cfg.vision_tokens, cfg.d_model), jnp.float32)
        total = s_text + cfg.vision_tokens
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(total)[None, None], (B, 3, total)).astype(jnp.int32)
        batch["labels"] = jax.random.randint(
            jax.random.fold_in(rng, 3), (B, total), 0, cfg.vocab)
    else:
        batch["labels"] = jax.random.randint(
            jax.random.fold_in(rng, 3), (B, s_text), 0, cfg.vocab)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(rng, 4), (B, cfg.encoder_seq, cfg.d_model),
            jnp.float32)
    batch["tokens"] = jax.random.randint(rng, (B, s_text), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward_shapes_no_nans(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    kw = {k: batch[k] for k in
          ("vision_embeds", "mrope_positions", "frames") if k in batch}
    logits, aux = jax.jit(lambda p, t: model.forward(p, t, **kw))(
        params, batch["tokens"])
    S_out = batch["labels"].shape[1]
    assert logits.shape == (2, S_out, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    state = init_state(model, rng)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                   TrainOptions()))
    state, metrics = step(state, _batch(cfg, rng))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1


def test_param_counts_match_published_sizes():
    # within 25% of the advertised parameter counts
    expect = {"qwen2-7b": 7.6e9, "tinyllama-1.1b": 1.1e9,
              "kimi-k2-1t-a32b": 1.0e12, "xlstm-1.3b": 1.3e9}
    for name, n in expect.items():
        got = ARCHS[name].param_count()
        assert abs(got - n) / n < 0.25, (name, got)
    # MoE active counts
    assert abs(ARCHS["kimi-k2-1t-a32b"].active_param_count() - 32e9) / 32e9 < 0.15
