"""Property tests for the structural search layer: mutation validity,
canonical-key stability under relabeling, lossless spec round-trips for
machine-generated structures, and cross-process determinism of the
mutate -> lower -> score pipeline.

Randomized structures come from a seeded ``np.random`` generator; the
seed axis is driven by hypothesis when installed (the CI profile) and by
a fixed parametrized sweep otherwise, so the properties are exercised
either way."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import ProxySpec, cache_stats, get_stack
from repro.core import engine, schedule
from repro.core.dag import (Edge, ProxyDAG, StructureError,
                            insert_accumulating_edge, insert_edge,
                            merge_chain, remove_edge, split_edge,
                            swap_component)
from repro.core.dwarfs import ComponentParams
from repro.core.proxy import ProxyBenchmark
from repro.core.structsearch import (StructuralTuner, propose_mutation,
                                     validate_components)

try:
    from hypothesis import given, strategies as st

    def property_seeds(f):
        return given(seed=st.integers(0, 2 ** 31 - 1))(f)
except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    def property_seeds(f):
        return pytest.mark.parametrize("seed", range(25))(f)

SIZE = 2048
POOL = ["quick_sort", "merge_sort", "interval_sampling", "hash",
        "min_max", "monte_carlo"]


def _edge(comp, src, dst, weight=1):
    extra = {"rounds": 2} if comp == "hash" else {}
    return Edge(comp, src, dst,
                ComponentParams(data_size=SIZE, chunk_size=64,
                                weight=weight, extra=extra))


def _random_dag(rs: np.random.RandomState) -> ProxyDAG:
    """Chain DAGs with optional accumulating joins — the machine-mutation
    input shapes."""
    n = int(rs.randint(2, 6))
    edges = [_edge(POOL[rs.randint(len(POOL))],
                   ["src"] if i == 0 else [f"n{i - 1}"], f"n{i}",
                   int(rs.randint(0, 5))) for i in range(n)]
    if rs.rand() < 0.5:
        j = int(rs.randint(n))
        edges.insert(j + 1, _edge(POOL[rs.randint(len(POOL))], ["src"],
                                  f"n{j}"))
    dag = ProxyDAG("prop", {"src": SIZE}, edges, f"n{n - 1}")
    dag.validate_structure()
    return dag


def _snap(dag):
    return json.dumps(dag.to_json(), sort_keys=True)


def _snap_edge(e):
    return json.dumps(e.to_json(), sort_keys=True)


# ---------------------------------------------------------------------------
# mutation validity (acyclic, topologically ordered, connected to sink)
# ---------------------------------------------------------------------------


@property_seeds
def test_proposed_mutations_yield_valid_structures(seed):
    rs = np.random.RandomState(seed)
    dag = _random_dag(rs)
    before = _snap(dag)
    got = propose_mutation(dag, rs, POOL)
    assert _snap(dag) == before                  # proposals are pure
    if got is None:
        return
    child, mut = got
    child.validate_structure()                   # raises on any violation
    # the mutation's edit set is consistent: removed edges came from the
    # parent, added edges are in the child
    parent_edges = [_snap_edge(e) for e in dag.edges]
    child_edges = [_snap_edge(e) for e in child.edges]
    for e in mut.removed:
        assert _snap_edge(e) in parent_edges
    for e in mut.added:
        assert _snap_edge(e) in child_edges


@property_seeds
def test_mutation_chains_stay_valid(seed):
    """Repeated mutation (the evolutionary loop's actual usage) must never
    drift out of the valid region."""
    rs = np.random.RandomState(seed)
    cur = _random_dag(rs)
    for _ in range(4):
        got = propose_mutation(cur, rs, POOL)
        if got is None:
            break
        cur = got[0]
        cur.validate_structure()


def test_primitives_reject_illegal_sites():
    dag = ProxyDAG("t", {"src": SIZE},
                   [_edge("quick_sort", ["src"], "a", 1)], "a")
    with pytest.raises(StructureError):
        remove_edge(dag, 0)                      # last edge
    with pytest.raises(StructureError):
        split_edge(dag, 0, 1)                    # weight 1 cannot split
    with pytest.raises(StructureError):
        merge_chain(dag, 0)                      # nothing after edge 0
    with pytest.raises(StructureError):
        swap_component(dag, 0, "quick_sort")     # same component
    with pytest.raises(KeyError):
        insert_edge(dag, 0, "not_a_component")
    with pytest.raises(StructureError):
        insert_accumulating_edge(dag, "nowhere", 0, "min_max")


def test_split_then_merge_restores_canonical_structure():
    dag = ProxyDAG("t", {"src": SIZE},
                   [_edge("quick_sort", ["src"], "a", 4),
                    _edge("min_max", ["a"], "b", 1)], "b")
    split = split_edge(dag, 0, 1)
    assert len(split.edges) == 3
    merged = merge_chain(split, 0)
    assert merged.canonical_structure_key() == dag.canonical_structure_key()


def test_remove_edge_bypasses_consumers_and_sink():
    dag = ProxyDAG("t", {"src": SIZE},
                   [_edge("quick_sort", ["src"], "a", 2),
                    _edge("min_max", ["a"], "b", 1)], "b")
    no_tail = remove_edge(dag, 1)
    assert no_tail.sink == "a"
    no_head = remove_edge(dag, 0)
    assert no_head.edges[0].src == ["src"]
    no_head.validate_structure()


def test_validate_structure_rejects_dead_edges():
    dag = ProxyDAG("t", {"src": SIZE},
                   [_edge("quick_sort", ["src"], "a", 1),
                    _edge("min_max", ["src"], "dead", 1)], "a")
    with pytest.raises(StructureError):
        dag.validate_structure()


# ---------------------------------------------------------------------------
# canonical structure keys (isomorphic relabeling)
# ---------------------------------------------------------------------------


@property_seeds
def test_canonical_key_stable_under_relabeling(seed):
    rs = np.random.RandomState(seed)
    dag = _random_dag(rs)
    mapping = {e.dst: f"x_{i}" for i, e in enumerate(dag.edges)}
    relabeled = ProxyDAG(
        dag.name, dict(dag.sources),
        [Edge(e.component, [mapping.get(s, s) for s in e.src],
              mapping.get(e.dst, e.dst), e.params) for e in dag.edges],
        mapping.get(dag.sink, dag.sink))
    relabeled.validate_structure()
    assert (relabeled.canonical_structure_key()
            == dag.canonical_structure_key())
    # ... and the canonical key still separates genuinely different
    # structures: dropping an edge changes it
    try:
        pruned = remove_edge(dag, 0)
    except StructureError:
        return
    assert (pruned.canonical_structure_key()
            != dag.canonical_structure_key())


def test_relabeled_structure_shares_plan_and_executable():
    d1 = ProxyDAG("a", {"src": SIZE},
                  [_edge("quick_sort", ["src"], "mid", 2),
                   _edge("min_max", ["mid"], "out", 1)], "out")
    d2 = ProxyDAG("b", {"src": SIZE},
                  [_edge("quick_sort", ["src"], "other", 2),
                   _edge("min_max", ["other"], "final", 1)], "final")
    assert schedule.lower(d1) is schedule.lower(d2)       # one cached plan
    stack = get_stack("openmp")
    r1 = stack.run(d1)
    t0 = cache_stats()["traces"]
    r2 = stack.run(d2)
    assert cache_stats()["traces"] == t0                  # cache hit
    assert np.asarray(r1.result) == np.asarray(r2.result)  # bit-identical


# ---------------------------------------------------------------------------
# spec round-trip for machine-generated structures
# ---------------------------------------------------------------------------


@property_seeds
def test_mutated_spec_roundtrips_losslessly(seed):
    rs = np.random.RandomState(seed)
    cur = _random_dag(rs)
    for _ in range(3):
        got = propose_mutation(cur, rs, POOL)
        if got is not None:
            cur = got[0]
    spec = ProxySpec.from_dag(cur, stack="openmp")
    text = spec.dumps()                          # json-serializable always
    loaded = ProxySpec.loads(text)
    redag = loaded.to_dag()
    assert redag.structure_key() == cur.structure_key()
    assert (redag.canonical_structure_key()
            == cur.canonical_structure_key())
    assert loaded.dumps() == text                # idempotent re-dump
    # re-lowering reproduces the exact stage partition
    p1 = schedule.lower(cur, threshold=0.0, cache=False)
    p2 = schedule.lower(redag, threshold=0.0, cache=False)
    assert p1.structure_key() == p2.structure_key()


def test_numpy_scalars_in_params_serialize():
    dag = ProxyDAG("t", {"src": SIZE},
                   [_edge("quick_sort", ["src"], "a", 1)], "a")
    dag.edges[0].params.weight = np.int64(3)
    dag.edges[0].params.extra["rounds"] = np.float64(2.0)
    spec = ProxySpec.from_dag(dag)
    loaded = ProxySpec.loads(spec.dumps())
    e = loaded.to_dag().edges[0]
    assert e.params.weight == 3
    assert isinstance(e.to_json()["weight"], int)


def test_fractional_weight_executes_and_serializes_identically():
    """rounded() and the dynamic-param path must agree on fractional
    weights (round-half-away, not truncate), or save/load changes the
    executed repeat count."""
    p = ComponentParams(data_size=SIZE, chunk_size=64, weight=2.7)
    assert p.rounded().weight == 3
    e = Edge("quick_sort", ["src"], "a", p)
    assert int(e.dynamic_values()["weight"]) == e.to_json()["weight"] == 3


# ---------------------------------------------------------------------------
# cross-process determinism of mutate -> lower -> score
# ---------------------------------------------------------------------------


def _search_fingerprint() -> str:
    """Canonical keys, plan partitions, and scored metrics of a fixed
    mutation trajectory — byte-identical across processes."""
    dag = ProxyDAG("fp", {"src": SIZE},
                   [_edge("interval_sampling", ["src"], "a", 1),
                    _edge("quick_sort", ["a"], "b", 3),
                    _edge("merge_sort", ["b"], "c", 2)], "c")
    rs = np.random.RandomState(1234)
    scorer = engine.StructureScorer()
    out = []
    cur = dag
    for _ in range(6):
        got = propose_mutation(cur, rs, POOL)
        if got is None:
            continue
        child, mut = got
        plan = schedule.lower(child, threshold=0.0, cache=False)
        metrics = scorer.score_child(cur, child, mut.removed, mut.added)
        out.append({
            "kind": mut.kind,
            "detail": mut.detail,
            "key": repr(child.canonical_structure_key()),
            "partition": [list(m) for m in plan.partition()],
            "metrics": {k: round(v, 9) for k, v in sorted(metrics.items())
                        if k.startswith("mix_")
                        or k == "arithmetic_intensity"},
        })
        cur = child
    return json.dumps(out, sort_keys=True)


def test_mutate_lower_score_deterministic_in_process():
    assert _search_fingerprint() == _search_fingerprint()


def test_mutate_lower_score_deterministic_across_processes():
    want = _search_fingerprint()
    code = ("import sys, tests.test_structsearch as t;"
            "sys.stdout.write(t._search_fingerprint())")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"), env.get("PYTHONPATH", "")])
    env.setdefault("JAX_PLATFORMS", "cpu")
    got = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, check=True).stdout
    assert got == want


# ---------------------------------------------------------------------------
# scoring: delta == full assembly, plan == dag
# ---------------------------------------------------------------------------


@property_seeds
def test_delta_scoring_matches_full_assembly(seed):
    rs = np.random.RandomState(seed)
    dag = _random_dag(rs)
    got = propose_mutation(dag, rs, POOL)
    if got is None:
        return
    child, mut = got
    scorer = engine.StructureScorer()
    scorer.score(dag)                             # parent cached
    delta = scorer.score_child(dag, child, mut.removed, mut.added)
    full = engine.StructureScorer().score(child)
    for k, v in full.items():
        assert delta[k] == pytest.approx(v, rel=1e-9, abs=1e-9), k


def test_measure_plan_matches_measure_dag():
    dag = ProxyDAG("t", {"src": SIZE},
                   [_edge("quick_sort", ["src"], "a", 2),
                    _edge("min_max", ["a"], "b", 1)], "b")
    plan = schedule.lower(dag, threshold=0.0, cache=False)
    via_plan = engine.measure_plan(plan)
    via_dag = engine.measure(dag)
    for k, v in via_dag.items():
        assert via_plan[k] == pytest.approx(v, rel=1e-9, abs=1e-9), k


# ---------------------------------------------------------------------------
# tuner budget / bookkeeping
# ---------------------------------------------------------------------------


def test_structural_tuner_respects_total_budget():
    ref = ProxyDAG("ref", {"src": SIZE},
                   [_edge("interval_sampling", ["src"], "a", 1),
                    _edge("quick_sort", ["a"], "b", 4),
                    _edge("merge_sort", ["b"], "c", 2)], "c")
    target = engine.measure(ref)
    det = ProxyDAG("det", {"src": SIZE},
                   [_edge("interval_sampling", ["src"], "a", 1),
                    _edge("quick_sort", ["a"], "b", 1)], "b")
    tuner = StructuralTuner(target, max_candidates=40, generations=3,
                            components=POOL, seed=0, tol=0.05)
    res = tuner.tune(ProxyBenchmark(det))
    assert res.candidates_evaluated <= 40
    assert (res.candidates_evaluated
            == res.structures_scored + res.weight_candidates)
    assert res.final_accuracy["avg"] >= res.initial_accuracy["avg"] - 1e-9
    # every structure the result references is valid and serializable
    res.proxy.dag.validate_structure()
    ProxySpec.from_benchmark(res.proxy).dumps()


def test_executable_cache_reports_eviction_pressure():
    assert "evictions" in cache_stats()


def test_component_pool_typos_fail_loudly():
    dag = ProxyDAG("t", {"src": SIZE},
                   [_edge("quick_sort", ["src"], "a", 1)], "a")
    with pytest.raises(KeyError):
        propose_mutation(dag, np.random.RandomState(0),
                         ["quick_sort", "not_a_component"])
    with pytest.raises(KeyError):
        validate_components(["qwick_sort"])
