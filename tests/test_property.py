"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dwarfs import ComponentParams
from repro.core.dwarfs.base import fit_buffer
from repro.core.metrics import (eq1_accuracy, metric_accuracy, parse_shapes,
                                shape_bytes, vector_accuracy)
from repro.models.components import moe_apply, sdpa, blockwise_sdpa

SETTINGS = dict(max_examples=25, deadline=None)


@given(h=st.floats(-1e6, 1e6, allow_nan=False),
       p=st.floats(-1e6, 1e6, allow_nan=False))
@settings(**SETTINGS)
def test_eq1_accuracy_bounded(h, p):
    a = eq1_accuracy(h, p)
    assert 0.0 <= a <= 1.0
    if h == p:
        assert a == 1.0


@given(share_h=st.floats(0, 1), share_p=st.floats(0, 1))
@settings(**SETTINGS)
def test_mix_accuracy_symmetric_bounded(share_h, share_p):
    a = metric_accuracy("mix_dot", share_h, share_p)
    b = metric_accuracy("mix_dot", share_p, share_h)
    assert a == pytest.approx(b)
    assert 0.0 <= a <= 1.0


@given(n=st.integers(1, 5000), m=st.integers(1, 5000))
@settings(**SETTINGS)
def test_fit_buffer_always_exact_length(n, m):
    x = jnp.arange(n, dtype=jnp.float32)
    y = fit_buffer(x, m)
    assert y.shape == (m,)


@given(data_size=st.integers(1, 1 << 22), chunk=st.integers(1, 1 << 16),
       par=st.integers(-5, 1000), weight=st.integers(-3, 500))
@settings(**SETTINGS)
def test_component_params_rounding_invariants(data_size, chunk, par, weight):
    p = ComponentParams(data_size, chunk, par, weight).rounded()
    assert p.chunk_size >= 8 and p.chunk_size % 8 == 0
    assert p.data_size >= p.chunk_size
    assert p.data_size % p.chunk_size == 0
    assert 1 <= p.parallelism <= 256
    assert 0 <= p.weight <= 128


@given(st.text(alphabet="abcdefxyz0123456789[],() ", max_size=60))
@settings(**SETTINGS)
def test_shape_parser_never_crashes(s):
    shapes = parse_shapes(s)
    assert shape_bytes(shapes) >= 0


@given(sq=st.integers(1, 64), skv=st.integers(1, 96),
       h=st.sampled_from([2, 4]), kv=st.sampled_from([1, 2]),
       causal=st.booleans())
@settings(max_examples=10, deadline=None)
def test_blockwise_equals_naive_sdpa(sq, skv, h, kv, causal):
    if h % kv:
        kv = 1
    rng = jax.random.PRNGKey(sq * 1000 + skv)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, sq, h, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, skv, kv, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, skv, kv, 16), jnp.float32)
    a = sdpa(q, k, v, causal=causal)
    b = blockwise_sdpa(q, k, v, causal=causal, block_q=16, block_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_moe_no_drop_equals_dense_reference(rng):
    """With capacity_factor high enough to avoid drops, routed MoE output
    must equal the dense gather-per-token reference."""
    import dataclasses
    from repro.configs import ARCHS
    from repro.models.components import moe_init

    cfg = dataclasses.replace(
        ARCHS["granite-moe-3b-a800m"].reduced(),
        moe_capacity_factor=8.0, moe_groups=2)
    p = moe_init(rng, cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = moe_apply(p, x, cfg)

    # dense reference: every token through its top-k experts
    xt = x.reshape(-1, cfg.d_model)
    logits = (xt @ p["router"]).astype(jnp.float32)
    gate, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe_topk)
    gate = gate / gate.sum(-1, keepdims=True)
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    ys = []
    for t in range(xt.shape[0]):
        acc = 0.0
        for j in range(cfg.moe_topk):
            e = int(eidx[t, j])
            h = jax.nn.silu(xt[t] @ wg[e]) * (xt[t] @ wu[e])
            acc = acc + gate[t, j] * (h @ wd[e])
        ys.append(acc)
    ref = jnp.stack(ys).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
    assert float(aux) > 0


@given(b=st.integers(1, 3), s=st.integers(4, 32))
@settings(max_examples=8, deadline=None)
def test_vector_accuracy_avg_bounded(b, s):
    t = {"flops": float(b * s), "mix_dot": 0.5}
    p = {"flops": float(b), "mix_dot": 0.9}
    acc = vector_accuracy(t, p)
    assert 0.0 <= acc["avg"] <= 1.0
