"""Unit tests: the eight dwarf components (semantics + robustness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dwarfs import DWARFS, REGISTRY, ComponentParams, get_component
from repro.core.dwarfs.base import as_u32, fit_buffer

P = ComponentParams(data_size=2048, chunk_size=128, parallelism=1, weight=1)


def test_registry_covers_all_eight_dwarfs():
    assert {c.dwarf for c in REGISTRY.values()} == set(DWARFS)
    assert len(REGISTRY) >= 24  # >= 3 components per dwarf


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_component_runs_finite(name, rng):
    comp = REGISTRY[name]
    x = jax.random.normal(rng, (P.data_size,))
    out = jax.jit(lambda x, r: comp(x, P, r))(x, rng)
    assert np.isfinite(np.asarray(out)).all()
    assert out.ndim == 1 and out.shape[0] > 0


def test_quick_sort_sorts_rows(rng):
    comp = get_component("quick_sort")
    x = jax.random.normal(rng, (2048,))
    out = np.asarray(comp(x, P, rng)).reshape(-1, P.chunk_size)
    assert (np.diff(out, axis=1) >= 0).all()


def test_top_k_values_descend(rng):
    comp = get_component("top_k")
    x = jax.random.normal(rng, (2048,))
    out = np.asarray(comp(x, P.replace(extra={"k": 16}), rng))
    rows = out.reshape(-1, P.chunk_size)[:, :16]
    assert (np.diff(rows, axis=1) <= 1e-6).all()


def test_histogram_counts_consistent(rng):
    comp = get_component("histogram")
    x = jax.random.normal(rng, (2048,))
    p = P.replace(extra={"bins": 16})
    out = np.asarray(comp(x, p, rng))
    # output = counts[bin(x)] / N: every value in (0, 1], sums finite
    assert (out > 0).all() and (out <= 1.0).all()


def test_hash_deterministic_and_avalanche(rng):
    comp = get_component("hash")
    x = jax.random.normal(rng, (2048,))
    a = np.asarray(comp(x, P, rng))
    b = np.asarray(comp(x, P, rng))
    assert (a == b).all()
    # flipping one input element changes a bounded, nonzero set of outputs
    x2 = x.at[7].set(x[7] + 1.0)
    c = np.asarray(comp(x2, P, rng))
    assert (a != c).any()


def test_set_intersection_against_numpy(rng):
    comp = get_component("set_intersection")
    x = jax.random.normal(rng, (2048,))
    p = P.replace(extra={"buckets": 64})
    out = np.asarray(comp(x, p, rng))
    keys = np.asarray(as_u32(fit_buffer(x, 2048))) % 64
    h = 1024
    a, b = keys[:h], keys[h:]
    expected_nonzero = len(np.intersect1d(a, b)) > 0
    assert (np.count_nonzero(out[:h]) > 0) == expected_nonzero


def test_graph_construction_degree_mass(rng):
    comp = get_component("graph_construction")
    x = jax.random.normal(rng, (2048,))
    p = P.replace(extra={"vertices": 64})
    out = np.asarray(comp(x, p, rng))
    # gathered out_deg[src] + in_deg[dst]: strictly positive, mean >= 2
    assert (out >= 1.0).all()
    assert out.mean() >= 2.0


def test_spmv_conserves_rank_mass(rng):
    comp = get_component("spmv")
    x = jax.random.normal(rng, (4096,))
    p = P.replace(extra={"vertices": 128})
    out = np.asarray(comp(x, p, rng))
    assert np.isfinite(out).all() and (out >= 0).all()


def test_parallelism_lanes_change_shape_not_values_distribution(rng):
    comp = get_component("count_average")
    x = jax.random.normal(rng, (4096,))
    a = np.asarray(comp(x, P.replace(data_size=4096), rng))
    b = np.asarray(comp(x, P.replace(data_size=4096, parallelism=4), rng))
    assert a.shape == b.shape
    assert abs(a.std() - b.std()) < 0.5


def test_weight_zero_means_pruned():
    p = ComponentParams(weight=0).rounded()
    assert p.weight == 0
