"""ExecutionPlan lowering contract: fused-stage execution must be
bit-identical to the unfused per-edge path (per stack and per dwarf
component), bucket schedules must be deterministic across processes, and
bucketed population execution must hold the ≤1-executable-per-bucket /
0-retrace contract."""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # property test skips; deterministic tests run
    HAVE_HYPOTHESIS = False

from repro.api import ParamSpace, cache_stats, get_stack
from repro.core import schedule
from repro.core.dag import Edge, ProxyDAG
from repro.core.dwarfs import ComponentParams
from repro.core.dwarfs.base import REGISTRY

POP = 3
SIZE = 1024

#: per-component extras that must exist for the dynamic tunables to appear
_SEED_EXTRAS = {
    "hash": {"rounds": 2},
    "encryption": {"rounds": 2},
    "histogram": {"mix_rounds": 1},
    "grouped_count": {"mix_rounds": 1},
    "top_k": {"k": 8},
}

FUSE_ALL = 1e30


def _chain_dag(component: str, size: int = SIZE) -> ProxyDAG:
    """component -> hash chain on one buffer size: a fusable private
    linear chain exercising the component inside a fused switch loop."""
    return ProxyDAG(
        f"sched_{component}", {"src": size},
        [Edge(component, ["src"], "mid",
              ComponentParams(data_size=size, chunk_size=64, weight=2,
                              extra=dict(_SEED_EXTRAS.get(component, {})))),
         Edge("hash", ["mid"], "out",
              ComponentParams(data_size=size, chunk_size=128, weight=1,
                              extra={"rounds": 2}))],
        "out")


_CACHE = {}


def _component_fixture(component):
    """(dag, space, fused jitted pfn, unfused jitted pfn) built once per
    component — hypothesis examples step only *dynamic* params, so both
    executables compile exactly once."""
    if component not in _CACHE:
        dag = _chain_dag(component)
        space = ParamSpace.from_dag(dag)
        fused = schedule.lower(dag, threshold=FUSE_ALL, cache=False)
        unfused = schedule.lower(dag, threshold=0.0, cache=False)
        assert fused.fused_stage_count == 1, component
        assert unfused.fused_stage_count == 0
        _CACHE[component] = (dag, space,
                             jax.jit(fused.build_parametric()),
                             jax.jit(unfused.build_parametric()))
    return _CACHE[component]


def _assert_fused_matches_unfused(component, weights, extras):
    dag, space, fused, unfused = _component_fixture(component)
    base = space.values(dag)
    rows = np.tile(base, (POP, 1))
    for i, w in enumerate(weights):
        for li, leaf in enumerate(space.leaves):
            if leaf.dynamic:
                rows[i, li] = w if leaf.field == "weight" else extras[i]
    batched = space.stack_candidates(dag, rows)
    rng = jax.random.PRNGKey(0)
    for i, dyn in enumerate(space.unstack_candidates(batched)):
        a = np.asarray(fused(rng, dyn))
        b = np.asarray(unfused(rng, dyn))
        assert a == b, (
            f"{component}: candidate {i} (weight={weights[i]}, "
            f"extra={extras[i]}) fused {a!r} != unfused {b!r}")


# ---------------------------------------------------------------------------
# fused == unfused, bit-identical, per dwarf component (hypothesis sweep)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("component", sorted(REGISTRY))
    @given(data=st.data())
    def test_fused_stage_matches_unfused_per_component(component, data):
        weights = data.draw(st.lists(st.integers(0, 5), min_size=POP,
                                     max_size=POP), label="weights")
        extras = data.draw(st.lists(st.integers(1, 4), min_size=POP,
                                    max_size=POP), label="extras")
        _assert_fused_matches_unfused(component, weights, extras)


#: deterministic tier-1 subset of the hypothesis sweep above
_FAMILY_SUBSET = sorted({
    "matrix_multiplication", "monte_carlo", "hash", "encryption", "fft",
    "jaccard", "graph_traversal", "quick_sort", "top_k", "histogram",
    "grouped_count", "count_average",
})


@pytest.mark.parametrize("component", _FAMILY_SUBSET)
def test_fused_stage_matches_unfused_fixed(component):
    _assert_fused_matches_unfused(component, weights=[0, 2, 5],
                                  extras=[1, 3, 2])


# ---------------------------------------------------------------------------
# fused == unfused on every stack (threshold flip via the env knob)
# ---------------------------------------------------------------------------


def _stack_dag() -> ProxyDAG:
    return ProxyDAG(
        "sched_stacks", {"src": 2048},
        [Edge("quick_sort", ["src"], "a",
              ComponentParams(data_size=2048, chunk_size=128, weight=2)),
         Edge("hash", ["a"], "b",
              ComponentParams(data_size=2048, chunk_size=256, weight=3,
                              extra={"rounds": 2})),
         Edge("min_max", ["b"], "out",
              ComponentParams(data_size=2048, chunk_size=128, weight=1))],
        "out")


@pytest.mark.parametrize("stack_name", ["openmp", "mpi", "spark", "hadoop"])
def test_fused_run_matches_unfused_on_stack(stack_name, monkeypatch):
    stack = get_stack(stack_name)
    rng = jax.random.PRNGKey(0)
    monkeypatch.setenv("REPRO_FUSION_THRESHOLD", str(FUSE_ALL))
    assert schedule.lower(_stack_dag()).fused_stage_count == 1
    fused = stack.run(_stack_dag(), rng=rng)
    monkeypatch.setenv("REPRO_FUSION_THRESHOLD", "0")
    assert schedule.lower(_stack_dag()).fused_stage_count == 0
    unfused = stack.run(_stack_dag(), rng=rng)
    assert np.asarray(fused.result) == np.asarray(unfused.result)
    if stack_name == "hadoop":
        # spilling per fused stage (one chain spill) must move strictly
        # less host traffic than spilling per edge
        assert 0.0 < fused.io_bytes < unfused.io_bytes


def test_fused_population_matches_unfused_on_stack(monkeypatch):
    dag = _stack_dag()
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(5, space.values(dag), seed=7)
    stack = get_stack("openmp")
    monkeypatch.setenv("REPRO_FUSION_THRESHOLD", str(FUSE_ALL))
    fused = np.asarray(stack.run_population(dag, matrix).result)
    monkeypatch.setenv("REPRO_FUSION_THRESHOLD", "0")
    unfused = np.asarray(stack.run_population(dag, matrix).result)
    np.testing.assert_array_equal(fused, unfused)


# ---------------------------------------------------------------------------
# lowering + plan cache
# ---------------------------------------------------------------------------


def test_lower_caches_per_structure_and_threshold():
    d1, d2 = _stack_dag(), _stack_dag()
    d2.edges[0].params.weight = 9          # dynamic step: same structure
    p1 = schedule.lower(d1, threshold=FUSE_ALL)
    p2 = schedule.lower(d2, threshold=FUSE_ALL)
    assert p1 is p2                        # one plan per (structure, thr)
    p3 = schedule.lower(d1, threshold=0.0)
    assert p3 is not p1
    assert p3.structure_key() != p1.structure_key()   # partition in the key
    d3 = _stack_dag()
    d3.edges[0].params.data_size = 4096    # structural step: new plan
    assert schedule.lower(d3, threshold=FUSE_ALL) is not p1


def test_fusion_requires_private_linear_chain():
    # "a" feeds two consumers -> edge 0 must not fuse into edge 1
    dag = ProxyDAG(
        "diamond", {"src": 1024},
        [Edge("hash", ["src"], "a",
              ComponentParams(data_size=1024, chunk_size=64, weight=1,
                              extra={"rounds": 1})),
         Edge("min_max", ["a"], "b",
              ComponentParams(data_size=1024, chunk_size=64, weight=1)),
         Edge("histogram", ["a", "b"], "out",
              ComponentParams(data_size=1024, chunk_size=64, weight=1))],
        "out")
    plan = schedule.lower(dag, threshold=FUSE_ALL, cache=False)
    assert plan.partition() == ((0,), (1,), (2,))


def test_threshold_zero_is_one_stage_per_edge():
    plan = schedule.lower(_stack_dag(), threshold=0.0, cache=False)
    assert plan.partition() == ((0,), (1,), (2,))
    assert plan.fused_stage_count == 0


def test_fused_plan_has_fewer_loop_ops():
    dag = ProxyDAG(
        "two_mm", {"src": 1024},
        [Edge("matrix_multiplication", ["src"], "a",
              ComponentParams(data_size=1024, chunk_size=64, weight=2)),
         Edge("matrix_multiplication", ["a"], "out",
              ComponentParams(data_size=1024, chunk_size=64, weight=3))],
        "out")
    rng = jax.random.PRNGKey(0)
    dyn = dag.dynamic_params()

    def loops(jaxpr):
        n = 0
        for eq in jaxpr.eqns:
            if eq.primitive.name in ("while", "scan"):
                n += 1
            for v in eq.params.values():
                for vv in (v if isinstance(v, (list, tuple)) else (v,)):
                    if hasattr(vv, "jaxpr"):
                        n += loops(vv.jaxpr)
        return n

    unfused = schedule.lower(dag, threshold=0.0, cache=False)
    fused = schedule.lower(dag, threshold=FUSE_ALL, cache=False)
    ju = jax.make_jaxpr(unfused.build_parametric())(rng, dyn)
    jf = jax.make_jaxpr(fused.build_parametric())(rng, dyn)
    assert loops(jf.jaxpr) < loops(ju.jaxpr)


# ---------------------------------------------------------------------------
# bucket schedules
# ---------------------------------------------------------------------------


def _schedule_fingerprint(bucket_size=None) -> str:
    dag = _stack_dag()
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(16, space.values(dag), seed=13)
    plan = schedule.lower(dag, threshold=0.0, cache=False)
    sched = plan.bucket_schedule(space.stack_candidates(dag, matrix),
                                 bucket_size)
    return json.dumps({
        "signature": list(sched.signature),
        "buckets": [[b.indices.tolist(), b.valid, b.trip_bound]
                    for b in sched.buckets],
    })


def test_bucket_schedule_is_deterministic_across_processes():
    want = _schedule_fingerprint(bucket_size=4)
    code = (
        "import sys, tests.test_schedule as t;"
        "sys.stdout.write(t._schedule_fingerprint(bucket_size=4))")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
         os.path.join(os.path.dirname(os.path.dirname(
             os.path.abspath(__file__))), "src"),
         env.get("PYTHONPATH", "")])
    env.setdefault("JAX_PLATFORMS", "cpu")
    got = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, check=True).stdout
    assert got == want


def test_bucket_schedule_invariants():
    dag = _stack_dag()
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(10, space.values(dag), seed=3)
    plan = schedule.lower(dag, threshold=0.0, cache=False)
    sched = plan.bucket_schedule(space.stack_candidates(dag, matrix), 4)
    assert sched.signature == (3, 4)
    # every bucket padded to one shared size; real indices partition [0, n)
    seen = []
    for b in sched.buckets:
        assert b.indices.shape == (4,)
        seen.extend(b.indices[:b.valid].tolist())
    assert sorted(seen) == list(range(10))
    # stratified: bucket cost bounds are nondecreasing
    bounds = [b.cost_bound for b in sched.buckets]
    assert bounds == sorted(bounds)
    masses = sched.bucket_masses()
    assert masses.shape == (3,) and masses.sum() == pytest.approx(1.0)


def test_bucketed_execution_matches_single_batch():
    dag = _stack_dag()
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(8, space.values(dag), seed=21)
    stack = get_stack("openmp")
    whole = np.asarray(
        stack.run_population(dag, matrix, bucket_size=8).result)
    for bs in (1, 3, 4):
        got = np.asarray(
            stack.run_population(dag, matrix, bucket_size=bs).result)
        np.testing.assert_array_equal(got, whole)


# ---------------------------------------------------------------------------
# ≤1 executable per bucket signature / 0 retraces across sweeps
# ---------------------------------------------------------------------------


def test_bucketed_sweep_compiles_one_executable_and_never_retraces():
    from repro.api.stack import OpenMPStack
    dag = _stack_dag()
    space = ParamSpace.from_dag(dag)
    base = space.values(dag)
    stack = OpenMPStack()                 # fresh executable cache
    m0 = cache_stats()["misses"]
    stack.run_population(dag, space.sample_dynamic(12, base, seed=0),
                         bucket_size=3)
    assert cache_stats()["misses"] - m0 == 1   # one executable, 4 buckets
    t0, m1 = cache_stats()["traces"], cache_stats()["misses"]
    for seed in (1, 2, 3):
        rep = stack.run_population(dag,
                                   space.sample_dynamic(12, base, seed=seed),
                                   bucket_size=3)
        assert rep.batch == 12
    # population-size changes re-bucket onto the same executable
    stack.run_population(dag, space.sample_dynamic(9, base, seed=4),
                         bucket_size=3)
    assert cache_stats()["traces"] == t0
    assert cache_stats()["misses"] == m1


def test_default_bucket_size_follows_devices_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_POP_BUCKETS", raising=False)
    assert schedule.resolve_bucket_size(16) == max(1, min(
        16, jax.device_count()))
    monkeypatch.setenv("REPRO_POP_BUCKETS", "4")
    assert schedule.resolve_bucket_size(16) == 4
    assert schedule.resolve_bucket_size(3) == 1
    monkeypatch.setenv("REPRO_POP_BUCKETS", "1")
    assert schedule.resolve_bucket_size(16) == 16


# ---------------------------------------------------------------------------
# megakernel: one-kernel fused stages ≡ the fori_loop + switch path
# ---------------------------------------------------------------------------


def _mega_dag() -> ProxyDAG:
    """A private linear chain whose members all have registered megakernel
    segment bodies (quick_sort/hash/top_k/min_max) — fused under FUSE_ALL
    it lowers to a single mega-eligible stage."""
    P = lambda w, **e: ComponentParams(data_size=2048, chunk_size=128,
                                       weight=w, extra=e)
    return ProxyDAG(
        "mega_chain", {"src": 2048},
        [Edge("quick_sort", ["src"], "a", P(2)),
         Edge("hash", ["a"], "b", P(3, rounds=2)),
         Edge("top_k", ["b"], "c", P(2, k=8)),
         Edge("min_max", ["c"], "out", P(1))],
        "out")


def _run_plan(plan, dag):
    """Fresh-jitted scalar result (a new jit per call, so flipping env
    knobs between calls always retraces)."""
    out = jax.jit(plan.build_parametric())(jax.random.PRNGKey(0),
                                           dag.dynamic_params())
    return np.asarray(out)


@pytest.fixture
def pallas_env(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    monkeypatch.delenv("REPRO_MEGAKERNEL", raising=False)


def test_megakernel_stage_is_bit_identical_to_fori_path(pallas_env,
                                                        monkeypatch):
    dag = _mega_dag()
    fused = schedule.lower(dag, threshold=FUSE_ALL, cache=False)
    unfused = schedule.lower(dag, threshold=0.0, cache=False)
    assert fused.partition() == ((0, 1, 2, 3),)
    assert fused.stages[0].mega                 # structural eligibility
    assert fused.mega_stage_count == 1
    assert unfused.mega_stage_count == 0

    schedule.reset_mega_stats()
    a = _run_plan(fused, dag)                   # megakernel engaged
    assert schedule.mega_stats() == {"mega": 1, "fallback": 0}

    monkeypatch.setenv("REPRO_MEGAKERNEL", "0")
    schedule.reset_mega_stats()
    b = _run_plan(fused, dag)                   # same plan, fori+switch
    assert schedule.mega_stats() == {"mega": 0, "fallback": 1}
    monkeypatch.delenv("REPRO_MEGAKERNEL", raising=False)

    c = _run_plan(unfused, dag)                 # per-edge path
    assert a == b, f"megakernel {a!r} != fori_loop {b!r}"
    assert a == c, f"megakernel {a!r} != unfused {c!r}"


def test_megakernel_matches_across_weight_steps(pallas_env, monkeypatch):
    """Dynamic weights are the one traced input the kernel accepts (the
    per-segment trip bound): stepping them must track the fori path
    bit-for-bit, including zero-trip members."""
    dag = _mega_dag()
    fused = schedule.lower(dag, threshold=FUSE_ALL, cache=False)
    space = ParamSpace.from_dag(dag)
    rows = space.sample_dynamic(4, space.values(dag), seed=11)
    # force one candidate to all-zero weights (identity stage)
    for li, leaf in enumerate(space.leaves):
        if leaf.dynamic and leaf.field == "weight":
            rows[0, li] = 0
    batched = space.stack_candidates(dag, rows)
    rng = jax.random.PRNGKey(0)
    jm = jax.jit(fused.build_parametric())
    monkeypatch.setenv("REPRO_MEGAKERNEL", "0")
    jf = jax.jit(fused.build_parametric())
    monkeypatch.delenv("REPRO_MEGAKERNEL", raising=False)
    for dyn in space.unstack_candidates(batched):
        got = np.asarray(jm(rng, dyn))
        with_fori = None
        try:
            monkeypatch.setenv("REPRO_MEGAKERNEL", "0")
            with_fori = np.asarray(jf(rng, dyn))
        finally:
            monkeypatch.delenv("REPRO_MEGAKERNEL", raising=False)
        assert got == with_fori


def test_megakernel_degrades_under_forced_xla(pallas_env):
    """The circuit breaker's forced-XLA override must demote a mega stage
    to the stock path *and* produce the stock result — the degrade
    contract extends through the megakernel."""
    from repro.kernels.dispatch import forced_backend
    dag = _mega_dag()
    fused = schedule.lower(dag, threshold=FUSE_ALL, cache=False)
    assert fused.stages[0].mega
    schedule.reset_mega_stats()
    with forced_backend("xla"):
        degraded = _run_plan(fused, dag)
    st = schedule.mega_stats()
    assert st["mega"] == 0 and st["fallback"] == 1
    with forced_backend("xla"):
        stock = _run_plan(schedule.lower(dag, threshold=0.0, cache=False),
                          dag)
    assert degraded == stock


def test_megakernel_flag_is_part_of_exec_cache_key(pallas_env, monkeypatch):
    """Flipping REPRO_MEGAKERNEL between runs on one stack must compile a
    second executable, never reuse one traced for the other lowering."""
    from repro.api.stack import OpenMPStack
    dag = _mega_dag()
    monkeypatch.setenv("REPRO_FUSION_THRESHOLD", str(FUSE_ALL))
    stack = OpenMPStack()
    a = np.asarray(stack.run(dag, rng=jax.random.PRNGKey(0)).result)
    m0 = stack.exec_domain().stats["misses"]
    monkeypatch.setenv("REPRO_MEGAKERNEL", "0")
    b = np.asarray(stack.run(dag, rng=jax.random.PRNGKey(0)).result)
    assert stack.exec_domain().stats["misses"] == m0 + 1
    assert a == b
