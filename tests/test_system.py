"""End-to-end behaviour of the paper's system: profile a workload ->
decompose to dwarfs -> build proxy -> autotune -> validate accuracy+speedup.

This is the paper's Fig. 2 pipeline executed on the smallest workload.
"""

import jax
import numpy as np

from repro.core import (characterize, decompose_to_dwarfs,
                        proxy_from_dwarf_weights, vector_accuracy)
from repro.core.autotune import autotune
from repro.core.metrics import REPORT_METRICS
from repro.core.workloads import WORKLOADS, workload_step_fn


def test_full_methodology_pipeline_kmeans():
    fn, args = workload_step_fn("kmeans", "tiny")
    prof = characterize(fn, args, name="kmeans", execute=True, exec_iters=1)

    weights = decompose_to_dwarfs(prof.report)
    # kmeans is dot-heavy, but the exact matrix share depends on how this
    # XLA version lowers the assignment step (newer versions emit the
    # argmin/one-hot as gathers, shifting share to the graph dwarf) — so
    # assert matrix stays a leading dwarf rather than pinning a lowering
    top2 = sorted(weights, key=weights.get, reverse=True)[:2]
    assert "matrix" in top2 and weights["matrix"] > 0.15

    proxy = WORKLOADS["kmeans"].make_proxy()
    res = autotune(proxy, prof.metrics, tol=0.15, max_iter=12)
    assert res.final_accuracy["avg"] >= res.initial_accuracy["avg"]

    pp = res.proxy.profile(execute=True, exec_iters=1)
    acc = vector_accuracy(prof.metrics, pp.metrics,
                          keys=[k for k in REPORT_METRICS
                                if k in prof.metrics and not
                                k.startswith(("mips", "flop_rate", "mem_bw"))])
    assert acc["avg"] > 0.6                  # structural match at tiny scale


def test_auto_proxy_from_decomposition_runs():
    fn, args = workload_step_fn("pagerank", "tiny")
    prof = characterize(fn, args, name="pagerank", execute=False)
    weights = decompose_to_dwarfs(prof.report)
    px = proxy_from_dwarf_weights("auto_pagerank", weights, base_size=1 << 12)
    out = jax.jit(px.dag.build())(jax.random.PRNGKey(0))
    assert np.isfinite(float(out))
