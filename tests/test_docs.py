"""Documentation contracts: the ARCHITECTURE.md Public API table locks
``repro.api.__all__``, every export is documented, and the reference
checker / snippet extractor in ``tools/check_docs.py`` find zero rot."""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from tools.check_docs import (DOC_FILES, check_references, documented_api,
                              extract_snippets)


def test_api_all_matches_documented_surface():
    import repro.api
    text = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    documented = documented_api(text)
    assert documented, "Public API table missing from ARCHITECTURE.md"
    assert sorted(set(documented)) == sorted(set(repro.api.__all__)), (
        "ARCHITECTURE.md Public API table drifted from repro.api.__all__:\n"
        f"  documented-only: {sorted(set(documented) - set(repro.api.__all__))}\n"
        f"  exported-only:   {sorted(set(repro.api.__all__) - set(documented))}")


def test_every_api_export_importable_and_documented():
    import repro.api
    for name in repro.api.__all__:
        obj = getattr(repro.api, name)      # raises on a broken export
        if callable(obj) or isinstance(obj, type):
            assert (obj.__doc__ or "").strip(), (
                f"repro.api.{name} has no docstring")


def test_docs_have_no_dangling_references():
    problems = []
    for rel in DOC_FILES:
        path = ROOT / rel
        assert path.exists(), f"{rel} missing"
        problems += check_references(path, do_import=True)
    assert not problems, "\n".join(problems)


def test_docs_snippets_exist_and_compile():
    # execution happens in CI's docs leg (tools/check_docs.py
    # --run-snippets); tier-1 keeps it cheap and just compiles them
    arch = ROOT / "docs" / "ARCHITECTURE.md"
    readme = ROOT / "README.md"
    snippets = extract_snippets(arch) + extract_snippets(readme)
    assert len(snippets) >= 3, "doc snippets went missing"
    for i, code in snippets:
        compile(code, f"snippet{i}", "exec")
