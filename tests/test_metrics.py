"""HLO cost analyzer: exactness vs XLA on straight-line code, trip-count
correction on scans, Eq.1 accuracy semantics, roofline terms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import (HW_V5E, analyze_hlo_text, eq1_accuracy,
                                metric_accuracy, metric_vector,
                                roofline_from_report, vector_accuracy)


def _analyze(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(compiled.as_text()), compiled


def _xla_cost(compiled):
    # jax < 0.5 returns a one-element list of per-device dicts
    cost = compiled.cost_analysis()
    return cost[0] if isinstance(cost, (list, tuple)) else cost


def test_dot_flops_exact():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    rep, compiled = _analyze(lambda a, b: a @ b, a, b)
    expect = 2 * 64 * 128 * 32
    assert rep.flops == expect
    xla = _xla_cost(compiled)
    assert abs(rep.flops - xla["flops"]) / expect < 0.01


def test_scan_trip_count_multiplies_flops():
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, None, length=11)
        return y

    rep, compiled = _analyze(f, x, w)
    per_iter = 2 * 8 * 64 * 64
    assert rep.flops == pytest.approx(11 * per_iter, rel=0.01)
    # XLA's own analysis counts the body once — the bug we correct
    xla = _xla_cost(compiled)
    assert xla["flops"] < rep.flops / 5
    assert rep.while_trip_counts == [11]


def test_nested_scan_trip_counts_compound():
    x = jnp.zeros((4, 16), jnp.float32)
    w = jnp.zeros((16, 16), jnp.float32)

    def inner(c, w):
        def body(c, _):
            return c @ w, ()
        c, _ = jax.lax.scan(body, c, None, length=3)
        return c

    def f(x, w):
        def body(c, _):
            return inner(c, w), ()
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    rep, _ = _analyze(f, x, w)
    per_iter = 2 * 4 * 16 * 16
    assert rep.flops == pytest.approx(15 * per_iter, rel=0.01)


def test_collective_bytes_detected():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")


def test_dynamic_slice_counts_touched_bytes_only():
    big = jnp.zeros((1 << 16, 64), jnp.float32)

    def f(x):
        def body(c, i):
            sl = jax.lax.dynamic_slice(x, (i * 8, 0), (8, 64))
            return c + sl.sum(), ()
        out, _ = jax.lax.scan(body, 0.0, jnp.arange(64))
        return out

    rep, _ = _analyze(f, big)
    # touched: 64 iterations x 8x64 rows; full operand would be 64x16MB
    assert rep.bytes_accessed < 16e6


def test_eq1_accuracy_semantics():
    assert eq1_accuracy(100.0, 100.0) == 1.0
    assert eq1_accuracy(100.0, 90.0) == pytest.approx(0.9)
    assert eq1_accuracy(100.0, 250.0) == 0.0          # clipped
    assert metric_accuracy("mix_dot", 0.5, 0.4) == pytest.approx(0.9)
    assert metric_accuracy("mix_dot", 0.001, 0.011) == pytest.approx(0.99)


def test_vector_accuracy_weighted_avg():
    t = {"flops": 100.0, "mix_dot": 0.5}
    p = {"flops": 90.0, "mix_dot": 0.5}
    acc = vector_accuracy(t, p)
    assert acc["avg"] == pytest.approx((0.9 + 1.0) / 2)


def test_roofline_terms_and_dominance():
    a = jnp.zeros((512, 512), jnp.float32)
    rep, _ = _analyze(lambda a: a @ a, a)
    roof = roofline_from_report(rep, chips=1, model_flops=2 * 512 ** 3)
    assert roof.compute_s == pytest.approx(rep.flops / HW_V5E.peak_flops_bf16)
    assert roof.memory_s == pytest.approx(rep.bytes_accessed / HW_V5E.hbm_bw)
    assert roof.dominant in ("compute", "memory", "collective")
    assert 0.0 < roof.useful_flops_ratio <= 1.05


def test_metric_vector_mix_shares_sum_to_one():
    a = jnp.zeros((128, 128), jnp.float32)
    rep, _ = _analyze(lambda a: jnp.sort(a @ a, axis=-1).sum(), a)
    vec = metric_vector(rep)
    mix = sum(v for k, v in vec.items() if k.startswith("mix_"))
    assert mix == pytest.approx(1.0, abs=1e-6)
