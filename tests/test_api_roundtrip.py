"""Versioned ProxySpec round-trip + the issue's end-to-end demo:
TeraSort proxy -> to_json -> from_json -> uniform Stack.run on openmp and
hadoop -> autotune via the pytree parameter space."""

import json

import jax
import numpy as np
import pytest

from repro.api import ParamSpace, ProxySpec, SpecError, get_stack
from repro.core import ProxyBenchmark, proxy_from_dwarf_weights
from repro.core.autotune import autotune
from repro.core.workloads import PROXY_SPECS, WORKLOADS


def _assert_same_metrics(m1, m2):
    assert set(m1) == set(m2)
    for k in m1:
        assert m1[k] == pytest.approx(m2[k], rel=1e-9), k


# ---------------------------------------------------------------------------
# spec round-trip
# ---------------------------------------------------------------------------


def test_spec_json_roundtrip_profiles_identically():
    direct = WORKLOADS["terasort"].make_proxy()
    spec = ProxySpec.from_benchmark(direct, stack="hadoop", scale="tiny")
    wire = json.dumps(spec.to_json())              # full serialize...
    back = ProxySpec.from_json(json.loads(wire))   # ...and back
    assert back.stack == "hadoop" and back.scale == "tiny"
    assert back.to_json() == spec.to_json()
    _assert_same_metrics(
        direct.profile(execute=False).metrics,
        back.to_benchmark().profile(execute=False).metrics)


def test_save_load_roundtrip_with_extra_params(tmp_path):
    pb = WORKLOADS["kmeans"].make_proxy()
    # touch an extra param so the round-trip must preserve it
    pb.dag.edges[0].params.extra["centers"] = 24
    path = str(tmp_path / "proxy_kmeans.json")
    pb.save(path, stack="spark", scale="small")
    loaded = ProxyBenchmark.load(path)
    assert loaded.dag.edges[0].params.extra["centers"] == 24
    assert loaded.description == pb.description
    assert loaded.dag.to_json() == pb.dag.to_json()
    _assert_same_metrics(pb.profile(execute=False).metrics,
                         loaded.profile(execute=False).metrics)


def test_legacy_v1_bare_dag_json_still_loads(tmp_path):
    pb = WORKLOADS["sift"].make_proxy()
    path = str(tmp_path / "legacy.json")
    with open(path, "w") as f:
        json.dump(pb.dag.to_json(), f)             # the seed's save() format
    loaded = ProxyBenchmark.load(path)
    assert loaded.dag.to_json() == pb.dag.to_json()


@pytest.mark.parametrize("mutate, at", [
    (lambda d: d.pop("sources"), "sources"),
    (lambda d: d.update(spec_version=99), "spec_version"),
    (lambda d: d["edges"][0].update(component="warp_drive"), "component"),
    (lambda d: d["edges"][0].update(src=[]), "src"),
    (lambda d: d["edges"][1].update(src=["not_a_node"]), "not yet defined"),
    (lambda d: d.update(sources={"src": -3}), "positive"),
])
def test_spec_validation_rejects_malformed(mutate, at):
    d = json.loads(json.dumps(PROXY_SPECS["terasort"]))
    mutate(d)
    with pytest.raises((SpecError, ValueError), match=at):
        ProxySpec.from_json(d)


def test_all_registered_workload_specs_are_valid():
    for name, spec_json in PROXY_SPECS.items():
        spec = ProxySpec.from_json(spec_json)
        assert spec.name == f"proxy_{name}"
        assert spec.stack in set(get_stack(s).name
                                 for s in ("openmp", "mpi", "spark", "hadoop"))


# ---------------------------------------------------------------------------
# dropped-dwarf warning (proxy_from_dwarf_weights)
# ---------------------------------------------------------------------------


def test_proxy_from_dwarf_weights_warns_on_unknown_dwarf():
    with pytest.warns(UserWarning, match="no registered components"):
        pb = proxy_from_dwarf_weights(
            "auto", {"sort": 0.5, "quantum_annealing": 0.5},
            base_size=1 << 10)
    assert "quantum_annealing" in pb.description
    assert [e for e in pb.dag.edges]               # sort edge still present


def test_proxy_from_dwarf_weights_clean_when_all_known():
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pb = proxy_from_dwarf_weights("auto", {"sort": 0.7, "graph": 0.3},
                                      base_size=1 << 10)
    assert "dropped" not in pb.description


# ---------------------------------------------------------------------------
# the acceptance demo: spec -> stacks -> pytree autotune
# ---------------------------------------------------------------------------


def test_terasort_spec_stacks_autotune_demo():
    # 1. build the TeraSort proxy and round-trip it through the spec
    direct = WORKLOADS["terasort"].make_proxy()
    wire = json.dumps(ProxySpec.from_benchmark(direct).to_json())
    spec = ProxySpec.from_json(json.loads(wire))
    proxy = spec.to_benchmark()

    # shrink via the pytree parameter space so the demo stays fast
    for pb in (direct, proxy):
        space = ParamSpace.from_dag(pb.dag)
        vec = space.values(pb.dag)
        for li, leaf in enumerate(space.leaves):
            if leaf.field == "data_size":
                vec[li] = 4096
        space.apply(pb.dag, vec)

    # the round-trip is lossless: identical DAG json and metric vector
    assert proxy.dag.to_json() == direct.dag.to_json()
    base_direct = direct.profile(execute=False).metrics
    base_rt = proxy.profile(execute=False).metrics
    _assert_same_metrics(base_direct, base_rt)

    # 2. run on at least openmp and hadoop through the uniform Stack API
    results = {}
    for stack_name in ("openmp", "hadoop"):
        rep = get_stack(stack_name).run(proxy, rng=jax.random.PRNGKey(0))
        results[stack_name] = float(np.asarray(rep.result))
        assert np.isfinite(results[stack_name])
    assert results["hadoop"] == pytest.approx(results["openmp"], rel=1e-3)

    # 3. autotune via the pytree parameter space toward a recoverable
    #    target (the same DAG re-weighted), paper-style <=15% deviation
    target_pb = proxy.clone()
    tspace = ParamSpace.from_dag(target_pb.dag)
    tvec = tspace.values(target_pb.dag)
    tvec[tspace.index_of("e2.quick_sort.weight")] = 8
    tvec[tspace.index_of("e3.merge_sort.weight")] = 1
    tspace.apply(target_pb.dag, tvec)
    target = target_pb.profile(execute=False).metrics

    res = autotune(proxy, target, tol=0.15, max_iter=8)
    # 4. no worse than the seed path's guarantee on the same metrics:
    #    tuned accuracy >= untuned, and a strong absolute match
    assert res.final_accuracy["avg"] >= res.initial_accuracy["avg"]
    assert res.final_accuracy["avg"] > 0.85
    assert res.history or res.converged
    # sensitivity table is keyed by pytree leaf names
    assert all("." in k for k in res.sensitivity)
