"""The four BigDataBench originals + Table-3 proxies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import characterize, decompose_to_dwarfs
from repro.core.workloads import (WORKLOADS, kmeans_sparse_step,
                                  kmeans_step, pagerank_step, sift_step,
                                  terasort_step, workload_step_fn)
from repro.data import gen_matrix, gen_records, gen_sparse_csr


def test_terasort_sorts_and_partitions(rng):
    keys, payload = gen_records(rng, 1 << 12)
    sk, sp, counts = jax.jit(terasort_step)(keys, payload)
    sk = np.asarray(sk)
    assert int(counts.sum()) == 1 << 12
    # keys non-decreasing *within* partitions and partition ids sorted first
    # => global lexicographic order by (pid, key); spot-check global keyness
    # per partition via counts offsets
    off = 0
    for c in np.asarray(counts):
        part = sk[off: off + c]
        assert (np.diff(part.astype(np.int64)) >= 0).all()
        off += c


def test_kmeans_inertia_decreases(rng):
    x = gen_matrix(rng, 1 << 10, 16)
    centers = gen_matrix(jax.random.fold_in(rng, 1), 8, 16)
    _, inertia = jax.jit(lambda x, c: kmeans_step(x, c, 5))(x, centers)
    inertia = np.asarray(inertia)
    assert inertia[-1] <= inertia[0]


def test_kmeans_sparse_matches_dense_semantics(rng):
    idx, vals = gen_sparse_csr(rng, 256, 16, sparsity=0.5)
    centers = gen_matrix(jax.random.fold_in(rng, 1), 4, 16)
    c2, inertia = jax.jit(lambda i, v, c: kmeans_sparse_step(i, v, c, 2))(
        idx, vals, centers)
    assert np.isfinite(np.asarray(c2)).all()


def test_pagerank_mass_conserved(rng):
    from repro.data import gen_graph
    src, dst = gen_graph(rng, 1 << 12, 1 << 8)
    rank, top, deltas = jax.jit(
        lambda s, d: pagerank_step(s, d, 1 << 8, 5))(src, dst)
    rank = np.asarray(rank)
    assert rank.min() >= 0
    # damping leaks mass at dangling nodes; stays within (0.1, 1.]
    assert 0.1 < rank.sum() <= 1.0 + 1e-3
    assert (np.diff(np.asarray(top)) <= 1e-9).all()     # top-k descending


def test_sift_outputs_finite(rng):
    from repro.data import gen_images
    imgs = gen_images(rng, 2, 32, 32)
    desc, hist, n_extrema, top = jax.jit(sift_step)(imgs)
    assert np.isfinite(np.asarray(desc)).all()
    assert np.asarray(hist).shape == (8,)
    assert float(n_extrema) > 0


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_proxy_builds_and_runs(name, rng):
    px = WORKLOADS[name].make_proxy()
    out = jax.jit(px.dag.build())(rng)
    assert np.isfinite(float(out))


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_original_characterizes_with_dwarf_decomposition(name):
    fn, args = workload_step_fn(name, "tiny")
    prof = characterize(fn, args, name=name, execute=False)
    weights = decompose_to_dwarfs(prof.report)
    assert abs(sum(weights.values()) - 1.0) < 1e-6
    paper = WORKLOADS[name].table3_weights
    # the profiler must attribute nonzero weight to at least one of the
    # paper's Table-3 dwarfs for this workload
    assert sum(weights[d] for d in paper) > 0.1
