"""AI dwarf components (core/dwarfs/ai.py) and everything wired to them:
the lm_train / lm_decode proxy specs, the decompose attribution fix, the
heterogeneous serving zero-retrace contract, the forced-XLA degrade path,
and the ``ai_fidelity_harness`` structural-insertion acceptance run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.autotune import _deviations
from repro.core.dwarfs import ComponentParams, get_component
from repro.core.dwarfs.base import REGISTRY
from repro.core.metrics import CostReport
from repro.core.profiler import decompose_to_dwarfs
from repro.core.proxy import proxy_from_dwarf_weights
from repro.core.structsearch import ai_fidelity_harness
from repro.core.workloads import PROXY_SPECS

AI_COMPONENTS = ("attention", "gemm_train", "scan_recurrent")


# ---------------------------------------------------------------------------
# component basics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", AI_COMPONENTS)
def test_ai_component_registered_and_runs(name, rng):
    comp = get_component(name)
    assert comp.pallas_capable
    assert comp.parity_tol is not None          # float kernels: tolerance,
    assert comp.dwarf in ("attention", "gemm", "recurrent")
    x = jax.random.normal(rng, (2048,), jnp.float32)
    p = ComponentParams(data_size=2048, chunk_size=128)
    out = comp(x, p, rng)
    assert out.ndim == 1 and out.size > 0
    assert np.isfinite(np.asarray(out, np.float32)).all()


@pytest.mark.parametrize("heads,kv_heads", [(4, 4), (8, 2), (6, 4), (4, 1)])
def test_attention_gqa_head_snap(heads, kv_heads, rng):
    """kv_heads snaps down to a divisor of heads — every GQA/MQA request
    yields a valid grouping instead of a reshape error."""
    comp = get_component("attention")
    S, H, kv, hd = comp._geometry(ComponentParams(
        data_size=4096, chunk_size=128,
        extra={"heads": heads, "kv_heads": kv_heads}))
    assert H == heads and H % kv == 0 and kv <= kv_heads
    x = jax.random.normal(rng, (4096,), jnp.float32)
    out = comp(x, ComponentParams(data_size=4096, chunk_size=128,
                                  extra={"heads": heads,
                                         "kv_heads": kv_heads}), rng)
    assert np.isfinite(np.asarray(out)).all()


def test_forced_xla_degrade_disables_pallas_components():
    """The circuit breaker's ``forced_backend("xla")`` must beat even a
    per-edge ``extra["backend"]="pallas"`` pin on every AI component."""
    from repro.kernels.dispatch import forced_backend
    p = ComponentParams(data_size=1024, chunk_size=64,
                        extra={"backend": "pallas"})
    for name in AI_COMPONENTS:
        comp = get_component(name)
        assert comp.uses_pallas(p), name
        with forced_backend("xla"):
            assert not comp.uses_pallas(p), name


# ---------------------------------------------------------------------------
# dwarf attribution (the lm_proxy misattribution fix)
# ---------------------------------------------------------------------------


def test_decompose_attention_not_misattributed_as_matrix():
    """An attention-dominated report decomposes into attention + gemm mass
    — not the big-data ``matrix`` dwarf — and round-trips through
    ``proxy_from_dwarf_weights`` to a DAG that actually carries an
    attention-class edge.  This is the path that silently produced
    pure-matmul proxies for every LM cell before the fix."""
    rep = CostReport(flops=1e9, attention_flops=4e8, bytes_accessed=1e8,
                    reduce_elems=1e5)
    w = decompose_to_dwarfs(rep)
    assert w["attention"] > 0.1
    assert w["gemm"] > 0.1
    assert w["matrix"] == 0.0                   # not the big-data class
    pb = proxy_from_dwarf_weights("lm_cell", w, base_size=1 << 12, chunk=128)
    dwarfs_used = {REGISTRY[e.component].dwarf for e in pb.dag.edges}
    assert "attention" in dwarfs_used
    assert "gemm" in dwarfs_used


def test_decompose_big_data_reports_unchanged():
    """No attention signal -> the original eight-dwarf attribution (the
    TeraSort/Kmeans decompositions must not move)."""
    rep = CostReport(flops=1e9, sort_elems=1e6, rng_elems=1e5)
    w = decompose_to_dwarfs(rep)
    assert w["matrix"] > 0.0
    assert w["attention"] == 0.0 and w["gemm"] == 0.0


# ---------------------------------------------------------------------------
# lm_train / lm_decode proxy specs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["lm_train", "lm_decode"])
def test_lm_proxy_spec_validates_lowers_and_runs(name):
    from repro.api import ProxySpec, get_stack
    spec = ProxySpec.from_json(PROXY_SPECS[name])
    assert spec.name == f"proxy_{name}"
    pb = spec.to_benchmark()
    comps = {e.component for e in pb.dag.edges}
    assert comps & set(AI_COMPONENTS), comps
    report = get_stack(spec.stack).run(spec)
    assert report.wall_s > 0
    assert np.isfinite(np.asarray(report.result, np.float32)).all()


def test_lm_specs_in_searchable_registry():
    """The AI proxies ride every registry-driven sweep (plan sweep,
    serving templates) — sorted(PROXY_SPECS) must include them."""
    assert {"lm_train", "lm_decode"} <= set(PROXY_SPECS)


# ---------------------------------------------------------------------------
# heterogeneous serving: big-data + lm_decode, zero steady-state retraces
# ---------------------------------------------------------------------------


def test_serve_mixed_lm_trace_zero_retraces():
    from repro.serve.engine import ServingEngine, poisson_trace
    trace = poisson_trace(n=8, rate_rps=200.0, seed=3,
                          mix=("terasort", "lm_decode"))
    eng = ServingEngine(stack="openmp", max_batch=4, bucket_size=2)
    eng.warmup(trace)
    retraces = 0
    for _ in range(2):
        rep = eng.serve(trace, clock="wall", mode="open")
        assert rep.n_requests == 8 and rep.lost_requests == 0
        retraces += rep.retraces
    assert retraces == 0


# ---------------------------------------------------------------------------
# structural acceptance: tune_structure must *insert* an attention-class
# component (mirrors the ai_structure_sweep CI gate, same harness)
# ---------------------------------------------------------------------------


def test_tune_structure_inserts_attention_class_component():
    from repro.api import tune_structure

    from repro.core.dag import Edge, ProxyDAG

    reference, detuned, pool = ai_fidelity_harness()
    size = reference.sources["tokens"]
    chunk = reference.edges[0].params.chunk_size
    # profile every pool component once so the search itself is purely
    # compositional (same warmup the ai_structure_sweep CI gate does)
    warmup = ProxyDAG(
        "ai_struct_warmup", {"tokens": size},
        [Edge(c, ["tokens"] if i == 0 else [f"w{i - 1}"], f"w{i}",
              ComponentParams(data_size=size, chunk_size=chunk))
         for i, c in enumerate(pool)], f"w{len(pool) - 1}")
    engine.measure(warmup)
    target = engine.measure(reference)
    seed_dev = max(abs(d) for d in _deviations(
        target, engine.measure(detuned),
        [k for k in target if abs(target[k]) > 1e-12]).values())
    assert seed_dev > 0.10      # the detuned seed genuinely deviates

    e0 = engine.stats()
    res = tune_structure(detuned, target, tol=0.10, max_candidates=96,
                         generations=4, components=pool, seed=0)
    e1 = engine.stats()

    attn_classes = {n for n, c in REGISTRY.items()
                    if c.dwarf in ("attention", "recurrent")}
    used = {e.component for e in res.proxy.dag.edges}
    assert used & attn_classes, res.best_lineage
    assert res.final_deviation < seed_dev        # structural improvement
    # compile-once contract: zero executable traces, zero new body compiles
    assert e1["traces"] - e0["traces"] == 0
    assert res.new_body_compiles == 0
