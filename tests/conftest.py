import os

# Tests must see the real device count (1 CPU), never the dry-run's 512
# fake devices — keep XLA_FLAGS untouched here on purpose.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
