import os

# Tests must see the real device count (1 CPU), never the dry-run's 512
# fake devices — keep XLA_FLAGS untouched here on purpose.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

try:
    from hypothesis import HealthCheck, settings

    # "ci" (HYPOTHESIS_PROFILE=ci): more examples, fixed derandomized seed,
    # no deadline — compile-heavy jax examples blow any wall-clock budget.
    settings.register_profile(
        "ci", max_examples=50, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.register_profile(
        "dev", max_examples=10, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    pass


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
