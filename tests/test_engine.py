"""Compile-once/run-many contract: fori_loop weights, the compositional
cost engine, stack executable caches, and the Pallas backend dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ParamSpace, cache_stats, get_stack
from repro.core import ProxyBenchmark, engine
from repro.core.autotune import autotune
from repro.core.dag import Edge, ProxyDAG
from repro.core.dwarfs import ComponentParams, get_component
from repro.kernels.dispatch import default_interpret, resolve_backend


def _dag(weight=2, size=4096, rounds=2):
    return ProxyDAG(
        name="engine_test",
        sources={"src": size},
        edges=[
            Edge("matrix_multiplication", ["src"], "mm",
                 ComponentParams(data_size=size, chunk_size=64,
                                 weight=weight)),
            Edge("hash", ["mm"], "out",
                 ComponentParams(data_size=size, chunk_size=256, weight=1,
                                 extra={"rounds": rounds})),
        ],
        sink="out")


def _count_eqns(jaxpr) -> int:
    n = 0
    for eq in jaxpr.eqns:
        n += 1
        for v in eq.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for vv in vs:
                if hasattr(vv, "jaxpr"):
                    n += _count_eqns(vv.jaxpr)
    return n


# ---------------------------------------------------------------------------
# fori_loop weights: graph size is O(edges), not O(sum of weights)
# ---------------------------------------------------------------------------


def test_weight_64_same_jaxpr_size_as_weight_2(rng):
    j2 = jax.make_jaxpr(_dag(weight=2).build())(rng)
    j64 = jax.make_jaxpr(_dag(weight=64).build())(rng)
    assert _count_eqns(j2.jaxpr) == _count_eqns(j64.jaxpr)


def test_parametric_build_matches_static_build(rng):
    d = _dag(weight=3)
    a = jax.jit(d.build())(rng)
    b = jax.jit(d.build_parametric())(rng, d.dynamic_params())
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_structure_key_ignores_dynamic_values():
    assert _dag(weight=2).structure_key() == _dag(weight=64).structure_key()
    assert _dag(rounds=1).structure_key() == _dag(rounds=7).structure_key()
    assert _dag(size=4096).structure_key() != _dag(size=8192).structure_key()


def test_stepping_dynamic_params_does_not_retrace(rng):
    d = _dag()
    traces = [0]
    pfn = d.build_parametric()

    def counted(r, dyn):
        traces[0] += 1
        return pfn(r, dyn)

    jfn = jax.jit(counted)
    space = ParamSpace.from_dag(d)
    vec = space.values(d)
    for li, leaf in enumerate(space.leaves):
        if not leaf.dynamic:
            continue
        for mult in (2.0, 4.0):
            vec[li] = max(vec[li], 1.0) * mult
            space.apply(d, vec)
            jfn(rng, d.dynamic_params())
    assert traces[0] == 1   # one trace total across every dynamic step


# ---------------------------------------------------------------------------
# compositional cost engine
# ---------------------------------------------------------------------------


def test_engine_metrics_match_whole_program_profile():
    d = _dag(weight=4, size=8192)
    prof = ProxyBenchmark(d).profile(execute=False).metrics
    eng = engine.measure(d)
    for k in ("arithmetic_intensity", "vpu_share", "mix_dot", "mix_sort"):
        assert eng[k] == pytest.approx(prof[k], rel=0.05, abs=0.01)


def test_engine_weight_steps_cost_zero_compiles():
    d = _dag(weight=1)
    engine.measure(d)                     # warm the per-edge caches
    before = engine.stats()
    flops = []
    for w in (2, 8, 64):
        d.edges[0].params.weight = w
        flops.append(engine.measure(d)["flops"])
    after = engine.stats()
    assert after["compiles"] == before["compiles"]
    assert after["traces"] == before["traces"]
    assert flops[1] > 3.5 * flops[0] and flops[2] > 7.0 * flops[1]


def test_engine_tracks_dynamic_extra_values():
    # the body report bakes dynamic-extra values in (hash rounds set a loop
    # trip count), so stepping `rounds` must refresh the cost — not serve
    # the stale cached report — and the tuner must see nonzero sensitivity
    d = _dag(rounds=1)
    v1 = engine.measure(d)["vpu_ops"]
    d.edges[1].params.extra["rounds"] = 64
    v64 = engine.measure(d)["vpu_ops"]
    assert v64 > 2.0 * v1


def test_structure_key_tracks_resolved_backend(monkeypatch):
    d = ProxyDAG(
        "bk", {"src": 2048},
        [Edge("top_k", ["src"], "out",
              ComponentParams(data_size=2048, chunk_size=128,
                              extra={"k": 8}))],
        "out")
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    k_xla = d.structure_key()
    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    assert d.structure_key() != k_xla


def test_engine_shape_change_recompiles_only_touched_edge():
    d = _dag(size=4096)
    engine.measure(d)
    before = engine.stats()["compiles"]
    d.edges[1].params.chunk_size = 128    # static field on one edge
    engine.measure(d)
    assert engine.stats()["compiles"] == before + 1


def test_autotune_sweep_triggers_zero_retraces_after_first_compile():
    target = engine.measure(_dag(weight=6, size=4096))
    start = ProxyBenchmark(_dag(weight=1, size=4096))
    res = autotune(start, target, tol=0.15, max_iter=8)
    assert res.profiles_run > 2
    # re-tune a same-structure proxy: the sensitivity probes and feedback
    # measurements hit the process-wide caches — dynamic-param steps never
    # compile, only adjustments that move a *shape* leaf to an unseen value
    # may (bounded by the iteration count)
    before = engine.stats()
    res2 = autotune(ProxyBenchmark(_dag(weight=2, size=4096)), target,
                    tol=0.15, max_iter=8)
    after = engine.stats()
    assert res2.profiles_run > 0
    assert after["compiles"] - before["compiles"] <= 8
    assert after["traces"] == before["traces"]   # no execution retraces at all


def test_engine_execute_adds_rate_metrics_without_retrace():
    d = _dag(weight=2)
    m = engine.measure(d, execute=True)
    assert m["mips"] > 0 and m["mem_bw"] > 0
    before = engine.stats()
    d.edges[0].params.weight = 5
    m2 = engine.measure(d, execute=True)
    after = engine.stats()
    assert after["exec_compiles"] == before["exec_compiles"]
    assert after["traces"] == before["traces"]
    assert m2["flops"] > m["flops"]


# ---------------------------------------------------------------------------
# stack executable cache
# ---------------------------------------------------------------------------


def test_stack_run_reuses_compiled_executable():
    stack = get_stack("openmp")
    d = _dag(weight=2, size=2048)
    r1 = stack.run(d, rng=jax.random.PRNGKey(0))
    t0 = cache_stats()["traces"]
    h0 = cache_stats()["hits"]
    r2 = stack.run(d, rng=jax.random.PRNGKey(0))
    assert cache_stats()["traces"] == t0          # no retrace
    assert cache_stats()["hits"] > h0             # served from cache
    assert float(np.asarray(r1.result)) == pytest.approx(
        float(np.asarray(r2.result)), rel=1e-6)


def test_stack_run_weight_step_hits_cache_shape_change_recompiles():
    stack = get_stack("openmp")
    d = _dag(weight=2, size=2048)
    stack.run(d, rng=jax.random.PRNGKey(0))
    t0 = cache_stats()["traces"]
    d.edges[0].params.weight = 9                  # dynamic step
    rep = stack.run(d, rng=jax.random.PRNGKey(0))
    assert cache_stats()["traces"] == t0
    assert np.isfinite(float(np.asarray(rep.result)))
    d.edges[0].params.data_size = 4096            # structural step
    stack.run(d, rng=jax.random.PRNGKey(0))
    assert cache_stats()["traces"] == t0 + 1


def test_run_batch_reuses_cache_across_calls_and_batches():
    stack = get_stack("openmp")
    d = _dag(weight=2, size=2048)
    rngs = jax.random.split(jax.random.PRNGKey(0), 4)
    stack.run_batch(d, rngs)
    t0 = cache_stats()["traces"]
    rep = stack.run_batch(d, rngs)
    assert cache_stats()["traces"] == t0
    assert rep.batch == 4
    d.edges[0].params.weight = 7                  # dynamic step, batched
    stack.run_batch(d, rngs)
    assert cache_stats()["traces"] == t0


def test_hadoop_staged_run_reuses_stage_compiles():
    stack = get_stack("hadoop")
    d = _dag(weight=2, size=2048)
    r1 = stack.run(d, rng=jax.random.PRNGKey(0))
    t0 = cache_stats()["traces"]
    d.edges[0].params.weight = 5
    r2 = stack.run(d, rng=jax.random.PRNGKey(0))
    assert cache_stats()["traces"] == t0          # stages cache-served
    assert r2.io_bytes > 0
    assert np.isfinite(float(np.asarray(r2.result)))
    assert float(np.asarray(r1.result)) != pytest.approx(
        float(np.asarray(r2.result)), rel=1e-9)   # weight actually applied


# ---------------------------------------------------------------------------
# dynamic leaves + backend dispatch
# ---------------------------------------------------------------------------


def test_param_space_flags_dynamic_leaves():
    space = ParamSpace.from_dag(_dag())
    dyn = set(space.dynamic_names())
    assert "e0.matrix_multiplication.weight" in dyn
    assert "e1.hash.weight" in dyn
    assert "e1.hash.rounds" in dyn
    assert "e0.matrix_multiplication.data_size" not in dyn
    assert space.is_dynamic("e1.hash.rounds")
    assert not space.is_dynamic("e1.hash.chunk_size")


def test_backend_dispatch_matches_xla(rng):
    x = jax.random.normal(rng, (2048,))
    p = ComponentParams(data_size=2048, chunk_size=128)
    for name in ("top_k", "hash", "histogram", "grouped_count"):
        comp = get_component(name)
        a = np.asarray(comp(x, p, rng))
        b = np.asarray(comp(x, p.replace(extra={"backend": "pallas"}), rng))
        np.testing.assert_allclose(a, b, rtol=1e-6, err_msg=name)


def test_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    assert resolve_backend() == "pallas"
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    assert resolve_backend() == "xla"
    monkeypatch.delenv("REPRO_BACKEND")
    # auto resolves from the platform: CPU has no Pallas lowering
    if jax.default_backend() == "cpu":
        assert resolve_backend("auto") == "xla"
    with pytest.raises(ValueError):
        resolve_backend("mosaic")


def test_interpret_autodetect_and_override(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert default_interpret("cpu") is True
    assert default_interpret("tpu") is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert default_interpret("tpu") is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret("cpu") is False


def test_pallas_backend_runs_inside_weight_loop(rng):
    # the Pallas fast path must compose with the fori_loop repeat engine
    d = ProxyDAG(
        "pallas_loop", {"src": 2048},
        [Edge("top_k", ["src"], "out",
              ComponentParams(data_size=2048, chunk_size=128, weight=3,
                              extra={"k": 8, "backend": "pallas"}))],
        "out")
    out = jax.jit(d.build())(rng)
    assert np.isfinite(float(out))
    ref = ProxyDAG(
        "xla_loop", {"src": 2048},
        [Edge("top_k", ["src"], "out",
              ComponentParams(data_size=2048, chunk_size=128, weight=3,
                              extra={"k": 8, "backend": "xla"}))],
        "out")
    assert float(out) == pytest.approx(float(jax.jit(ref.build())(rng)),
                                       rel=1e-5)
