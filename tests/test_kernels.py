"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Two layers of parity:

* raw kernels (``flash_attention`` / ``matmul``) against their references;
* the **backend-dispatch surface** — every op an edge can route through
  ``kernels.dispatch`` (``topk``, ``hash_mix``, and each
  ``pallas_capable`` dwarf component) — swept pallas-interpret vs the
  stock XLA lowering across shapes and dtypes.  Integer-kernel
  components (``parity_tol is None``) must be *bit-identical*: a tuner
  switching backend mid-sweep may never see the proxy's output move.
  Float AI components declare a ``parity_tol`` — their blocked
  accumulation order (flash attention's online softmax, the tiled
  matmul's f32 scratch) legitimately differs from the stock lowering.

The raw-kernel tests pass ``backend="pallas"`` explicitly: since the
dispatch fix, a bare call resolves through ``REPRO_BACKEND``/auto and
takes the XLA path on CPU hosts — which would silently turn these into
oracle-vs-oracle no-ops.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dwarfs import ComponentParams, get_component
from repro.core.dwarfs.base import REGISTRY
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.hash_mix import hash_mix, hash_mix_ref
from repro.kernels.matmul import matmul, matmul_ref
from repro.kernels.sort_net import sort_rows, sort_rows_ref
from repro.kernels.topk import topk, topk_ref

#: every component the dispatch layer can route to a Pallas fast path —
#: discovered from the registry so a newly dispatched edge joins the sweep
DISPATCHED_COMPONENTS = sorted(n for n, c in REGISTRY.items()
                               if c.pallas_capable)


@pytest.mark.parametrize("B,Sq,Skv,H,Kv,hd,causal", [
    (1, 128, 128, 4, 4, 32, True),
    (2, 64, 192, 8, 2, 64, True),      # GQA + rectangular
    (1, 200, 200, 4, 1, 32, True),     # MQA + non-divisible (padding)
    (2, 96, 96, 6, 3, 16, False),      # non-causal
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Skv, H, Kv, hd, causal, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Kv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64,
                          backend="pallas")
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal
                        ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 192, 64),
                                   (100, 70, 50), (8, 1024, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(M, K, N, dtype, rng):
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (M, K), dtype)
    b = jax.random.normal(k2, (K, N), dtype)
    out = matmul(a, b, block_m=64, block_n=64, block_k=64,
                 backend="pallas")
    ref = matmul_ref(a, b)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * K)


# ---------------------------------------------------------------------------
# backend-dispatch parity sweep: pallas-interpret vs XLA, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,N,k", [(64, 128, 8), (100, 40, 4), (256, 512, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_parity_pallas_vs_xla(M, N, k, dtype, rng):
    x = jax.random.normal(rng, (M, N), dtype)
    v1, i1 = topk(x, k, block_m=64, interpret=True)   # pallas (interpret)
    v2, i2 = topk_ref(x, k)                           # XLA lax.top_k
    assert (np.asarray(v1, np.float32) == np.asarray(v2, np.float32)).all()
    assert (np.asarray(i1) == np.asarray(i2)).all()


@pytest.mark.parametrize("M,N", [(16, 128), (10, 100), (64, 256),
                                 (3, 33), (300, 64), (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint32])
def test_sort_net_int_parity_vs_lax_sort(M, N, dtype, rng):
    """Bitonic network vs ``jax.lax.sort``: integer rows must be
    *bit-identical* (a sort is a permutation — no arithmetic to drift).
    Non-power-of-two row lengths exercise the pad-to-pow2 path."""
    x = jax.random.randint(rng, (M, N), -1_000_000, 1_000_000).astype(dtype)
    a = sort_rows(x, interpret=True)                  # pallas (interpret)
    b = sort_rows_ref(x)                              # XLA sort network
    assert a.dtype == x.dtype and a.shape == x.shape
    assert (np.asarray(a) == np.asarray(b)).all()


@pytest.mark.parametrize("M,N", [(16, 128), (10, 100), (5, 513)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sort_net_float_parity_vs_lax_sort(M, N, dtype, rng):
    """Float rows sort within the sort dwarfs' parity budget (the network
    only moves values, so in practice this is exact too — the tolerance
    is the *contract*, bit-equality the observed behavior)."""
    x = jax.random.normal(rng, (M, N), dtype)
    a = sort_rows(x, interpret=True)
    b = sort_rows_ref(x)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-6, atol=1e-6)
    # and each output row is a permutation of its input row
    assert (np.sort(np.asarray(x, np.float32), axis=1)
            == np.sort(np.asarray(a, np.float32), axis=1)).all()


@pytest.mark.parametrize("shape", [(1000,), (4096,), (33,)])
@pytest.mark.parametrize("rounds", [1, 3])
def test_hash_mix_parity_pallas_vs_xla(shape, rounds, rng):
    u = jax.random.bits(rng, shape, jnp.uint32)
    a = hash_mix(u, rounds=rounds, interpret=True)    # pallas (interpret)
    b = hash_mix_ref(u, rounds)                       # XLA fori_loop
    assert (np.asarray(a) == np.asarray(b)).all()


@pytest.mark.parametrize("component", DISPATCHED_COMPONENTS)
@pytest.mark.parametrize("size,chunk", [(1024, 64), (2000, 128), (4096, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatched_component_parity_pallas_vs_xla(component, size, chunk,
                                                   dtype, rng):
    """Every dwarf component with a Pallas fast path, executed through the
    same ``kernels.dispatch`` route an edge takes, must be bit-identical
    between backends (components cast to f32 internally, so bf16 inputs
    exercise the cast path)."""
    comp = get_component(component)
    x = jax.random.normal(rng, (size,), dtype)
    p = ComponentParams(data_size=size, chunk_size=chunk,
                        extra={"k": 8, "bins": 64, "groups": 32, "rounds": 2,
                               "mix_rounds": 2})
    a = comp(x, p.replace(extra={**p.extra, "backend": "xla"}), rng)
    b = comp(x, p.replace(extra={**p.extra, "backend": "pallas"}), rng)
    assert a.dtype == b.dtype
    if comp.parity_tol is None:
        assert (np.asarray(a, np.float32)
                == np.asarray(b, np.float32)).all(), component
    else:
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=comp.parity_tol,
                                   atol=comp.parity_tol,
                                   err_msg=component)


@pytest.mark.parametrize("B,Sq,Skv,H,Kv,hd", [
    (2, 64, 192, 8, 2, 64),        # GQA + rectangular
    (1, 200, 200, 4, 1, 32),       # MQA + non-divisible seq (padding path)
])
def test_flash_attention_backend_dispatch_parity(B, Sq, Skv, H, Kv, hd, rng):
    """The same call, routed to each backend via the new ``backend``
    kwarg — what the circuit breaker's forced-XLA degrade actually flips."""
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, Kv, hd), jnp.float32)
    out_p = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                            backend="pallas")
    out_x = flash_attention(q, k, v, causal=True, backend="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (100, 70, 50)])
def test_matmul_backend_dispatch_parity(M, K, N, rng):
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (M, K), jnp.float32)
    b = jax.random.normal(k2, (K, N), jnp.float32)
    out_p = matmul(a, b, block_m=64, block_n=64, block_k=64,
                   backend="pallas")
    out_x = matmul(a, b, backend="xla")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x),
                               rtol=1e-3, atol=1e-3)


def test_kernel_backend_forced_override_degrades_to_xla(rng):
    """``forced_backend("xla")`` (the PR-7 circuit breaker's degrade
    path) must win over an explicit ``backend="pallas"`` request and
    reproduce the stock XLA lowering bit-for-bit."""
    from repro.kernels.dispatch import forced_backend
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    a = jax.random.normal(ks[0], (96, 64), jnp.float32)
    b = jax.random.normal(ks[1], (64, 48), jnp.float32)
    with forced_backend("xla"):
        attn_forced = flash_attention(q, k, v, causal=True,
                                      backend="pallas")
        mm_forced = matmul(a, b, backend="pallas")
    attn_xla = flash_attention(q, k, v, causal=True, backend="xla")
    mm_xla = matmul(a, b, backend="xla")
    assert (np.asarray(attn_forced) == np.asarray(attn_xla)).all()
    assert (np.asarray(mm_forced) == np.asarray(mm_xla)).all()


def test_flash_attention_decode_shape(rng):
    """q_len=1 against a deep cache — the decode cell's access pattern."""
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 1, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 512, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=8, block_kv=128,
                          backend="pallas")
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=False
                        ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
