"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.hash_mix import hash_mix, hash_mix_ref
from repro.kernels.matmul import matmul, matmul_ref
from repro.kernels.topk import topk, topk_ref


@pytest.mark.parametrize("B,Sq,Skv,H,Kv,hd,causal", [
    (1, 128, 128, 4, 4, 32, True),
    (2, 64, 192, 8, 2, 64, True),      # GQA + rectangular
    (1, 200, 200, 4, 1, 32, True),     # MQA + non-divisible (padding)
    (2, 96, 96, 6, 3, 16, False),      # non-causal
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Skv, H, Kv, hd, causal, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Kv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal
                        ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 192, 64),
                                   (100, 70, 50), (8, 1024, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(M, K, N, dtype, rng):
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (M, K), dtype)
    b = jax.random.normal(k2, (K, N), dtype)
    out = matmul(a, b, block_m=64, block_n=64, block_k=64)
    ref = matmul_ref(a, b)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * K)


@pytest.mark.parametrize("M,N,k", [(64, 128, 8), (100, 40, 4), (256, 512, 1)])
def test_topk_matches_ref(M, N, k, rng):
    x = jax.random.normal(rng, (M, N), jnp.float32)
    v1, i1 = topk(x, k, block_m=64)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    assert (np.asarray(i1) == np.asarray(i2)).all()


@pytest.mark.parametrize("n,rounds", [(1000, 1), (4096, 3), (33, 2)])
def test_hash_mix_matches_ref(n, rounds, rng):
    u = jax.random.bits(rng, (n,), jnp.uint32)
    a = hash_mix(u, rounds=rounds)
    b = hash_mix_ref(u, rounds)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_flash_attention_decode_shape(rng):
    """q_len=1 against a deep cache — the decode cell's access pattern."""
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 1, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 512, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=8, block_kv=128)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=False
                        ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
