"""DAG proxy engine + auto-tuner behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ComponentParams, Edge, ProxyBenchmark, ProxyDAG,
                        autotune, proxy_from_dwarf_weights, vector_accuracy)


def _mini_dag(weight=2, size=4096):
    return ProxyDAG(
        name="mini",
        sources={"src": size},
        edges=[
            Edge("matrix_multiplication", ["src"], "mm",
                 ComponentParams(data_size=size, chunk_size=64, weight=weight)),
            Edge("quick_sort", ["mm"], "out",
                 ComponentParams(data_size=size, chunk_size=256, weight=1)),
        ],
        sink="out")


def test_dag_builds_and_runs(rng):
    fn = _mini_dag().build()
    out = jax.jit(fn)(rng)
    assert np.isfinite(float(out))


def test_dag_validates_topology():
    bad = ProxyDAG("bad", {"src": 128},
                   [Edge("quick_sort", ["missing"], "out",
                         ComponentParams())], "out")
    with pytest.raises(ValueError):
        bad.build()


def test_weight_zero_edge_is_identity_passthrough(rng):
    d1 = _mini_dag(weight=0)
    d2 = _mini_dag(weight=2)
    p1 = ProxyBenchmark(d1).profile(execute=False)
    p2 = ProxyBenchmark(d2).profile(execute=False)
    assert p1.report.flops < p2.report.flops


def test_weight_scales_cost(rng):
    f1 = ProxyBenchmark(_mini_dag(weight=1)).profile(execute=False)
    f4 = ProxyBenchmark(_mini_dag(weight=4)).profile(execute=False)
    assert f4.report.flops > 2.5 * f1.report.flops


def test_param_space_includes_extras():
    dag = ProxyDAG("x", {"src": 256},
                   [Edge("euclidean_distance", ["src"], "o",
                         ComponentParams(extra={"centers": 8}))], "o")
    fields = {f for _, f in dag.param_space()}
    assert {"data_size", "chunk_size", "parallelism", "weight",
            "centers"} <= fields


def test_proxy_from_dwarf_weights_structure():
    px = proxy_from_dwarf_weights("auto", {"sort": 0.7, "sampling": 0.1,
                                           "graph": 0.2})
    dwarfs = [e.component for e in px.dag.edges]
    assert len(dwarfs) == 3
    # heaviest dwarf gets the largest repeat weight
    weights = {e.component: e.params.weight for e in px.dag.edges}
    assert max(weights.values()) == weights[px.dag.edges[0].component]


def test_autotune_converges_to_known_target(rng):
    # target = a proxy with different parameters; the tuner must recover a
    # metric match within tolerance (paper: adjust/feedback to <=15% dev)
    target_dag = _mini_dag(weight=4, size=16384)
    target = ProxyBenchmark(target_dag).profile(execute=False).metrics
    start = ProxyBenchmark(_mini_dag(weight=1, size=4096))
    res = autotune(start, target, tol=0.15, max_iter=15)
    assert res.final_accuracy["avg"] > res.initial_accuracy["avg"]
    assert res.final_accuracy["avg"] > 0.85
    assert res.profiles_run > 5
    assert res.history  # adjust/feedback steps recorded


def test_autotune_summary_readable():
    target_dag = _mini_dag(weight=2)
    target = ProxyBenchmark(target_dag).profile(execute=False).metrics
    res = autotune(ProxyBenchmark(_mini_dag(weight=1)), target, max_iter=3)
    s = res.summary()
    assert "autotune[mini]" in s
