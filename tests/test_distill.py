"""Proxy distillation: fingerprint determinism + round-trip, distilled-
vs-hand tuning parity, and subsetting coverage invariants."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (WorkloadFingerprint, fingerprint, get_stack,
                       subset_fingerprints, tune_structure)
from repro.api.spec import ProxySpec, SpecError
from repro.core import engine
from repro.core.autotune import coerce_target
from repro.core.subset import SubsetReport, normalize_fingerprints
from repro.core.workloads import (PROXY_SPECS, proxy_fingerprint,
                                  seed_components, workload_fingerprint)


def _dag(name):
    return ProxySpec.from_json(PROXY_SPECS[name]).to_dag()


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


def _fingerprint_digest() -> str:
    """Channel vectors of one fn-measured and every dag-measured proxy —
    the cross-process determinism witness."""
    rows = {n: proxy_fingerprint(n).channels for n in sorted(PROXY_SPECS)}
    rows["kmeans_fn"] = workload_fingerprint("kmeans", "tiny").channels
    return json.dumps({k: [repr(c) for c in v] for k, v in rows.items()},
                      sort_keys=True)


def test_fingerprint_deterministic_in_process():
    assert _fingerprint_digest() == _fingerprint_digest()


def test_fingerprint_deterministic_across_processes():
    want = _fingerprint_digest()
    code = ("import sys, tests.test_distill as t;"
            "sys.stdout.write(t._fingerprint_digest())")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"), env.get("PYTHONPATH", "")])
    env.setdefault("JAX_PLATFORMS", "cpu")
    got = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, check=True).stdout
    assert got == want


def test_fingerprint_matches_measure_exactly():
    # the lossless-basis contract: metrics() reproduces engine.measure
    for name in sorted(PROXY_SPECS):
        dag = _dag(name)
        assert fingerprint(dag, name=name).metrics() == engine.measure(dag)


# ---------------------------------------------------------------------------
# sources: fn / run / serve
# ---------------------------------------------------------------------------


def test_fingerprint_from_fn_and_profile_agree():
    from repro.core import characterize
    from repro.core.workloads import workload_step_fn
    fn, args = workload_step_fn("kmeans", "tiny")
    fp_fn = fingerprint(fn, *args, name="kmeans")
    fp_prof = fingerprint(characterize(fn, args, name="kmeans",
                                       execute=False))
    assert fp_fn.channels == fp_prof.channels
    assert fp_fn.source == "fn" and fp_prof.source == "report"


def test_fingerprint_from_run_report():
    spec = ProxySpec.from_json(PROXY_SPECS["kmeans"])
    rep = get_stack("openmp").run(spec)
    fp = fingerprint(rep)
    assert fp.source == "run"
    assert fp.host_bytes == rep.io_bytes
    np.testing.assert_allclose(fp.vector(), fingerprint(spec).vector())
    # raw-callable runs carry no DAG and must fail loudly
    raw = get_stack("openmp").run(lambda rng: rng.sum(),
                                  rng=__import__("jax").random.PRNGKey(0))
    with pytest.raises(ValueError, match="no attached DAG"):
        fingerprint(raw)


def test_fingerprint_from_serve_report():
    from repro.api import serve
    from repro.serve import poisson_trace
    trace = poisson_trace(n=6, rate_rps=100.0, seed=0,
                          mix=["terasort", "kmeans"])
    report = serve(trace, clock="virtual")
    fp = fingerprint(report)
    assert fp.source == "serve"
    expect = sum(
        c * fingerprint(report.templates[s]).vector()
        for s, c in report.structure_mix.items())
    np.testing.assert_allclose(fp.vector(), expect)
    # the mix itself serializes with the report
    assert report.to_json()["structure_mix"] == report.structure_mix


# ---------------------------------------------------------------------------
# JSON round-trip + schema
# ---------------------------------------------------------------------------


def test_fingerprint_json_round_trip():
    fp = proxy_fingerprint("terasort")
    d = json.loads(json.dumps(fp.to_json()))
    fp2 = WorkloadFingerprint.from_json(d)
    assert fp2.channels == fp.channels
    assert fp2.name == fp.name
    assert fp2.metrics() == fp.metrics()
    assert fp2.source == "json"


def test_fingerprint_json_validation_errors():
    good = proxy_fingerprint("kmeans").to_json()
    for mutate, match in [
            (lambda d: d.pop("fingerprint_version"), "fingerprint_version"),
            (lambda d: d.update(fingerprint_version=99), "newer than"),
            (lambda d: d.update(name=""), "name"),
            (lambda d: d["channels"].pop("flops"), "flops"),
            (lambda d: d["channels"].update(bogus=1.0), "bogus"),
            (lambda d: d.update(host_bytes=-1), "host_bytes"),
    ]:
        d = json.loads(json.dumps(good))
        mutate(d)
        with pytest.raises(SpecError, match=match):
            WorkloadFingerprint.from_json(d)


def test_coerce_target_accepts_fingerprint_and_dict():
    fp = proxy_fingerprint("sift")
    assert coerce_target(fp) == fp.metrics()
    assert coerce_target({"mix_sort": 0.5}) == {"mix_sort": 0.5}
    with pytest.raises(TypeError, match="metrics"):
        coerce_target(object())


# ---------------------------------------------------------------------------
# distillation: measured target matches the hand-declared run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["terasort", "kmeans", "lm_decode"])
def test_distilled_deviation_matches_hand_target(name):
    # 2 big-data + 1 AI proxy: tuning against the measured fingerprint
    # must do at least as well as tuning against the hand metric dict,
    # and the deterministic replay must be free (0 traces / 0 compiles)
    spec = ProxySpec.from_json(PROXY_SPECS[name])
    dag = spec.to_dag()
    hand = engine.measure(dag)
    fp = fingerprint(dag, name=name)

    def detuned():
        bench = spec.to_benchmark()
        for e in bench.dag.edges:
            e.params.extra["weight"] = 1.0
        return bench

    kw = dict(tol=0.10, max_candidates=32, generations=2,
              structure_population=4, mutations_per_parent=2,
              components=seed_components(), seed=0)
    r_hand = tune_structure(detuned(), hand, **kw)
    s0 = engine.stats()
    r_fp = tune_structure(detuned(), fp, **kw)
    s1 = engine.stats()
    assert r_fp.final_deviation <= r_hand.final_deviation + 1e-9
    assert s1["traces"] - s0["traces"] == 0
    assert r_fp.new_body_compiles == 0


# ---------------------------------------------------------------------------
# subsetting
# ---------------------------------------------------------------------------


def _suite():
    return [proxy_fingerprint(n) for n in sorted(PROXY_SPECS)]


def test_subset_members_within_cluster_bound():
    report = subset_fingerprints(_suite(), k=3)
    assert sorted(report.clusters) == report.representatives
    covered = set()
    for rep, members in report.clusters.items():
        assert rep in members
        for m in members:
            assert report.distances[m] <= report.max_distance[rep] + 1e-12
        covered.update(members)
    assert covered == set(report.names)
    assert report.coverage == max(report.max_distance.values())
    assert report.compression_x == pytest.approx(len(report.names) / 3)


def test_subset_singleton_clusters_survive():
    fps = _suite()
    report = subset_fingerprints(fps, k=len(fps))
    assert len(report.representatives) == len(fps)
    assert report.coverage == 0.0
    assert all(len(m) == 1 for m in report.clusters.values())


def test_subset_bound_growth_meets_coverage():
    fps = _suite()
    tight = subset_fingerprints(fps, max_distance=0.0)
    assert len(tight.representatives) == len(fps)
    loose = subset_fingerprints(fps, max_distance=1e9)
    assert len(loose.representatives) == 1


def test_subset_deterministic_and_round_trips():
    a = subset_fingerprints(_suite(), k=3, seed=7)
    b = subset_fingerprints(_suite(), k=3, seed=7)
    assert a.to_json() == b.to_json()
    back = SubsetReport.from_json(json.loads(json.dumps(a.to_json())))
    assert back.to_json() == a.to_json()


def test_subset_rejects_duplicates_and_bad_k():
    fps = _suite()
    with pytest.raises(ValueError, match="unique"):
        subset_fingerprints(fps + [fps[0]])
    with pytest.raises(ValueError, match="k must be"):
        subset_fingerprints(fps, k=0)
    with pytest.raises(ValueError, match="at least one"):
        normalize_fingerprints([])
