"""Serving-engine contract: deterministic traces and reports, the
zero-retrace steady state after warmup, micro-batched bit-identity
against sequential ``Stack.run``, and pool eviction under pressure."""

import copy

import numpy as np
import pytest

from repro.api.stack import OpenMPStack, cache_stats
from repro.core.pool import get_pool, pool_stats
from repro.serve.engine import (ArrivalTrace, ServingEngine, burst_trace,
                                poisson_trace, serve)

MIX = ("terasort", "kmeans")


@pytest.fixture(scope="module")
def trace():
    return poisson_trace(n=8, rate_rps=200.0, seed=11, mix=MIX)


@pytest.fixture(scope="module")
def engine(trace):
    eng = ServingEngine(stack="openmp", max_batch=4, bucket_size=2)
    eng.warmup(trace)
    return eng


def test_trace_is_deterministic_and_mixed(trace):
    again = poisson_trace(n=8, rate_rps=200.0, seed=11, mix=MIX)
    assert [r.arrival_s for r in again] == [r.arrival_s for r in trace]
    assert [r.structure for r in again] == [r.structure for r in trace]
    assert trace.structures == sorted(set(MIX))
    assert len(trace.unique_dags()) == 2
    arr = [r.arrival_s for r in trace]
    assert arr == sorted(arr) and arr[0] > 0.0
    # per-request dynamic params actually vary within a structure
    by_s = {}
    for r in trace:
        by_s.setdefault(r.structure, []).append(r)
    for rs in by_s.values():
        if len(rs) > 1:
            a = np.concatenate([np.ravel(v) for d in rs[0].dyn
                                for v in d.values()])
            b = np.concatenate([np.ravel(v) for d in rs[1].dyn
                                for v in d.values()])
            assert not np.array_equal(a, b)


def test_burst_trace_capacity_mode():
    tr = burst_trace(n=6, bursts=1, seed=0, mix=MIX)
    assert all(r.arrival_s == 0.0 for r in tr)
    tr4 = burst_trace(n=8, bursts=4, period_s=0.01, seed=0, mix=MIX)
    assert sorted(set(r.arrival_s for r in tr4)) == [0.0, 0.01, 0.02, 0.03]


def test_virtual_clock_reports_are_identical_across_runs(engine, trace):
    a = engine.serve(trace, clock="virtual", mode="open")
    b = engine.serve(trace, clock="virtual", mode="open")
    assert a.latency_s == b.latency_s
    assert a.queue_wait_s == b.queue_wait_s
    assert a.service_s == b.service_s
    assert a.throughput_rps == b.throughput_rps
    assert a.makespan_s == b.makespan_s
    assert a.batch_hist == b.batch_hist
    assert a.retraces == 0 and a.cold_dispatches == 0
    assert a.n_requests == len(trace) and a.structures == 2
    # percentile ordering sanity
    for d in (a.latency_s, a.queue_wait_s, a.service_s):
        assert d["p50"] <= d["p95"] <= d["p99"] <= d["max"]


def test_zero_retraces_after_warmup(engine, trace):
    first = engine.serve(trace, clock="wall", mode="open")
    second = engine.serve(trace, clock="wall", mode="open")
    for rep in (first, second):
        assert rep.retraces == 0
        assert rep.cold_dispatches == 0
        assert rep.compile_s == 0.0
        assert rep.n_requests == len(trace)
        assert rep.throughput_rps > 0
        assert rep.time_to_first_result_s > 0
        assert sum(k * v for k, v in rep.batch_hist.items()) >= len(trace)


def test_microbatched_results_match_sequential_stack_run(engine, trace):
    rep = engine.serve(trace, clock="wall", mode="open")
    assert all(r is not None for r in rep.results)
    stack = OpenMPStack()
    for req, got in zip(trace, rep.results):
        clone = copy.deepcopy(req.dag)
        for edge, dyn in zip(clone.edges, req.dyn):
            for field, v in dyn.items():
                if field == "weight":
                    edge.params.weight = float(v)
                else:
                    edge.params.extra[field] = float(v)
        want = stack.run(clone, rng=req.rng).result
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_closed_loop_serves_one_request_per_dispatch(engine, trace):
    rep = engine.serve(trace, clock="virtual", mode="closed")
    assert rep.mode == "closed"
    assert rep.batch_hist == {1: len(trace)}
    assert rep.queue_wait_s["max"] == 0.0


def test_convenience_serve_and_report_json():
    tr = poisson_trace(n=4, rate_rps=500.0, seed=2, mix=("terasort",))
    rep = serve(tr, stack="openmp", clock="wall", mode="open",
                max_batch=2, bucket_size=2)
    assert rep.retraces == 0           # serve() warms up by default
    d = rep.to_json()
    assert "results" not in d
    assert set(d["latency_s"]) == {"p50", "p95", "p99", "mean", "max"}
    assert d["resources"]["samples"] >= 1


def test_eviction_under_cache_pressure(monkeypatch, trace):
    monkeypatch.setenv("REPRO_EXEC_CACHE_CAP", "1")
    stack = OpenMPStack()              # fresh instance: its own pool domain
    eng = ServingEngine(stack=stack, max_batch=4, bucket_size=2)
    rep = eng.serve(trace, clock="wall", mode="open")
    dom = stack.exec_domain()
    assert rep.n_requests == len(trace)
    # two alternating structures under a one-executable cap must churn
    assert len(dom.cache) <= 1
    assert dom.stats["evictions"] > 0
    assert rep.cold_dispatches > 0


def test_pool_cost_eviction_prefers_cheapest(monkeypatch):
    """Under pool-cap pressure the default policy evicts the executable
    that is cheapest to recompile (plan cost model), not the oldest;
    ``REPRO_POOL_POLICY=fifo`` restores the legacy order.  Artifacts
    admitted without a cost count as 0.0 — the preferred victims."""
    from repro.core.pool import ExecutablePool
    monkeypatch.delenv("REPRO_POOL_POLICY", raising=False)
    pool = ExecutablePool(cap=2)
    dom = pool.register("t:cost")
    pool.put(dom, "expensive", object(), cost=100.0)
    pool.put(dom, "cheap", object(), cost=1.0)
    pool.put(dom, "mid", object(), cost=10.0)   # over cap -> evict cheapest
    assert set(dom.cache) == {"expensive", "mid"}
    st = pool.stats()
    assert st["pool_policy"] == "cost"
    assert st["evictions_by_policy"]["pool_cost"] == 1
    pool.put(dom, "uncosted", object())          # no cost -> 0.0 -> victim
    pool.put(dom, "pricey", object(), cost=50.0)
    assert set(dom.cache) == {"expensive", "pricey"}
    monkeypatch.setenv("REPRO_POOL_POLICY", "fifo")
    pool.put(dom, "late", object(), cost=0.5)    # fifo -> evict oldest
    assert set(dom.cache) == {"pricey", "late"}
    assert pool.stats()["evictions_by_policy"]["pool_fifo"] == 1


def test_stats_surfaces_expose_hit_rate(engine, trace):
    engine.serve(trace, clock="wall", mode="open")
    cs = cache_stats()
    assert 0.0 <= cs["hit_rate"] <= 1.0
    ps = pool_stats()
    assert ps is get_pool().stats() or ps == get_pool().stats()
    doms = ps["domains"]
    assert any(name.startswith("stack:openmp") for name in doms)
    assert "plans" in doms and "engine:body" in doms
    for d in doms.values():
        assert d["size"] >= 0 and 0.0 <= d["hit_rate"] <= 1.0
    assert ps["executables"] == sum(d["size"] for d in doms.values()
                                    if d["kind"] == "executable")
    assert ps["hits"] == sum(d["hits"] for d in doms.values())
