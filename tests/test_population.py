"""Population evaluation: vmapped candidate batches must be bit-identical
to sequential per-candidate execution, compile like a single candidate,
and round-trip through the ParamSpace stack/unstack helpers."""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # property test skips; deterministic tests run
    HAVE_HYPOTHESIS = False

from repro.api import ParamSpace, cache_stats, get_stack
from repro.core import PopulationTuner, engine
from repro.core.dag import Edge, ProxyDAG
from repro.core.dwarfs import ComponentParams
from repro.core.dwarfs.base import REGISTRY
from repro.core.proxy import ProxyBenchmark

POP = 3          # fixed per-example population (one compile per component)
SIZE = 1024

#: per-component extras that must exist for the dynamic tunables to appear
#: as ParamSpace leaves (apply() defaults don't create leaves)
_SEED_EXTRAS = {
    "hash": {"rounds": 2},
    "encryption": {"rounds": 2},
    "histogram": {"mix_rounds": 1},
    "grouped_count": {"mix_rounds": 1},
    "top_k": {"k": 8},
}

_CACHE = {}


def _component_fixture(component):
    """(dag, space, base vector), built once per component so hypothesis
    examples share one compiled structure."""
    if component not in _CACHE:
        dag = ProxyDAG(
            f"pop_{component}", {"src": SIZE},
            [Edge(component, ["src"], "out",
                  ComponentParams(data_size=SIZE, chunk_size=64, weight=1,
                                  extra=dict(_SEED_EXTRAS.get(component,
                                                              {}))))],
            "out")
        space = ParamSpace.from_dag(dag)
        _CACHE[component] = (dag, space, space.values(dag))
    return _CACHE[component]


def _candidate_matrix(space, base, weights, extras):
    rows = np.tile(base, (len(weights), 1))
    for i, w in enumerate(weights):
        for leaf_i, leaf in enumerate(space.leaves):
            if not leaf.dynamic:
                continue
            rows[i, leaf_i] = w if leaf.field == "weight" else extras[i]
    return rows


# ---------------------------------------------------------------------------
# property: vmapped == sequential, bit-identical, for every dwarf component
# ---------------------------------------------------------------------------


def _assert_population_matches_sequential(component, weights, extras):
    dag, space, base = _component_fixture(component)
    matrix = _candidate_matrix(space, base, weights, extras)
    stack = get_stack("openmp")
    pop = np.asarray(
        stack.run_population(dag, matrix, space=space).result)
    for i in range(POP):
        trial = ProxyBenchmark(dag).clone()
        space.apply(trial.dag, matrix[i])
        single = np.asarray(stack.run(trial, rng=jax.random.PRNGKey(0)).result)
        assert pop[i] == single, (
            f"{component}: candidate {i} (weight={weights[i]}, "
            f"extra={extras[i]}) vmapped {pop[i]!r} != sequential {single!r}")


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("component", sorted(REGISTRY))
    @given(data=st.data())
    def test_vmapped_population_matches_sequential(component, data):
        weights = data.draw(st.lists(st.integers(0, 5), min_size=POP,
                                     max_size=POP), label="weights")
        extras = data.draw(st.lists(st.integers(1, 4), min_size=POP,
                                    max_size=POP), label="extras")
        _assert_population_matches_sequential(component, weights, extras)


#: one representative per dwarf family plus every dynamic-extra component —
#: the deterministic tier-1 subset of the hypothesis sweep above
_FAMILY_SUBSET = sorted({
    "matrix_multiplication", "monte_carlo", "hash", "encryption", "fft",
    "jaccard", "graph_traversal", "quick_sort", "top_k", "histogram",
    "grouped_count", "count_average",
})


@pytest.mark.parametrize("component", _FAMILY_SUBSET)
def test_vmapped_population_matches_sequential_fixed(component):
    _assert_population_matches_sequential(component, weights=[0, 2, 5],
                                          extras=[1, 3, 2])


@pytest.mark.parametrize("stack_name", ["mpi", "spark", "hadoop"])
def test_population_matches_sequential_on_distributed_stacks(stack_name):
    dag = ProxyDAG(
        "pop_stacks", {"src": 2048},
        [Edge("quick_sort", ["src"], "mid",
              ComponentParams(data_size=2048, chunk_size=128, weight=2)),
         Edge("hash", ["mid"], "out",
              ComponentParams(data_size=2048, chunk_size=256, weight=1,
                              extra={"rounds": 2}))],
        "out")
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(4, space.values(dag), seed=11)
    stack = get_stack(stack_name)
    rep = stack.run_population(dag, matrix, rng=jax.random.PRNGKey(0))
    assert rep.batch == 4
    pop = np.asarray(rep.result)
    for i in range(4):
        trial = ProxyBenchmark(dag).clone()
        space.apply(trial.dag, matrix[i])
        single = np.asarray(stack.run(trial, rng=jax.random.PRNGKey(0)).result)
        assert pop[i] == single
    if stack_name == "hadoop":
        assert rep.io_bytes > 0


# ---------------------------------------------------------------------------
# compile-once contract: a 16-candidate population costs one executable
# ---------------------------------------------------------------------------


def _sweep_dag():
    return ProxyDAG(
        "pop_sweep", {"src": 2048},
        [Edge("matrix_multiplication", ["src"], "mm",
              ComponentParams(data_size=2048, chunk_size=64, weight=2)),
         Edge("top_k", ["mm"], "out",
              ComponentParams(data_size=2048, chunk_size=128, weight=1,
                              extra={"k": 8}))],
        "out")


def test_16_candidate_population_compiles_at_most_one_executable():
    stack = get_stack("openmp")
    dag = _sweep_dag()
    space = ParamSpace.from_dag(dag)
    base = space.values(dag)

    m0 = cache_stats()["misses"]
    stack.run(dag, rng=jax.random.PRNGKey(0))
    single_compiles = cache_stats()["misses"] - m0

    m1 = cache_stats()["misses"]
    stack.run_population(dag, space.sample_dynamic(16, base, seed=0))
    pop_compiles = cache_stats()["misses"] - m1
    assert pop_compiles <= max(single_compiles, 1)

    # the population sweep itself: new candidate batches, zero retraces
    t0, m2 = cache_stats()["traces"], cache_stats()["misses"]
    for seed in (1, 2, 3):
        rep = stack.run_population(dag, space.sample_dynamic(16, base,
                                                             seed=seed))
        assert rep.batch == 16
        assert np.asarray(rep.result).shape == (16,)
    assert cache_stats()["traces"] == t0
    assert cache_stats()["misses"] == m2


def test_population_size_change_reuses_the_bucket_executable():
    # executables are keyed on (plan, bucket size), not population size:
    # growing or shrinking the population re-buckets onto the same
    # compiled program — only an explicit bucket-size change compiles
    stack = get_stack("openmp")
    dag = _sweep_dag()
    space = ParamSpace.from_dag(dag)
    base = space.values(dag)
    stack.run_population(dag, space.sample_dynamic(8, base, seed=0))
    t0 = cache_stats()["traces"]
    stack.run_population(dag, space.sample_dynamic(8, base, seed=1))
    assert cache_stats()["traces"] == t0          # same schedule: cache hit
    stack.run_population(dag, space.sample_dynamic(4, base, seed=1))
    assert cache_stats()["traces"] == t0          # same bucket size: hit
    stack.run_population(dag, space.sample_dynamic(8, base, seed=2),
                         bucket_size=8)
    assert cache_stats()["traces"] == t0 + 1      # new bucket size: compile


# ---------------------------------------------------------------------------
# stack/unstack helpers
# ---------------------------------------------------------------------------


def test_build_population_equals_parametric_per_candidate(rng):
    dag = _sweep_dag()
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(4, space.values(dag), seed=2)
    batched = space.stack_candidates(dag, matrix)
    pop = np.asarray(jax.jit(dag.build_population())(rng, batched))
    pfn = jax.jit(dag.build_parametric())
    for i, dyn in enumerate(space.unstack_candidates(batched)):
        assert pop[i] == np.asarray(pfn(rng, dyn))


def test_stack_candidates_roundtrips_through_unstack():
    dag = _sweep_dag()
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(5, space.values(dag), seed=3)
    batched = space.stack_candidates(dag, matrix)
    singles = space.unstack_candidates(batched)
    assert len(singles) == 5
    for i, dyn in enumerate(singles):
        trial = ProxyBenchmark(dag).clone()
        space.apply(trial.dag, matrix[i])
        expect = trial.dag.dynamic_params()
        assert jax.tree.structure(dyn) == jax.tree.structure(expect)
        for got, want in zip(jax.tree.leaves(dyn), jax.tree.leaves(expect)):
            assert got.dtype == want.dtype
            assert np.asarray(got) == np.asarray(want)


def test_stack_candidates_rejects_static_drift():
    dag = _sweep_dag()
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(3, space.values(dag), seed=0)
    matrix[1, space.index_of("e0.matrix_multiplication.data_size")] *= 2
    with pytest.raises(ValueError, match="static"):
        space.stack_candidates(dag, matrix)
    with pytest.raises(ValueError, match="static"):
        engine.measure_population(dag, space, matrix)


# ---------------------------------------------------------------------------
# population scorer: vectorized metrics == sequential engine.measure
# ---------------------------------------------------------------------------


def test_measure_population_matches_sequential_measure():
    dag = _sweep_dag()
    space = ParamSpace.from_dag(dag)
    matrix = space.sample_dynamic(8, space.values(dag), seed=5)
    engine.measure(dag)                         # warm the per-edge caches
    t0 = engine.stats()["traces"]
    pop = engine.measure_population(dag, space, matrix)
    assert engine.stats()["traces"] == t0       # scoring never executes
    for i in range(8):
        trial = ProxyBenchmark(dag).clone()
        space.apply(trial.dag, matrix[i])
        seq = engine.measure(trial.dag)
        for k, v in seq.items():
            assert pop[i][k] == pytest.approx(v, rel=1e-9, abs=1e-12), (
                f"candidate {i} metric {k}")


def test_tuner_slot_allocation_is_exact_even_for_zero_mass():
    tuner = PopulationTuner({"mix_sort": 1.0}, population=12)
    # proportional split sums exactly to the slot count
    counts = tuner._slot_allocation(np.array([0.5, 0.3, 0.2]), 10)
    assert counts.sum() == 10 and (counts > 0).all()
    # zero-mass population (every weight evolved to 0): round-robin, no
    # broadcast crash in _evolve
    counts = tuner._slot_allocation(np.zeros(3), 10)
    assert counts.sum() == 10


def test_tuner_evolves_zero_weight_population_without_crashing():
    dag = _sweep_dag()
    target = engine.measure(dag)
    tuner = PopulationTuner(target, population=6, generations=2, seed=0,
                            execute=False)
    from repro.api import ParamSpace
    space = ParamSpace.from_dag(dag)
    tuner._space, tuner._dyn_mask = space, space.dynamic_mask()
    tuner._base = space.values(dag)
    tuner._scorer = engine.PopulationScorer(dag, space)
    matrix = np.tile(tuner._base, (6, 1))
    matrix[:, tuner._dyn_mask] = 0.0            # all weights pruned
    out = tuner._evolve(matrix, np.zeros(6), gen=1)
    assert out.shape == matrix.shape


def test_tuner_search_buckets_hold_multiple_candidates():
    # search stratification must not collapse to the per-device execution
    # bucket size (1 on CPU): singleton "elites" would make the evolution
    # accuracy-blind
    tuner = PopulationTuner({"mix_sort": 1.0}, population=16)
    assert tuner._search_bucket_size(16) >= 2


def test_population_tuner_runs_generations_deterministically():
    dag = _sweep_dag()
    target = engine.measure(dag)
    start = ProxyBenchmark(_sweep_dag())
    start.dag.edges[0].params.weight = 8        # detune a dynamic leaf
    kw = dict(tol=1e-9, population=6, generations=3, seed=42, execute=False)
    res1 = PopulationTuner(target, **kw).tune(start)
    res2 = PopulationTuner(target, **kw).tune(start)
    assert res1.generations == res2.generations
    assert res1.candidates_evaluated == res2.candidates_evaluated <= 18
    assert res1.final_accuracy["avg"] == res2.final_accuracy["avg"]
    assert res1.final_accuracy["avg"] >= res1.initial_accuracy["avg"]
