"""Stack-protocol conformance: every registered stack runs the same tiny
DAG through the uniform ``Stack.run()`` API, produces the same result
(within tolerance), and reports well-formed ``RunReport`` fields."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (HadoopStack, ProxySpec, RunReport, get_stack,
                       list_stacks)
from repro.core.dag import Edge, ProxyDAG
from repro.core.dwarfs import ComponentParams


def _tiny_dag() -> ProxyDAG:
    mk = lambda w, **kw: ComponentParams(data_size=2048, chunk_size=64,
                                         parallelism=1, weight=w, extra=kw)
    return ProxyDAG(
        name="tiny",
        sources={"src": 2048},
        edges=[
            Edge("quick_sort", ["src"], "a", mk(1)),
            Edge("euclidean_distance", ["a"], "b", mk(2, centers=8)),
            Edge("histogram", ["a", "b"], "out", mk(1, bins=8)),
        ],
        sink="out")


@pytest.fixture(scope="module")
def reference():
    rep = get_stack("openmp").run(_tiny_dag(), rng=jax.random.PRNGKey(0))
    return float(np.asarray(rep.result))


def test_registry_has_all_four_stacks():
    assert {"openmp", "mpi", "spark", "hadoop"} <= set(list_stacks())


def test_get_stack_unknown_raises():
    with pytest.raises(KeyError, match="unknown stack"):
        get_stack("slurm")


@pytest.mark.parametrize("name", sorted({"openmp", "mpi", "spark", "hadoop"}))
def test_stack_conformance(name, reference):
    rep = get_stack(name).run(_tiny_dag(), rng=jax.random.PRNGKey(0))
    # well-formed report
    assert isinstance(rep, RunReport)
    assert rep.stack == name
    assert rep.wall_s > 0.0
    assert rep.io_bytes >= 0.0
    assert rep.batch == 1
    assert rep.result_bytes > 0.0
    assert rep.throughput > 0.0
    j = rep.to_json()
    assert j["stack"] == name and "result" not in j
    # identical result across stacks (tolerance: fusion differences only)
    val = float(np.asarray(rep.result))
    assert np.isfinite(val)
    assert val == pytest.approx(reference, rel=1e-3)


def test_hadoop_counts_host_spill_io(reference):
    rep = get_stack("hadoop").run(_tiny_dag(), rng=jax.random.PRNGKey(0))
    # every intermediate node materializes through host memory
    assert rep.io_bytes > 0.0
    assert float(np.asarray(rep.result)) == pytest.approx(reference, rel=1e-3)


@pytest.mark.parametrize("name", ["openmp", "mpi", "hadoop"])
def test_batched_execution_matches_single(name):
    rngs = jax.random.split(jax.random.PRNGKey(0), 4)
    rep = get_stack(name).run_batch(_tiny_dag(), rngs)
    assert rep.batch == 4
    vals = np.asarray(rep.result)
    assert vals.shape == (4,)
    single = get_stack(name).run(_tiny_dag(), rng=rngs[0])
    assert vals[0] == pytest.approx(float(np.asarray(single.result)),
                                    rel=1e-3)


def test_raw_fn_runs_on_every_stack():
    x = jnp.arange(512, dtype=jnp.float32)
    ref = float(jnp.sum(x * x))
    for name in ("openmp", "mpi", "spark", "hadoop"):
        rep = get_stack(name).run(lambda v: jnp.sum(v * v), x)
        assert float(np.asarray(rep.result)) == pytest.approx(ref, rel=1e-5)


def test_spec_and_benchmark_executables_coerce():
    spec = ProxySpec.from_dag(_tiny_dag())
    rep_spec = get_stack("openmp").run(spec, rng=jax.random.PRNGKey(0))
    rep_pb = get_stack("openmp").run(spec.to_benchmark(),
                                     rng=jax.random.PRNGKey(0))
    assert float(np.asarray(rep_spec.result)) == pytest.approx(
        float(np.asarray(rep_pb.result)), rel=1e-6)


def test_workload_runs_on_stack():
    from repro.core.workloads import WORKLOADS
    rep = get_stack("openmp").run(WORKLOADS["terasort"], "tiny")
    assert all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(rep.result))


def test_map_reduce_reports_io():
    data = jnp.arange(4096, dtype=jnp.float32)
    rep = HadoopStack(n_chunks=4).map_reduce(
        lambda c: jnp.sort(c.reshape(-1)), lambda x: jnp.sort(x), data)
    assert rep.io_bytes > 0
    assert np.asarray(rep.result).shape == (4096,)


def test_legacy_stack_functions_warn_and_delegate():
    from repro.core import stacks
    x = jnp.arange(64, dtype=jnp.float32)
    with pytest.warns(DeprecationWarning):
        out, io = stacks.openmp(lambda v: jnp.sum(v), x)
    assert float(out) == pytest.approx(float(jnp.sum(x)))
    assert io == 0.0
    with pytest.warns(DeprecationWarning):
        out, io = stacks.hadoop(lambda c: jnp.sort(c.reshape(-1)),
                                lambda v: jnp.sum(v), x, n_chunks=4)
    assert io > 0


def test_run_threads_rng_kwarg_into_raw_fn():
    fn = lambda rng: jnp.sum(jax.random.normal(rng, (64,)))
    key = jax.random.PRNGKey(7)
    rep = get_stack("openmp").run(fn, rng=key)
    expect = float(jax.jit(fn)(key))
    assert float(np.asarray(rep.result)) == pytest.approx(expect, rel=1e-6)


def test_run_rejects_positional_args_for_dag_executables():
    with pytest.raises(TypeError, match="rng="):
        get_stack("openmp").run(_tiny_dag(), jax.random.PRNGKey(7))


def test_mesh_stacks_do_not_touch_backend_until_used():
    # importing/instantiating must not freeze the jax device list
    from repro.api import MPIStack, SparkStack
    assert MPIStack()._mesh is None
    assert SparkStack()._mesh is None


def test_spec_warns_on_unknown_stack_name():
    from repro.core.workloads import PROXY_SPECS
    import json as _json
    d = _json.loads(_json.dumps(PROXY_SPECS["kmeans"]))
    d["stack"] = "hdoop"
    with pytest.warns(UserWarning, match="unregistered stack"):
        ProxySpec.from_json(d)


def test_legacy_mpi_keeps_spmd_sharding_semantics():
    # legacy mpi() shards inputs over the axis: psum over per-shard sums
    # must equal the global sum regardless of rank count
    from jax.sharding import Mesh
    from repro.core import stacks
    mesh = Mesh(np.array(jax.devices()), ("rank",))
    x = jnp.arange(64, dtype=jnp.float32)
    with pytest.warns(DeprecationWarning):
        out, io = stacks.mpi(
            lambda v: jax.lax.psum(jnp.sum(v), "rank"), mesh, "rank", x)
    assert float(out) == pytest.approx(float(jnp.sum(x)))
    assert io == 0.0
