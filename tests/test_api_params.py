"""Pytree parameter space: named leaves, bounds, vector/tree round-trip."""

import numpy as np
import pytest

from repro.api import CORE_FIELDS, ParamSpace
from repro.core.dag import Edge, ProxyDAG
from repro.core.dwarfs import ComponentParams


def _dag():
    return ProxyDAG(
        "x", {"src": 4096},
        [Edge("euclidean_distance", ["src"], "a",
              ComponentParams(data_size=4096, chunk_size=64, weight=2,
                              extra={"centers": 8})),
         Edge("quick_sort", ["a"], "out",
              ComponentParams(data_size=4096, chunk_size=256, weight=1))],
        "out")


def test_leaves_cover_core_fields_and_numeric_extras():
    space = ParamSpace.from_dag(_dag())
    names = set(space.names)
    for f in CORE_FIELDS:
        assert f"e0.euclidean_distance.{f}" in names
        assert f"e1.quick_sort.{f}" in names
    assert "e0.euclidean_distance.centers" in names
    assert len(space) == 2 * len(CORE_FIELDS) + 1


def test_every_leaf_has_finite_bounds():
    space = ParamSpace.from_dag(_dag())
    lo, hi = space.lower(), space.upper()
    assert (lo < hi).all() and np.isfinite(lo).all() and np.isfinite(hi).all()


def test_values_apply_roundtrip():
    dag = _dag()
    space = ParamSpace.from_dag(dag)
    vec = space.values(dag)
    vec[space.index_of("e0.euclidean_distance.centers")] = 32
    vec[space.index_of("e1.quick_sort.weight")] = 5
    space.apply(dag, vec)
    assert dag.edges[0].params.extra["centers"] == 32
    assert dag.edges[1].params.weight == 5
    assert np.allclose(space.values(dag), vec)


def test_apply_clamps_to_bounds_and_rounds_ints():
    dag = _dag()
    space = ParamSpace.from_dag(dag)
    vec = space.values(dag)
    li = space.index_of("e1.quick_sort.weight")
    vec[li] = 1e9                      # above the weight upper bound
    space.apply(dag, vec)
    assert dag.edges[1].params.weight == space.leaves[li].hi
    vec[li] = 2.6                      # integral field
    space.apply(dag, vec)
    assert dag.edges[1].params.weight == 3


def test_apply_is_noop_for_unchanged_leaves_even_out_of_bounds():
    # an existing out-of-bounds param (schema doesn't enforce bounds) must
    # survive an identity write-back: probing one leaf may not clamp others
    dag = _dag()
    dag.edges[0].params.extra["centers"] = float(1 << 23)   # above EXTRA hi
    space = ParamSpace.from_dag(dag)
    vec = space.values(dag)
    vec[space.index_of("e1.quick_sort.weight")] = 3         # touch one leaf
    space.apply(dag, vec)
    assert dag.edges[0].params.extra["centers"] == float(1 << 23)
    assert dag.edges[1].params.weight == 3


def test_apply_clamp_false_restores_out_of_bounds_values():
    # a tuner revert must reproduce the exact prior state, even when the
    # original value sat outside the nominal bounds
    dag = _dag()
    dag.edges[0].params.extra["centers"] = float(1 << 23)
    space = ParamSpace.from_dag(dag)
    orig = space.values(dag)
    step = orig.copy()
    step[space.index_of("e0.euclidean_distance.centers")] = 16
    space.apply(dag, step)
    space.apply(dag, orig, clamp=False)           # revert
    assert dag.edges[0].params.extra["centers"] == float(1 << 23)


def test_tree_and_bounds_tree_views():
    dag = _dag()
    space = ParamSpace.from_dag(dag)
    tree = space.tree(dag)
    assert tree["e0.euclidean_distance"]["centers"] == 8
    bounds = space.bounds_tree()
    lo, hi = bounds["e1.quick_sort"]["weight"]
    assert lo == 0.0 and hi > 1

    tree["e1.quick_sort"]["weight"] = 7
    space.apply_tree(dag, tree)
    assert dag.edges[1].params.weight == 7


def test_sample_stays_in_bounds():
    space = ParamSpace.from_dag(_dag())
    cand = space.sample(16, seed=3)
    assert cand.shape == (16, len(space))
    assert (cand >= space.lower() - 1e-9).all()
    assert (cand <= space.upper() + 1e-9).all()


# -- regression: integer rounding must never escape the leaf bounds ---------


def _fractional_int_space():
    # an integer leaf with fractional bounds: round-after-clamp used to
    # push 8.0 -> clamp 7.5 -> round 8.0, outside the bounds again
    from repro.api import ParamLeaf
    return ParamSpace([
        ParamLeaf("e0.x.weight", 0, "weight", 2.5, 7.5, True, dynamic=True),
        ParamLeaf("e0.x.fraction", 0, "fraction", 0.05, 0.95, False),
    ], dag_name="frac")


def test_clamp_respects_bounds_for_integer_leaves_with_fractional_bounds():
    space = _fractional_int_space()
    got = space.clamp(np.array([[8.0, 2.0], [0.0, -1.0], [7.49, 0.5]]))
    lo, hi = space.lower(), space.upper()
    assert (got >= lo).all() and (got <= hi).all(), got
    assert got[0, 0] == 7.0 and got[1, 0] == 3.0       # integral + inside
    ints = [l.integer for l in space.leaves]
    assert (got[:, ints] == np.round(got[:, ints])).all()


def test_sample_respects_integer_bounds_and_roundtrips_without_drift():
    dag = _dag()
    space = ParamSpace.from_dag(dag)
    cand = space.sample(32, seed=9)
    assert (cand >= space.lower()).all() and (cand <= space.upper()).all()
    ints = np.array([l.integer for l in space.leaves])
    assert (cand[:, ints] == np.round(cand[:, ints])).all()
    # apply -> values is drift-free: a sampled row IS the dag's new state
    for row in cand[:4]:
        space.apply(dag, row)
        assert np.array_equal(space.values(dag), row)
        # idempotent: re-clamping an applied row changes nothing
        assert np.array_equal(space.clamp(row), row)


def test_apply_clamps_integers_inside_fractional_bounds():
    from repro.api import ParamLeaf
    dag = _dag()
    space = ParamSpace([ParamLeaf("e1.quick_sort.weight", 1, "weight",
                                  2.5, 7.5, True, dynamic=True)])
    space.apply(dag, [100.0])
    assert dag.edges[1].params.weight == 7.0           # floor(7.5), not 8
    space.apply(dag, [0.0])
    assert dag.edges[1].params.weight == 3.0           # ceil(2.5), not 2


def test_sample_is_deterministic_across_processes():
    import subprocess
    import sys

    space = ParamSpace.from_dag(_dag())
    local = space.sample(8, seed=1234)
    code = (
        "import numpy as np\n"
        "from repro.api import ParamSpace\n"
        "from repro.core.dag import Edge, ProxyDAG\n"
        "from repro.core.dwarfs import ComponentParams\n"
        "dag = ProxyDAG('x', {'src': 4096},\n"
        "    [Edge('euclidean_distance', ['src'], 'a',\n"
        "          ComponentParams(data_size=4096, chunk_size=64, weight=2,\n"
        "                          extra={'centers': 8})),\n"
        "     Edge('quick_sort', ['a'], 'out',\n"
        "          ComponentParams(data_size=4096, chunk_size=256,\n"
        "                          weight=1))], 'out')\n"
        "print(repr(ParamSpace.from_dag(dag).sample(8, seed=1234)"
        ".tobytes().hex()))\n"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, check=True,
                         env={**__import__('os').environ,
                              "JAX_PLATFORMS": "cpu"})
    assert out.stdout.strip() == repr(local.tobytes().hex())


def test_legacy_param_space_shim_matches():
    dag = _dag()
    space = ParamSpace.from_dag(dag)
    handles = dag.param_space()
    assert handles == [space.handle(i) for i in range(len(space))]
    fields = {f for _, f in handles}
    assert {"data_size", "chunk_size", "parallelism", "weight",
            "centers"} <= fields


def test_legacy_get_set_param_warn():
    dag = _dag()
    with pytest.warns(DeprecationWarning):
        v = dag.get_param(0, "centers")
    assert v == 8
    with pytest.warns(DeprecationWarning):
        dag.set_param(0, "centers", 16)
    assert dag.edges[0].params.extra["centers"] == 16
