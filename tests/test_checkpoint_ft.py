"""Checkpoint/restart, async writer, fault injection, elastic resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.distributed.fault_tolerance import ResilientTrainLoop
from repro.faults import InjectedFailure
from repro.models import Model
from repro.train import AdamWConfig, TrainOptions, init_state, make_train_step
from repro.train.checkpoint import (AsyncCheckpointer, available_steps,
                                    latest_step, restore_checkpoint,
                                    save_checkpoint)


def _tiny_state(rng):
    cfg = ARCHS["tinyllama-1.1b"].reduced()
    model = Model(cfg)
    return cfg, model, init_state(model, rng)


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg, model, state = _tiny_state(rng)
    save_checkpoint(state, str(tmp_path), step=7)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(state, str(tmp_path))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_partial_checkpoints(tmp_path, rng):
    _, _, state = _tiny_state(rng)
    save_checkpoint(state, str(tmp_path), step=1)
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names)


def test_async_checkpointer_gc(tmp_path, rng):
    _, _, state = _tiny_state(rng)
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(state, s)
    ck.wait()
    assert available_steps(str(tmp_path)) == [3, 4]


def test_resilient_loop_recovers_from_failures(tmp_path, rng):
    cfg, model, state = _tiny_state(rng)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                      TrainOptions()))

    def batch_fn(step):
        r = jax.random.PRNGKey(step)          # deterministic data replay
        toks = jax.random.randint(r, (2, 16), 0, cfg.vocab)
        return {"tokens": toks, "labels": toks}

    fails = {5, 9}

    def injector(step):
        if step in fails:
            fails.discard(step)
            raise InjectedFailure(f"node died at step {step}")

    loop = ResilientTrainLoop(step_fn, str(tmp_path), ckpt_every=3)
    result = loop.run(state, batch_fn, num_steps=12,
                      failure_injector=injector)
    assert result.restarts == 2
    assert int(result.state.step) == 12
    assert all(np.isfinite(m["loss"]) for m in result.metrics_history)


def test_elastic_restore_with_new_shardings(tmp_path, rng):
    """A checkpoint restores onto a different mesh (elastic scaling)."""
    if not hasattr(jax.sharding, "AxisType") or not hasattr(jax, "P"):
        pytest.skip("explicit mesh axis types (jax.sharding.AxisType / "
                    "jax.P) require jax >= 0.5")
    cfg, model, state = _tiny_state(rng)
    save_checkpoint(state, str(tmp_path), step=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    from repro.distributed.sharding import named, param_specs
    specs = named(param_specs(state.params, mesh, cfg=cfg), mesh)
    shardings = type(state)(params=specs,
                            opt={"mu": specs, "nu": specs, "master": specs},
                            step=jax.NamedSharding(mesh, jax.P()))
    restored = restore_checkpoint(state, str(tmp_path), shardings=shardings)
    assert int(restored.step) == int(state.step)
    a = jax.tree.leaves(restored.params)[0]
    assert isinstance(a.sharding, jax.sharding.NamedSharding)


def test_loss_decreases_and_compression_works(rng):
    cfg, model, state = _tiny_state(rng)
    toks = jax.random.randint(rng, (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    for compress in (False, True):
        st = init_state(model, rng)
        step_fn = jax.jit(make_train_step(
            model, AdamWConfig(lr=3e-3, warmup_steps=1),
            TrainOptions(compress_grads=compress)))
        losses = []
        for _ in range(8):
            st, m = step_fn(st, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], f"compress={compress}: {losses}"


def test_microbatch_accumulation_matches_full_batch(rng):
    cfg, model, _ = _tiny_state(rng)
    toks = jax.random.randint(rng, (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    s1 = init_state(model, rng)
    s2 = init_state(model, rng)
    f1 = jax.jit(make_train_step(model, AdamWConfig(), TrainOptions(accum=1)))
    f2 = jax.jit(make_train_step(model, AdamWConfig(), TrainOptions(accum=2)))
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    # same data => losses close; grads averaged identically up to reordering
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
