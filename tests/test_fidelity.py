"""Paper-fidelity harness for the population tuner (§2.3 + Data Dwarfs).

The paper's workflow tunes a dwarf-combination proxy until its metric
vector deviates from the target by less than a tolerance (~10%).  This
harness pits the batched :class:`PopulationTuner` against the greedy
one-parameter-at-a-time :class:`AutoTuner` on a terasort-style proxy
(sample -> hash-partition -> sort -> merge), on CPU, under a fixed
candidate budget — the population tuner must reach a final worst-metric
deviation at least as good as greedy's (or inside the paper tolerance),
and its sweep must never trace through the measurement engine (the
compile-once contract that makes populations cheap).

The detuned start prunes the merge edge entirely (weight 0).  That is a
known greedy blind spot — its multiplicative steps cannot re-grow a zero
weight — so the harness also documents *why* population search earns its
keep beyond raw throughput.
"""

import numpy as np
import pytest

from repro.api import cache_stats
from repro.core import (AutoTuner, PopulationTuner, ProxyBenchmark,
                        StructuralTuner, engine)
from repro.core.autotune import DEFAULT_METRICS, _deviations
from repro.core.dag import Edge, ProxyDAG
from repro.core.dwarfs import ComponentParams
from repro.core.structsearch import structural_fidelity_harness

PAPER_TOL = 0.10          # the paper's ~10% deviation target
BUDGET = 96               # fixed candidate budget (16 x 6 generations)
SEED = 0
SIZE = 16384


def _terasort_style(w_sample, w_partition, w_sort, w_merge):
    """The TeraSort pipeline shape: interval-sample the keys, hash them
    into range partitions, sort per partition, merge the runs."""
    return ProxyDAG(
        "terasort_style", {"records": SIZE},
        [Edge("interval_sampling", ["records"], "sampled",
              ComponentParams(data_size=SIZE, chunk_size=256,
                              weight=w_sample)),
         Edge("hash", ["sampled"], "partitioned",
              ComponentParams(data_size=SIZE, chunk_size=256,
                              weight=w_partition, extra={"rounds": 2})),
         Edge("quick_sort", ["partitioned"], "sorted",
              ComponentParams(data_size=SIZE, chunk_size=256,
                              weight=w_sort)),
         Edge("merge_sort", ["sorted"], "merged",
              ComponentParams(data_size=SIZE, chunk_size=256,
                              weight=w_merge))],
        "merged")


def _reference():
    return ProxyBenchmark(_terasort_style(1, 2, 4, 2))


def _detuned():
    """Merge pruned, sort knocked down: the dominant gather/scatter
    channel collapses and the tuner must re-grow it."""
    return ProxyBenchmark(_terasort_style(1, 2, 1, 0))


def _worst_dev(target, metrics, keys):
    devs = _deviations(target, metrics, keys)
    return max((abs(d) for d in devs.values()), default=np.inf)


@pytest.fixture(scope="module")
def target():
    return engine.measure(_reference().dag)


def _keys(target):
    return [k for k in DEFAULT_METRICS if abs(target.get(k, 0.0)) > 1e-12]


def test_detuned_start_is_actually_off_target(target):
    start_dev = _worst_dev(target, engine.measure(_detuned().dag),
                           _keys(target))
    assert start_dev > PAPER_TOL     # otherwise the harness proves nothing


def test_population_tuner_meets_greedy_deviation_within_budget(target):
    keys = _keys(target)

    greedy = AutoTuner(target, tol=0.05, max_iter=8).tune(_detuned())
    greedy_dev = _worst_dev(target, engine.measure(greedy.proxy.dag), keys)

    e0 = engine.stats()
    s0 = cache_stats()
    pop = PopulationTuner(target, tol=0.05, population=16, generations=6,
                          max_candidates=BUDGET, seed=SEED).tune(_detuned())
    e1 = engine.stats()
    s1 = cache_stats()

    # budget + fidelity: at least as close as greedy, or inside the
    # paper's tolerance
    assert pop.candidates_evaluated <= BUDGET
    assert (pop.final_deviation <= greedy_dev + 1e-9
            or pop.final_deviation <= PAPER_TOL), (
        f"population dev {pop.final_deviation:.4f} vs greedy "
        f"{greedy_dev:.4f}")
    assert pop.final_accuracy["avg"] >= pop.initial_accuracy["avg"] - 1e-9

    # the returned proxy really measures at the reported deviation
    redo = _worst_dev(target, engine.measure(pop.proxy.dag), keys)
    assert redo == pytest.approx(pop.final_deviation, rel=1e-6, abs=1e-9)

    # compile-once contract: the population sweep reports 0 engine traces,
    # and the vmapped executable compiles at most once per (structure,
    # population size) across every generation
    assert e1["traces"] - e0["traces"] == 0
    assert s1["traces"] - s0["traces"] <= 2   # 16-wide + truncated last gen


def test_population_recovers_a_pruned_edge_greedy_cannot(target):
    """The qualitative advantage: multiplicative greedy steps cannot
    re-grow a zero weight, log-uniform population search can."""
    greedy = AutoTuner(target, tol=0.05, max_iter=8).tune(_detuned())
    assert greedy.proxy.dag.edges[3].params.weight == 0
    pop = PopulationTuner(target, tol=0.05, population=16, generations=6,
                          max_candidates=BUDGET, seed=SEED).tune(_detuned())
    assert pop.proxy.dag.edges[3].params.weight > 0
    assert pop.final_deviation <= PAPER_TOL


def test_population_sweep_reports_zero_engine_traces(target):
    engine.reset_stats()
    pop = PopulationTuner(target, tol=0.05, population=8, generations=3,
                          seed=SEED, execute=False).tune(_detuned())
    assert pop.candidates_evaluated <= 24
    assert engine.stats()["traces"] == 0


# ---------------------------------------------------------------------------
# structural fidelity: a target reachable only by a structure change
# ---------------------------------------------------------------------------
#
# The reference pipeline carries an fft stage the detuned structure lacks
# *entirely* (not weight-0 — the edge does not exist).  No re-weighting of
# the remaining edges can create the missing transform channel, so this is
# the blind spot of every weight-only tuner — population search included —
# and exactly the half of the Fig.-3 design space the StructuralTuner adds.
# The harness definition is shared with the benchmark CI gate
# (structural_fidelity_harness) so the two can never drift apart.

_FFT_REF, _FFT_DETUNED, STRUCT_POOL = structural_fidelity_harness(size=SIZE)


def _fft_reference():
    return ProxyBenchmark(_FFT_REF).clone()


def _structure_detuned():
    """The fft edge is gone — not pruned to weight 0, absent."""
    return ProxyBenchmark(_FFT_DETUNED).clone()


@pytest.fixture(scope="module")
def fft_target():
    return engine.measure(_fft_reference().dag)


def test_weight_only_tuner_cannot_create_a_missing_channel(fft_target):
    assert fft_target["mix_fft"] > 0
    pop = PopulationTuner(fft_target, tol=0.05, population=16,
                          generations=6, max_candidates=BUDGET, seed=SEED,
                          execute=False).tune(_structure_detuned())
    tuned = engine.measure(pop.proxy.dag)
    assert tuned.get("mix_fft", 0.0) == 0.0     # unreachable by weights
    assert pop.final_deviation > PAPER_TOL


def test_structural_tuner_rediscovers_the_missing_edge(fft_target):
    """Under the same total candidate budget, the structural tuner must
    insert the absent fft component and converge where weight-only search
    cannot — with zero engine retraces (structure scoring is pure
    compositional arithmetic over cached body reports)."""
    weight_only = PopulationTuner(
        fft_target, tol=0.05, population=16, generations=6,
        max_candidates=BUDGET, seed=SEED,
        execute=False).tune(_structure_detuned())

    t0 = engine.stats()["traces"]
    res = StructuralTuner(fft_target, tol=PAPER_TOL, max_candidates=BUDGET,
                          generations=4, components=STRUCT_POOL,
                          seed=SEED).tune(_structure_detuned())
    assert engine.stats()["traces"] - t0 == 0
    assert res.candidates_evaluated <= BUDGET
    assert any(e.component == "fft" for e in res.proxy.dag.edges)
    assert res.final_deviation <= PAPER_TOL
    assert res.final_deviation < weight_only.final_deviation
    # the returned proxy really measures at the reported deviation
    redo = _worst_dev(fft_target, engine.measure(res.proxy.dag),
                      _keys(fft_target))
    assert redo == pytest.approx(res.final_deviation, rel=1e-6, abs=1e-9)
