"""Fault-tolerance contract of the serving engine: seeded chaos plans are
bit-reproducible, retried chunks return bit-identical outputs, no request
is ever lost under injected failures, the circuit breaker trips and
recovers, live ``submit()`` is thread-safe at zero steady-state retraces,
and the partial-chunk timeout flush lowers P99 on sparse traces."""

import math
import threading

import numpy as np
import pytest

from repro.api.stack import CACHE_STATS, classify_failure
from repro.faults import FaultPlan, InjectedFailure, default_fault_rate
from repro.serve.engine import (ServingEngine, burst_trace, poisson_trace,
                                serve)

MIX = ("terasort", "kmeans")


def _deterministic(report):
    """ServeReport JSON minus the host RSS samples (the one field that is
    legitimately machine-state dependent even under the virtual clock)."""
    d = report.to_json()
    d.pop("resources")
    return d


@pytest.fixture(scope="module")
def trace():
    return poisson_trace(n=12, rate_rps=200.0, seed=5, mix=MIX)


@pytest.fixture(scope="module")
def engine(trace):
    eng = ServingEngine(stack="openmp", max_batch=4, bucket_size=2)
    eng.warmup(trace)
    return eng


# ---------------------------------------------------------------------------
# FaultPlan primitives
# ---------------------------------------------------------------------------


def test_fault_plan_is_seeded_and_pure():
    a = FaultPlan.sample(64, seed=9, failure_rate=0.25, straggler_rate=0.25,
                         eviction_rate=0.1, poison=(3,))
    b = FaultPlan.sample(64, seed=9, failure_rate=0.25, straggler_rate=0.25,
                         eviction_rate=0.1, poison=(3,))
    assert a == b
    assert a.summary() == b.summary()
    assert not a.empty and FaultPlan().empty
    # pure lookups: failures clear after fail_attempts, poison never does
    rid = next(iter(a.failures))
    assert a.should_fail(rid, 0) and not a.should_fail(rid, 1)
    assert a.should_fail(3, 0) and a.should_fail(3, 10_000)
    assert a.straggler_delay_s(next(iter(a.stragglers))) > 0.0
    c = FaultPlan.sample(64, seed=10, failure_rate=0.25)
    assert c.failures != a.failures


def test_fault_rate_env_knob(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_RATE", raising=False)
    assert default_fault_rate() == 0.0
    monkeypatch.setenv("REPRO_FAULT_RATE", "")
    assert default_fault_rate() == 0.0
    monkeypatch.setenv("REPRO_FAULT_RATE", "0.25")
    assert default_fault_rate() == 0.25


def test_failure_classification():
    assert classify_failure(InjectedFailure("boom")) == "injected"
    assert classify_failure(MemoryError()) == "resource"
    assert classify_failure(RuntimeError("RESOURCE_EXHAUSTED: oom")) \
        == "resource"
    assert classify_failure(ValueError("bad shape")) == "fatal"
    assert classify_failure(RuntimeError("transport glitch")) == "transient"


def test_fault_primitives_moved_but_shimmed():
    from repro.distributed import fault_tolerance as shim
    from repro import faults
    assert shim.InjectedFailure is faults.InjectedFailure
    assert shim.StragglerMonitor is faults.StragglerMonitor
    assert shim.StragglerReport is faults.StragglerReport


# ---------------------------------------------------------------------------
# deterministic chaos (virtual clock)
# ---------------------------------------------------------------------------


def test_chaos_run_is_bit_reproducible_under_virtual_clock(engine, trace):
    plan = FaultPlan.sample(len(trace), seed=7, failure_rate=0.3,
                            straggler_rate=0.3, eviction_rate=0.15)
    a = engine.serve(trace, clock="virtual", faults=plan)
    b = engine.serve(trace, clock="virtual", faults=plan)
    assert _deterministic(a) == _deterministic(b)
    assert a.failures > 0 and a.retries > 0
    assert a.fault_plan == plan.summary()
    # the eviction storm is modeled: at least one re-warmed executable
    assert a.cold_dispatches > 0
    # a different seed yields a different chaos run
    other = FaultPlan.sample(len(trace), seed=8, failure_rate=0.3,
                             straggler_rate=0.3, eviction_rate=0.15)
    assert _deterministic(engine.serve(trace, clock="virtual",
                                       faults=other)) != _deterministic(a)


def test_stragglers_are_charged_to_latency(engine, trace):
    base = engine.serve(trace, clock="virtual")
    slow = engine.serve(
        trace, clock="virtual",
        faults=FaultPlan(stragglers={r.rid: 0.5 for r in trace}))
    assert slow.latency_s["p99"] > base.latency_s["p99"]
    assert _deterministic(slow) == _deterministic(engine.serve(
        trace, clock="virtual",
        faults=FaultPlan(stragglers={r.rid: 0.5 for r in trace})))


# ---------------------------------------------------------------------------
# zero loss + bit-identical retries (wall clock, real execution)
# ---------------------------------------------------------------------------


def test_no_request_lost_and_retries_bit_identical(engine, trace):
    clean = engine.serve(trace, clock="wall")
    assert clean.lost_requests == 0 and clean.failures == 0
    # >= 10% injected executor failures plus stragglers (the acceptance
    # bar): every request still completes, and every retried chunk's
    # output is bit-identical to the fault-free run
    plan = FaultPlan.sample(len(trace), seed=13, failure_rate=0.35,
                            straggler_rate=0.25)
    assert len(plan.failures) >= max(2, len(trace) // 10)
    chaos = engine.serve(trace, clock="wall", faults=plan)
    assert chaos.lost_requests == 0
    assert chaos.failures >= len(plan.failures)
    assert chaos.retries > 0
    assert chaos.status_counts().get("retried", 0) > 0
    assert all(s in ("ok", "retried") for s in chaos.statuses)
    for r_clean, r_chaos in zip(clean.results, chaos.results):
        np.testing.assert_array_equal(np.asarray(r_clean),
                                      np.asarray(r_chaos))


def test_poison_request_is_isolated_not_batch_fatal(trace):
    # rid 2 fails on every attempt; with the breaker disabled (huge
    # threshold) it must be bisected out of its chunk, terminally failed,
    # and every *other* request still served bit-identically
    eng = ServingEngine(stack="openmp", max_batch=4, bucket_size=2,
                        breaker_threshold=1000)
    eng.warmup(trace)
    clean = eng.serve(trace, clock="wall")
    plan = FaultPlan(poison=frozenset({2}))
    rep = eng.serve(trace, clock="wall", faults=plan)
    assert rep.lost_requests == 0
    assert rep.statuses[2] == "failed"
    assert rep.results[2] is None
    for rid in range(len(trace)):
        if rid == 2:
            continue
        assert rep.statuses[rid] in ("ok", "retried")
        np.testing.assert_array_equal(np.asarray(clean.results[rid]),
                                      np.asarray(rep.results[rid]))


def test_eviction_storm_recovers_with_recompile(trace):
    eng = ServingEngine(stack="openmp", max_batch=4, bucket_size=2)
    eng.warmup(trace)
    plan = FaultPlan(evictions=frozenset({trace.requests[4].rid}))
    rep = eng.serve(trace, clock="wall", faults=plan)
    # the storm evicted live executables: recovery recompiles (cold
    # dispatches) but never drops a request
    assert rep.lost_requests == 0
    assert rep.cold_dispatches > 0
    assert all(s in ("ok", "retried") for s in rep.statuses)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


def test_circuit_breaker_trips_degrades_and_recovers():
    tr = poisson_trace(n=10, rate_rps=500.0, seed=3, mix=("terasort",))
    eng = ServingEngine(stack="openmp", max_batch=1,
                        breaker_threshold=2, breaker_recovery=2,
                        max_retries=2)
    eng.warmup(tr)
    # rid 0 is poison on the normal path; after 2 consecutive failures the
    # breaker opens and the lane degrades to the forced-XLA singleton
    # path, which rescues rid 0 and subsequent requests until 2 degraded
    # successes close the breaker again
    rep = eng.serve(tr, clock="wall", faults=FaultPlan(poison=frozenset({0})))
    assert rep.breaker_trips == 1
    assert rep.degraded_dispatches == 2
    assert rep.lost_requests == 0
    counts = rep.status_counts()
    assert counts.get("degraded", 0) == 2
    assert counts.get("failed", 0) == 0


def test_deadline_misses_are_accounted_per_slo():
    tr = burst_trace(n=6, bursts=1, seed=0, mix=("terasort",),
                     deadline_s=1e-9, slo="interactive")
    eng = ServingEngine(stack="openmp", max_batch=2, bucket_size=2)
    rep = eng.serve(tr, clock="virtual")
    assert rep.deadline_misses > 0
    assert rep.deadline_miss_by_slo.get("interactive") == rep.deadline_misses
    relaxed = burst_trace(n=6, bursts=1, seed=0, mix=("terasort",),
                          deadline_s=1e9, slo="batch")
    assert eng.serve(relaxed, clock="virtual").deadline_misses == 0


# ---------------------------------------------------------------------------
# partial-chunk timeout flush
# ---------------------------------------------------------------------------


def test_timeout_flush_lowers_p99_on_sparse_trace():
    # arrivals ~40 s apart, service a few seconds: holding for a full
    # chunk of 8 makes early requests wait for late arrivals; a finite
    # flush bound releases short padded chunks instead
    sparse = poisson_trace(n=12, rate_rps=0.025, seed=2, mix=("terasort",))
    hold = serve(sparse, stack="openmp", clock="virtual", warmup=False,
                 max_batch=8, bucket_size=8, batch_wait_s=math.inf)
    flush = serve(sparse, stack="openmp", clock="virtual", warmup=False,
                  max_batch=8, bucket_size=8, batch_wait_s=0.05)
    assert flush.timeout_flushes > 0
    assert flush.latency_s["p99"] < hold.latency_s["p99"]
    # flushing must not lose or duplicate anything
    assert flush.lost_requests == 0
    assert sum(k * v for k, v in flush.batch_hist.items()) == len(sparse)
    # eager dispatch (the default) reports no timeout flushes
    eager = serve(sparse, stack="openmp", clock="virtual", warmup=False,
                  max_batch=8, bucket_size=8)
    assert eager.timeout_flushes == 0


# ---------------------------------------------------------------------------
# live submission
# ---------------------------------------------------------------------------


def test_concurrent_submit_zero_steady_state_retraces(engine, trace):
    eng = engine
    eng.warmup(trace)            # idempotent; ensures both chunk sizes
    eng.start()
    try:
        futs = {}
        flock = threading.Lock()
        traces0 = CACHE_STATS["traces"]

        def feed(shard):
            for r in shard:
                f = eng.submit(r)
                with flock:
                    futs[r.rid] = f

        threads = [threading.Thread(target=feed,
                                    args=(trace.requests[i::8],))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert eng.drain(timeout=60.0)
    finally:
        rep = eng.shutdown()
    assert CACHE_STATS["traces"] == traces0
    assert rep.mode == "live"
    assert rep.n_requests == len(trace)
    assert rep.lost_requests == 0
    assert rep.retraces == 0
    assert len(futs) == len(trace)
    for f in futs.values():
        assert np.asarray(f.result()).size > 0


def test_live_submit_requires_start_and_stamps_rids(trace):
    eng = ServingEngine(stack="openmp", max_batch=2, bucket_size=2)
    with pytest.raises(RuntimeError):
        eng.submit(trace.requests[0])
    eng.warmup(trace)
    eng.start()
    try:
        f0 = eng.submit(trace.requests[3], deadline_s=10.0)
        f1 = eng.submit(trace.requests[3])
        assert f0.result(timeout=60.0) is not None
        assert f1.result(timeout=60.0) is not None
    finally:
        rep = eng.shutdown()
    # re-stamped rids: two submissions of the same request are distinct
    assert rep.n_requests == 2
    assert rep.lost_requests == 0
    with pytest.raises(RuntimeError):
        eng.submit(trace.requests[0])   # engine is shut down again
