"""Sharding-rule validity for every arch on both production meshes.

Uses AbstractMesh — no fake devices needed; checks every assigned axis
divides its dimension (the no-uneven-shards invariant) for params, inputs
and caches, full-size configs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_is_supported
from repro.distributed.sharding import (batch_spec_axis, cache_specs_tree,
                                        param_specs)
from repro.models.model import Model, cache_specs, input_specs


def _abstract_mesh(sizes, names):
    try:                       # jax >= 0.5 signature: (axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:          # jax 0.4.x signature: ((name, size), ...)
        return AbstractMesh(tuple(zip(names, sizes)))


def _meshes():
    yield _abstract_mesh((16, 16), ("data", "model"))
    yield _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, axis):
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _check_tree(tree_specs, tree_shapes, mesh, where):
    leaves_s = jax.tree.leaves(tree_specs, is_leaf=lambda x: isinstance(x, P))
    leaves_v = jax.tree.leaves(tree_shapes)
    assert len(leaves_s) == len(leaves_v)
    for spec, val in zip(leaves_s, leaves_v):
        for dim, axis in zip(val.shape, tuple(spec)):
            if axis is None:
                continue
            size = _axis_size(mesh, axis)
            assert dim % size == 0, (where, val.shape, tuple(spec), axis)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divisible_all_meshes(arch):
    cfg = ARCHS[arch]
    model = Model(cfg)
    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    for mesh in _meshes():
        specs = param_specs(params_sds, mesh, cfg=cfg)
        _check_tree(specs, params_sds, mesh, arch)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_cache_specs_divisible(arch):
    cfg = ARCHS[arch]
    for shape_name in ("decode_32k", "long_500k"):
        shape = SHAPES[shape_name]
        ok, _ = cell_is_supported(cfg, shape)
        if not ok:
            continue
        sds = cache_specs(cfg, shape)
        for mesh in _meshes():
            specs = cache_specs_tree(cfg, sds, mesh)
            _check_tree(specs, sds, mesh, (arch, shape_name))


def test_batch_spec_axis_prefers_full_dp():
    mesh = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert batch_spec_axis(mesh, 256) == ("pod", "data")
    assert batch_spec_axis(mesh, 16) == "data"
    assert batch_spec_axis(mesh, 1) is None


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_input_specs_exist_for_all_supported_cells(arch):
    cfg = ARCHS[arch]
    for shape in SHAPES.values():
        ok, why = cell_is_supported(cfg, shape)
        if not ok:
            assert "full-attention" in why
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "train":
            assert "labels" in specs
        if shape.kind == "decode":
            assert specs["tokens"].shape[1] == 1
