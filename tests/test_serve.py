"""Serving correctness: prefill + decode must reproduce full-forward logits."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import Model
from repro.serve import generate, make_decode_step, make_prefill_step


@pytest.mark.parametrize("arch", ["qwen2-7b", "qwen3-4b", "xlstm-1.3b",
                                  "jamba-1.5-large-398b"])
def test_incremental_decode_matches_full_forward(arch, rng):
    cfg = ARCHS[arch].reduced()
    # disable chunking edge cases for short test sequences
    model = Model(cfg)
    params = model.init(rng)
    B, S = 2, 24
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)

    logits_full, _ = jax.jit(lambda p, t: model.forward(p, t))(params, toks)

    cache = model.init_cache(B, 32)
    pre_logits, cache = jax.jit(
        lambda p, t, c: model.prefill(p, t, c))(params, toks[:, :S - 2], cache)
    # two incremental decode steps
    l1, cache = jax.jit(model.decode_step)(params, toks[:, S - 2: S - 1],
                                           cache, jnp.asarray(S - 2))
    l2, cache = jax.jit(model.decode_step)(params, toks[:, S - 1: S],
                                           cache, jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(pre_logits[:, -1]),
                               np.asarray(logits_full[:, S - 3]),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(l2[:, -1]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_generate_driver_runs(rng):
    cfg = ARCHS["tinyllama-1.1b"].reduced()
    model = Model(cfg)
    params = model.init(rng)
    prompt = jax.random.randint(rng, (2, 8), 0, cfg.vocab)
    out = generate(model, params, prompt, max_new=4, max_seq=16)
    assert out.shape == (2, 4)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab).all()


def test_encdec_prefill_decode(rng):
    cfg = ARCHS["whisper-large-v3"].reduced()
    model = Model(cfg)
    params = model.init(rng)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    frames = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model))
    logits_full, _ = jax.jit(
        lambda p, t, f: model.forward(p, t, frames=f))(params, toks, frames)
    cache = model.init_cache(B, 16)
    pre, cache = jax.jit(lambda p, t, c, f: model.prefill(p, t, c, frames=f))(
        params, toks[:, :S - 1], cache, frames)
    l, _ = jax.jit(model.decode_step)(params, toks[:, S - 1:], cache,
                                      jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(l[:, -1]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)
