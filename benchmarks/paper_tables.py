"""Paper-table benchmarks: Table 6 (speedup), Fig 5 (accuracy), Fig 6
(instruction mix), Fig 7 (I/O bandwidth), Fig 8/9 (data impact), Fig 11
(scaling trends), Fig 12 (cross-platform), Table 1 (dwarf coverage).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ParamSpace, get_stack
from repro.core import characterize, decompose_to_dwarfs, vector_accuracy
from repro.core.metrics import REPORT_METRICS
from repro.core.workloads import SCALES, WORKLOADS, kmeans_sparse_step, \
    workload_step_fn
from repro.data import gen_records, gen_sparse_csr, gen_matrix

from .common import (BENCH_DIR, EVAL_SCALE, SCALE, csv_row, evaluate_pair,
                     original_profile, tuned_proxy)

WL = ("terasort", "kmeans", "pagerank", "sift")


def bench_table6_speedup() -> List[str]:
    """Table 6/8: execution-time speedup of proxy vs original."""
    rows = []
    for name in WL:
        orig, pp, acc = evaluate_pair(name)
        speedup = orig.exec_s / max(pp.exec_s, 1e-9)
        sim_speedup = orig.simulation_s / max(pp.simulation_s, 1e-9)
        rows.append(csv_row(
            f"table6/{name}", pp.exec_s * 1e6,
            f"orig_s={orig.exec_s:.3f};proxy_s={pp.exec_s:.4f};"
            f"speedup={speedup:.0f}x;compile_speedup={sim_speedup:.1f}x"))
    return rows


def bench_fig5_accuracy() -> List[str]:
    """Fig 5/10: per-workload average metric accuracy (Eq. 1)."""
    rows = []
    for name in WL:
        orig, pp, acc = evaluate_pair(name)
        worst = min((v, k) for k, v in acc.items() if k != "avg")
        rows.append(csv_row(
            f"fig5/{name}", acc["avg"] * 100,
            f"avg_acc={acc['avg']:.3f};worst={worst[1]}:{worst[0]:.2f};"
            f"n_metrics={len(acc) - 1}"))
    return rows


def bench_fig6_instruction_mix() -> List[str]:
    """Fig 6: element-op mix breakdown orig vs proxy (share points)."""
    rows = []
    for name in WL:
        orig, pp, _ = evaluate_pair(name)
        mix_acc = []
        parts = []
        for k in sorted(orig.metrics):
            if not k.startswith("mix_"):
                continue
            h, p = orig.metrics[k], pp.metrics.get(k, 0.0)
            if h < 0.01 and p < 0.01:
                continue
            mix_acc.append(1.0 - abs(h - p))
            parts.append(f"{k[4:]}:{h:.2f}/{p:.2f}")
        rows.append(csv_row(
            f"fig6/{name}", float(np.mean(mix_acc)) * 100,
            f"mix_acc={np.mean(mix_acc):.3f};" + ";".join(parts[:5])))
    return rows


def bench_fig7_io() -> List[str]:
    """Fig 7: disk-I/O bandwidth analog — Hadoop-substrate host spill."""
    rows = []
    rng = jax.random.PRNGKey(0)
    n = SCALES[SCALE]["terasort_n"]
    keys, _ = gen_records(rng, n)

    hstack = get_stack("hadoop")
    rep_orig = hstack.map_reduce(lambda c: jnp.sort(c.reshape(-1)),
                                 lambda x: jnp.sort(x), keys, n_chunks=8)
    bw_orig = rep_orig.io_bandwidth

    proxy, _ = tuned_proxy("terasort")
    pkeys = jax.random.bits(rng, (max(4096, n // 8),), jnp.uint32)
    rep_px = hstack.map_reduce(lambda c: jnp.sort(c.reshape(-1)),
                               lambda x: jnp.sort(x), pkeys, n_chunks=8)
    bw_px = rep_px.io_bandwidth
    acc = 1.0 - abs(bw_px - bw_orig) / bw_orig
    rows.append(csv_row(
        "fig7/terasort_io", bw_orig / 1e6,
        f"orig_MBps={bw_orig/1e6:.0f};proxy_MBps={bw_px/1e6:.0f};"
        f"acc={max(acc,0):.3f}"))
    return rows


def bench_fig8_9_data_impact() -> List[str]:
    """Fig 8/9: input sparsity changes behaviour; proxy tracks it."""
    rows = []
    rng = jax.random.PRNGKey(0)
    s = SCALES[SCALE]
    n, d, k = s["kmeans_n"], s["kmeans_d"], s["kmeans_k"]
    centers = gen_matrix(jax.random.fold_in(rng, 1), k, d)
    profs = {}
    for sparsity in (0.0, 0.9):
        idx, vals = gen_sparse_csr(rng, n, d, sparsity)
        prof = characterize(
            lambda i, v, c: kmeans_sparse_step(i, v, c, s["kmeans_iters"]),
            (idx, vals, centers), name=f"kmeans_sparse{sparsity}",
            execute=True, exec_iters=2)
        profs[sparsity] = prof
    bw0 = profs[0.0].metrics.get("mem_bw", 0.0)
    bw9 = profs[0.9].metrics.get("mem_bw", 0.0)
    rows.append(csv_row(
        "fig8/kmeans_sparse_vs_dense", bw9 / 1e6,
        f"dense_MBps={bw0/1e6:.0f};sparse_MBps={bw9/1e6:.0f};"
        f"ratio={bw9/max(bw0,1):.2f}"))
    # Fig 9: the tuned proxy stays accurate under both inputs (structural)
    proxy, _ = tuned_proxy("kmeans")
    pp = proxy.profile(execute=True, exec_iters=2)
    keys = [k2 for k2 in REPORT_METRICS
            if k2 in pp.metrics and not k2.startswith(("mips", "flop_rate",
                                                       "mem_bw"))]
    for sparsity, prof in profs.items():
        acc = vector_accuracy(prof.metrics, pp.metrics, keys=keys)
        rows.append(csv_row(
            f"fig9/kmeans_sparsity_{int(sparsity*100)}", acc["avg"] * 100,
            f"avg_acc={acc['avg']:.3f}"))
    return rows


def bench_fig11_scaling() -> List[str]:
    """Fig 11 analog: scaling trends orig vs proxy must correlate.

    The paper scales cores (cpu-hotplug); this container has one core, so we
    scale the problem (weak scaling over input size) and require the
    proxy's runtime trend to track the original's (consistent trends =
    the property the paper demonstrates).
    """
    rows = []
    for name in ("terasort", "kmeans"):
        times_o, times_p = [], []
        proxy, _ = tuned_proxy(name)
        for scale in ("tiny", "small"):
            prof = original_profile(name, scale, execute=True, exec_iters=2)
            times_o.append(prof.exec_s)
        base = proxy.profile(execute=True, exec_iters=2).exec_s
        # proxy scaled down by the same input ratio (pytree parameter space)
        small = proxy.clone()
        space = ParamSpace.from_dag(small.dag)
        vec = space.values(small.dag)
        for li, leaf in enumerate(space.leaves):
            if leaf.field == "data_size":
                vec[li] = max(256, vec[li] / 16)
        space.apply(small.dag, vec)
        times_p = [small.profile(execute=True, exec_iters=2).exec_s, base]
        trend_o = times_o[1] / max(times_o[0], 1e-9)
        trend_p = times_p[1] / max(times_p[0], 1e-9)
        consistent = (trend_o > 1) == (trend_p > 1)
        rows.append(csv_row(
            f"fig11/{name}", trend_o,
            f"orig_trend={trend_o:.1f}x;proxy_trend={trend_p:.1f}x;"
            f"consistent={consistent}"))
    return rows


def bench_fig12_cross_platform() -> List[str]:
    """Fig 12 analog: consistent speedup trends across 'platforms'.

    ARMv8 vs X86 is unavailable; the controlled platform change here is the
    numeric datapath (f32 vs bf16 pipelines), which changes the machine
    balance the same way for original and proxy.
    """
    rows = []
    name = "kmeans"
    fn, args = workload_step_fn(name, SCALE)
    prof32 = characterize(fn, args, name="kmeans_f32", execute=True,
                          exec_iters=2)
    args16 = tuple(a.astype(jnp.bfloat16) if a.dtype == jnp.float32 else a
                   for a in args)
    prof16 = characterize(fn, args16, name="kmeans_bf16", execute=True,
                          exec_iters=2)
    proxy, _ = tuned_proxy(name)
    pp32 = proxy.profile(execute=True, exec_iters=2)
    ratio_o = prof32.exec_s / max(prof16.exec_s, 1e-9)
    rows.append(csv_row(
        "fig12/kmeans_f32_vs_bf16", ratio_o,
        f"orig_ratio={ratio_o:.2f};proxy_runs=f32_only_on_cpu;"
        f"orig_f32_s={prof32.exec_s:.3f};orig_bf16_s={prof16.exec_s:.3f}"))
    return rows


def bench_table1_coverage() -> List[str]:
    """Table 1: dwarf coverage — profiler attribution per workload."""
    rows = []
    for name in WL:
        fn, args = workload_step_fn(name, "tiny")
        prof = characterize(fn, args, name=name, execute=False)
        w = decompose_to_dwarfs(prof.report)
        top = sorted(w.items(), key=lambda kv: -kv[1])[:4]
        rows.append(csv_row(
            f"table1/{name}", 100 * sum(v for _, v in top),
            ";".join(f"{k}:{v:.2f}" for k, v in top)))
    return rows
