"""Roofline table rows from the cached dry-run cells."""

from __future__ import annotations

import json
from pathlib import Path
from typing import List

from .common import csv_row

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def bench_roofline(tag: str = "") -> List[str]:
    rows = []
    for f in sorted(DRYRUN_DIR.glob(f"*{tag}.json")):
        d = json.loads(f.read_text())
        name = f"roofline/{d['arch']}_{d['shape']}_{d['mesh']}"
        if d["status"] != "ok":
            rows.append(csv_row(name, 0.0, d["status"]))
            continue
        r = d["roofline"]
        rows.append(csv_row(
            name, r["step_time_s"] * 1e6,
            f"dom={r['dominant']};c={r['compute_s']:.3f};m={r['memory_s']:.3f};"
            f"x={r['collective_s']:.3f};mfu={r['mfu']:.4f};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"peakGB={d['bytes_per_device']['peak']/1e9:.1f};"
            f"fits={d['fits_16GB']}"))
    return rows
