# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: paper tables/figures + beyond-paper LM-proxy + roofline.

REPRO_BENCH_SCALE  (default small): scale for proxy tuning targets.
REPRO_BENCH_EVAL_SCALE (default full): scale for original-vs-proxy evaluation.
REPRO_BENCH_FAST=1: skip the expensive full-scale evaluations.
"""

import os
import sys
import traceback


def main() -> None:
    from . import paper_tables as pt
    from .compile_vs_run import bench_compile_vs_run
    from .lm_proxy import bench_lm_proxy
    from .roofline import bench_roofline

    fast = os.environ.get("REPRO_BENCH_FAST", "") == "1"
    benches = [
        ("table1_coverage", pt.bench_table1_coverage),
        ("compile_vs_run", bench_compile_vs_run),
        ("table6_speedup", pt.bench_table6_speedup),
        ("fig5_accuracy", pt.bench_fig5_accuracy),
        ("fig6_instruction_mix", pt.bench_fig6_instruction_mix),
        ("fig7_io", pt.bench_fig7_io),
        ("fig8_9_data_impact", pt.bench_fig8_9_data_impact),
        ("fig11_scaling", pt.bench_fig11_scaling),
        ("fig12_cross_platform", pt.bench_fig12_cross_platform),
        ("lm_proxy", bench_lm_proxy),
        ("roofline", bench_roofline),
    ]
    if fast:
        benches = [b for b in benches
                   if b[0] in ("table1_coverage", "compile_vs_run",
                               "roofline")]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches:
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
            failed.append(name)
    if failed:
        # every bench still prints its row, but the harness must not rot
        # silently — CI's smoke step keys off this exit code
        print(f"benchmark errors in: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
