"""Beyond-paper benchmark: dwarf proxies of the LM fleet.

For each (arch x shape) dry-run cell, auto-generate a dwarf proxy seeded
from the cell's HLO dwarf decomposition, tune it against the cell's metric
vector, and report (a) metric accuracy and (b) 'architecture simulation'
speedup = cell lower+compile+analyze time / proxy lower+compile+analyze time.
This is the paper's 100x-simulation-cut applied to accelerator-scale
workloads.

Cells are produced by ``python -m repro.launch.dryrun`` (512-chip fleet
emulation).  When a cell is missing — fresh checkout, CI — it is regenerated
on demand at reduced scale (``run_cell(..., reduced=True)``: the family's
``reduced()`` config on the local devices) instead of silently scoring 0.0.
A cell that cannot be generated or parsed raises :class:`LmProxyError`;
benchmarks/run.py and the lm_proxy gate in benchmarks/compile_vs_run.py turn
that into a non-zero exit, so a dead bench fails loudly.
"""

from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, List

from repro.core import proxy_from_dwarf_weights, vector_accuracy
from repro.core.autotune import autotune
from repro.core.metrics import CostReport, metric_vector
from repro.core.profiler import decompose_to_dwarfs

from .common import BENCH_DIR, REFRESH, csv_row

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

#: filename suffix for on-demand reduced cells (never shadows full cells)
REDUCED_TAG = "__reduced"

#: cells representative of each family (full sweep is expensive on 1 core)
CELLS = (
    ("qwen2-7b", "train_4k", "16x16"),
    ("kimi-k2-1t-a32b", "train_4k", "16x16"),
    ("xlstm-1.3b", "train_4k", "16x16"),
    ("jamba-1.5-large-398b", "prefill_32k", "16x16"),
    ("whisper-large-v3", "train_4k", "16x16"),
)

_FAST = os.environ.get("REPRO_BENCH_FAST", "") == "1"
_N_CELLS = os.environ.get("REPRO_BENCH_LM_CELLS", "")
#: cells actually benchmarked this run (CI fast mode trims the sweep)
ACTIVE_CELLS = (CELLS[:max(1, int(_N_CELLS))] if _N_CELLS
                else (CELLS[:2] if _FAST else CELLS))
_MAX_ITER = 8 if _FAST else 20


class LmProxyError(RuntimeError):
    """A dry-run cell is missing/unusable and could not be regenerated."""


#: CostReport dict-valued fields restored explicitly
_STRUCTURED_KEYS = ("op_mix", "collective_bytes", "collective_count",
                    "op_bytes")
#: scalars CostReport.to_json() derives from other fields — not settable
_DERIVED_KEYS = frozenset({"total_collective_bytes", "arithmetic_intensity"})


def _report_from_json(d: Dict) -> CostReport:
    """Strict CostReport loader for dry-run cells.

    Unknown keys are tolerated only when plainly numeric (an older/newer
    writer's extra scalar channel — forward-compatible to ignore).  Anything
    else — a structured field this loader does not restore, a known field
    holding a non-numeric value — raises instead of being dropped: silent
    dropping is how ``attention_flops`` on disk quietly became 0.0 in the
    proxy target and the whole bench rotted unnoticed.
    """
    rep = CostReport()
    r = d["report"]
    fields = {f.name for f in dataclasses.fields(CostReport)}
    for k, v in r.items():
        if k in _STRUCTURED_KEYS or k in _DERIVED_KEYS:
            continue
        if k == "while_trip_counts":
            rep.while_trip_counts = [int(x) for x in v]
            continue
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            if k in fields:
                setattr(rep, k, float(v))
            continue
        raise LmProxyError(
            f"dry-run report key {k!r} has non-numeric value of type "
            f"{type(v).__name__}; refusing to drop it silently")
    for k in _STRUCTURED_KEYS:
        setattr(rep, k, {kk: float(vv) for kk, vv in r.get(k, {}).items()})
    return rep


def _load_cell(arch: str, shape: str, mesh: str) -> Dict:
    from repro.launch.dryrun import cell_path, run_cell

    full = cell_path(arch, shape, mesh)
    path = full if full.exists() else cell_path(arch, shape, mesh,
                                                REDUCED_TAG)
    if not path.exists():
        rec = run_cell(arch, shape, multi_pod=(mesh == "2x16x16"),
                       reduced=True)
        if rec.get("status") != "ok":
            raise LmProxyError(
                f"could not regenerate dry-run cell {arch}/{shape}/{mesh}: "
                f"status={rec.get('status')!r} "
                f"{rec.get('reason', rec.get('error', ''))}")
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(rec, indent=1))
    try:
        rec = json.loads(path.read_text())
    except ValueError as e:
        raise LmProxyError(f"unparseable dry-run cell {path.name}: {e}") \
            from e
    if rec.get("status") != "ok" or "report" not in rec:
        raise LmProxyError(
            f"dry-run cell {path.name} has no usable report "
            f"(status={rec.get('status')!r})")
    return rec


def _cell_result(arch: str, shape: str, mesh: str) -> Dict:
    """Tune + evaluate the proxy for one cell (cached under BENCH_DIR)."""
    rec = _load_cell(arch, shape, mesh)
    reduced = bool(rec.get("reduced"))
    tag = REDUCED_TAG if reduced else ""
    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    cache = BENCH_DIR / f"lmproxy_{arch}_{shape}_{mesh}{tag}.json"
    if cache.exists() and not REFRESH:
        d = json.loads(cache.read_text())
        if "acc" in d and "derived" in d:
            return d
    rep = _report_from_json(rec)
    target = metric_vector(rep)
    full_sim_s = rec["lower_s"] + rec["compile_s"]
    weights = decompose_to_dwarfs(rep)
    proxy = proxy_from_dwarf_weights(
        f"proxy_{arch}_{shape}", weights, base_size=1 << 16, chunk=512)
    res = autotune(proxy, target, tol=0.15, max_iter=_MAX_ITER)
    pp = res.proxy.profile(execute=True, exec_iters=1)
    acc = vector_accuracy(
        target, pp.metrics,
        keys=[k for k in target
              if k.startswith(("mix_", "arithmetic", "vpu_share"))
              and (target[k] > 1e-9 or pp.metrics.get(k, 0) > 1e-9)])
    sim_speedup = full_sim_s / max(pp.simulation_s, 1e-9)
    derived = (f"acc={acc['avg']:.3f};sim_speedup={sim_speedup:.0f}x;"
               f"full_compile_s={full_sim_s:.1f};"
               f"proxy_compile_s={pp.simulation_s:.2f};"
               f"proxy_exec_ms={pp.exec_s*1e3:.1f}"
               + (";reduced" if reduced else ""))
    d = {"name": f"{arch}_{shape}", "acc": acc["avg"],
         "sim_speedup": sim_speedup, "reduced": reduced,
         "attention_weight": weights.get("attention", 0.0),
         "derived": derived, "dag": res.proxy.dag.to_json()}
    cache.write_text(json.dumps(d))
    return d


def lm_proxy_summary() -> Dict:
    """Machine-readable sweep over ACTIVE_CELLS (BENCH_engine.json + gate).

    Raises :class:`LmProxyError` on any missing/unparseable cell — callers
    (benchmarks/run.py, the compile_vs_run gate) exit non-zero on that.
    """
    cells = [_cell_result(*c) for c in ACTIVE_CELLS]
    accs = [c["acc"] for c in cells]
    return {
        "cells": cells,
        "n_cells": len(cells),
        "mean_accuracy": sum(accs) / max(len(accs), 1),
        "min_accuracy": min(accs) if accs else 0.0,
        "n_reduced": sum(1 for c in cells if c["reduced"]),
    }


def bench_lm_proxy() -> List[str]:
    return [csv_row(f"lmproxy/{d['name']}", d["acc"] * 100, d["derived"])
            for d in (_cell_result(*c) for c in ACTIVE_CELLS)]
