"""Beyond-paper benchmark: dwarf proxies of the LM fleet.

For each (arch x shape) dry-run cell, auto-generate a dwarf proxy seeded
from the cell's HLO dwarf decomposition, tune it against the cell's metric
vector, and report (a) metric accuracy and (b) 'architecture simulation'
speedup = cell lower+compile+analyze time / proxy lower+compile+analyze time.
This is the paper's 100x-simulation-cut applied to accelerator-scale
workloads.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from repro.core import (proxy_from_dwarf_weights, vector_accuracy)
from repro.core.autotune import autotune
from repro.core.metrics import CostReport, metric_vector

from .common import BENCH_DIR, REFRESH, csv_row

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

#: cells representative of each family (full sweep is expensive on 1 core)
CELLS = (
    ("qwen2-7b", "train_4k", "16x16"),
    ("kimi-k2-1t-a32b", "train_4k", "16x16"),
    ("xlstm-1.3b", "train_4k", "16x16"),
    ("jamba-1.5-large-398b", "prefill_32k", "16x16"),
    ("whisper-large-v3", "train_4k", "16x16"),
)


def _report_from_json(d: Dict) -> CostReport:
    rep = CostReport()
    r = d["report"]
    import dataclasses as _dc
    fields = {f.name for f in _dc.fields(CostReport)}
    for k, v in r.items():
        if k in fields and isinstance(v, (int, float)):
            setattr(rep, k, float(v))
    rep.op_mix = {k: float(v) for k, v in r.get("op_mix", {}).items()}
    rep.collective_bytes = {k: float(v)
                            for k, v in r.get("collective_bytes", {}).items()}
    return rep


def _dwarf_weights_from_report(rep: CostReport) -> Dict[str, float]:
    from repro.core.profiler import decompose_to_dwarfs
    return decompose_to_dwarfs(rep)


def bench_lm_proxy() -> List[str]:
    rows = []
    for arch, shape, mesh in CELLS:
        cell = DRYRUN_DIR / f"{arch}__{shape}__{mesh}.json"
        if not cell.exists():
            rows.append(csv_row(f"lmproxy/{arch}_{shape}", 0.0,
                                "missing dry-run cell"))
            continue
        cache = BENCH_DIR / f"lmproxy_{arch}_{shape}_{mesh}.json"
        if cache.exists() and not REFRESH:
            d = json.loads(cache.read_text())
            rows.append(csv_row(f"lmproxy/{arch}_{shape}",
                                d["acc"] * 100, d["derived"]))
            continue
        d = json.loads(cell.read_text())
        rep = _report_from_json(d)
        target = metric_vector(rep)
        full_sim_s = d["lower_s"] + d["compile_s"]
        weights = _dwarf_weights_from_report(rep)
        proxy = proxy_from_dwarf_weights(
            f"proxy_{arch}_{shape}", weights, base_size=1 << 16, chunk=512)
        res = autotune(proxy, target, tol=0.15, max_iter=20)
        pp = res.proxy.profile(execute=True, exec_iters=1)
        acc = vector_accuracy(
            target, pp.metrics,
            keys=[k for k in target
                  if k.startswith(("mix_", "arithmetic", "vpu_share"))
                  and (target[k] > 1e-9 or pp.metrics.get(k, 0) > 1e-9)])
        sim_speedup = full_sim_s / max(pp.simulation_s, 1e-9)
        derived = (f"acc={acc['avg']:.3f};sim_speedup={sim_speedup:.0f}x;"
                   f"full_compile_s={full_sim_s:.1f};"
                   f"proxy_compile_s={pp.simulation_s:.2f};"
                   f"proxy_exec_ms={pp.exec_s*1e3:.1f}")
        cache.write_text(json.dumps({"acc": acc["avg"], "derived": derived,
                                     "dag": res.proxy.dag.to_json()}))
        rows.append(csv_row(f"lmproxy/{arch}_{shape}", acc["avg"] * 100,
                            derived))
    return rows
