"""Serving-engine benchmark: tail-latency SLOs under a deterministic
request stream, and the micro-batching capacity axis.

Emitted as the ``serve_sweep`` section of ``BENCH_engine.json``:

* **steady state** — a seeded open-loop Poisson trace over a mixed proxy
  working set, served twice after :meth:`ServingEngine.warmup`; reports
  P50/P95/P99 latency, queue wait, time to first result and sustained
  throughput.  ``steady_state_retraces`` (hard gate: must be 0) counts
  XLA traces across both passes — the serving restatement of the
  compile-once contract.
* **capacity** — everything arrives at once (``burst_trace(bursts=1)``);
  paired reps of micro-batched open-loop serving vs the closed-loop
  sequential baseline give ``batch_speedup_x`` as a median of paired
  per-rep makespan ratios (machine drift hits both alike — the
  baseline-gateable form).  Not hard-floored at 1.0 — and expected
  **below** 1.0 on a single-device CPU host: a vmapped chunk pays
  max-trips × lane-width on one device, so request batching only wins
  with device parallelism (the sharded MPI/Spark serve path) or when
  dispatch overhead dominates.  That is exactly why the engine's
  *default* chunk size is device-aware (1 on single-device hosts); the
  bench pins ``REPRO_BENCH_SERVE_BUCKET`` > 1 to keep the vmapped path
  exercised, and the committed-baseline ratio gate catches decay of the
  ratio itself, whichever side of 1.0 the hardware puts it on.
* **virtual reference** — the deterministic cost-model clock's
  percentiles for the same trace: machine-independent queueing structure.
"""

from __future__ import annotations

import math
import os
from statistics import median
from typing import Dict

from repro.api.stack import OpenMPStack
from repro.faults import FaultPlan, default_fault_rate
from repro.serve.engine import ServingEngine, burst_trace, poisson_trace

#: mixed working set: two big-data proxies plus the lm_decode AI proxy —
#: the steady-state zero-retrace gate must hold across the heterogeneous
#: (attention/scan/top-k) request stream, not just the paper's Table-3 set
SERVE_MIX = ("terasort", "kmeans", "lm_decode")
N_REQUESTS = int(os.environ.get("REPRO_BENCH_SERVE_REQUESTS", "24"))
RATE_RPS = float(os.environ.get("REPRO_BENCH_SERVE_RATE", "200"))
MAX_BATCH = int(os.environ.get("REPRO_BENCH_SERVE_MAX_BATCH", "8"))
SERVE_REPS = int(os.environ.get("REPRO_BENCH_SERVE_REPS", "3"))
#: chunk size pinned explicitly: the capacity axis compares a vmapped
#: request chunk against sequential dispatches, which needs chunks > 1
#: even on a single-device CPU host
BUCKET = int(os.environ.get("REPRO_BENCH_SERVE_BUCKET",
                            str(min(4, MAX_BATCH))))


def bench_serve_sweep() -> Dict[str, object]:
    stack = OpenMPStack()           # fresh instance: cold-compile accounting
    eng = ServingEngine(stack=stack, max_batch=MAX_BATCH, bucket_size=BUCKET)
    open_trace = poisson_trace(n=N_REQUESTS, rate_rps=RATE_RPS, seed=0,
                               mix=SERVE_MIX)
    capacity_trace = burst_trace(n=N_REQUESTS, bursts=1, seed=0,
                                 mix=SERVE_MIX)

    warm = eng.warmup(open_trace)

    # steady state: warm passes over the open-loop trace; percentiles from
    # the last pass, the zero-retrace contract over all of them
    steady = None
    steady_retraces = steady_cold = 0
    for _ in range(2):
        steady = eng.serve(open_trace, clock="wall", mode="open")
        steady_retraces += steady.retraces
        steady_cold += steady.cold_dispatches

    # capacity: paired micro-batched vs sequential makespans on the burst
    open_times, closed_times = [], []
    for _ in range(max(SERVE_REPS, 1)):
        open_times.append(
            eng.serve(capacity_trace, clock="wall", mode="open").makespan_s)
        closed_times.append(
            eng.serve(capacity_trace, clock="wall", mode="closed").makespan_s)
    batch_speedup = median(c / max(o, 1e-9)
                           for o, c in zip(open_times, closed_times))

    virtual = eng.serve(open_trace, clock="virtual", mode="open")
    dom = stack.exec_domain()

    return {
        "mix": list(SERVE_MIX),
        "requests": N_REQUESTS,
        "rate_rps": RATE_RPS,
        "max_batch": MAX_BATCH,
        "bucket_size": BUCKET,
        "warmup_structures": warm["structures"],
        "warmup_compiles": warm["compiles"],
        # SLO surface (steady-state wall clock)
        "latency_p50_s": steady.latency_s["p50"],
        "latency_p95_s": steady.latency_s["p95"],
        "latency_p99_s": steady.latency_s["p99"],
        "queue_wait_p95_s": steady.queue_wait_s["p95"],
        "service_p50_s": steady.service_s["p50"],
        "time_to_first_result_s": steady.time_to_first_result_s,
        "throughput_rps": steady.throughput_rps,
        "makespan_s": steady.makespan_s,
        "dispatches": steady.dispatches,
        "batch_hist": {str(k): v
                       for k, v in sorted(steady.batch_hist.items())},
        # the serving compile-once contract (hard-gated == 0)
        "steady_state_retraces": steady_retraces,
        "steady_state_cold_dispatches": steady_cold,
        # capacity axis (baseline-gated ratio; < 1 is expected on a
        # single-device CPU host — see module docstring)
        "batch_speedup_x": batch_speedup,
        "open_makespan_s": min(open_times),
        "closed_makespan_s": min(closed_times),
        # machine-independent queueing reference
        "virtual_latency_p50_s": virtual.latency_s["p50"],
        "virtual_latency_p99_s": virtual.latency_s["p99"],
        "virtual_throughput_rps": virtual.throughput_rps,
        # pool / resource posture after the sweep
        "pool_hits": dom.stats["hits"],
        "pool_misses": dom.stats["misses"],
        "pool_evictions": dom.stats["evictions"],
        "host_rss_peak_bytes": steady.resources.get(
            "host_rss_peak_bytes", 0.0),
    }


def bench_serve_faults() -> Dict[str, object]:
    """The ``serve_faults`` section: resilient serving under a seeded
    chaos plan.

    The injected executor-failure rate comes from ``REPRO_FAULT_RATE``
    (CI's ``chaos`` matrix leg sets it; default 0.2 so the section always
    exercises the recovery path), with stragglers at half that rate.
    Eviction storms are deliberately **excluded** here: the run is gated
    on ``steady_state_retraces == 0`` (injected failures and stragglers
    must never force a recompile), and a storm's whole point is a
    recompile — tests/test_serving_faults.py covers it separately.

    Hard gates (enforced by benchmarks/compile_vs_run.py):
    ``lost_requests == 0`` and ``steady_state_retraces == 0`` under
    injection.  Also reports the partial-chunk timeout-flush P99 win on a
    sparse trace (flush vs hold-until-full-chunk, deterministic virtual
    clock) and a chaos bit-reproducibility check."""
    fault_rate = default_fault_rate() or 0.2
    stack = OpenMPStack()
    eng = ServingEngine(stack=stack, max_batch=MAX_BATCH, bucket_size=BUCKET)
    trace = poisson_trace(n=N_REQUESTS, rate_rps=RATE_RPS, seed=0,
                          mix=SERVE_MIX)
    plan = FaultPlan.sample(N_REQUESTS, seed=1, failure_rate=fault_rate,
                            straggler_rate=fault_rate / 2)
    eng.warmup(trace)
    eng.serve(trace, clock="wall")              # warm pass, fault-free
    chaos = eng.serve(trace, clock="wall", faults=plan)

    # flush policy: sparse arrivals, full-chunk hold vs timeout flush
    # (virtual clock — the machine-independent form of the P99 claim)
    sparse = poisson_trace(n=N_REQUESTS, rate_rps=0.025, seed=2,
                           mix=(SERVE_MIX[0],))
    hold_eng = ServingEngine(stack=stack, max_batch=MAX_BATCH,
                             bucket_size=MAX_BATCH,
                             batch_wait_s=math.inf)
    flush_eng = ServingEngine(stack=stack, max_batch=MAX_BATCH,
                              bucket_size=MAX_BATCH, batch_wait_s=0.05)
    hold = hold_eng.serve(sparse, clock="virtual")
    flush = flush_eng.serve(sparse, clock="virtual")

    # chaos determinism: same plan, virtual clock, twice
    v1 = eng.serve(trace, clock="virtual", faults=plan)
    v2 = eng.serve(trace, clock="virtual", faults=plan)
    d1, d2 = v1.to_json(), v2.to_json()
    d1.pop("resources"), d2.pop("resources")

    return {
        "fault_rate": fault_rate,
        "requests": N_REQUESTS,
        "fault_plan": plan.summary(),
        # hard-gated invariants
        "lost_requests": chaos.lost_requests,
        "steady_state_retraces": chaos.retraces,
        # recovery accounting
        "failures": chaos.failures,
        "retries": chaos.retries,
        "status_counts": chaos.status_counts(),
        "degraded_dispatches": chaos.degraded_dispatches,
        "breaker_trips": chaos.breaker_trips,
        "chaos_latency_p99_s": chaos.latency_s["p99"],
        "chaos_throughput_rps": chaos.throughput_rps,
        # partial-chunk timeout flush (virtual, deterministic)
        "hold_p99_s": hold.latency_s["p99"],
        "flush_p99_s": flush.latency_s["p99"],
        "flush_p99_improvement_x": hold.latency_s["p99"]
        / max(flush.latency_s["p99"], 1e-12),
        "timeout_flushes": flush.timeout_flushes,
        # seeded chaos must be bit-reproducible under the virtual clock
        "virtual_chaos_deterministic": d1 == d2,
        "pool_invalidations": stack.exec_domain().stats["invalidations"],
        "pool_failures": stack.exec_domain().stats["failures"],
    }


if __name__ == "__main__":
    import json
    print(json.dumps({"serve_sweep": bench_serve_sweep(),
                      "serve_faults": bench_serve_faults()}, indent=1))
