"""Compile-once/run-many engine benchmark: trace counts, first-call vs
steady-state time, and autotune-sweep wall time on a reference Table-3
proxy — against the pre-PR execution model (rebuild + re-jit per run,
whole-program lower+compile per tuner measurement).

Emits ``BENCH_engine.json`` at the repo root so future PRs have a perf
trajectory to regress against; also prints the harness CSV rows.
"""

from __future__ import annotations

import json
import os
import time
from statistics import median
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.api import ProxySpec, cache_stats, get_stack
from repro.core import engine
from repro.core.autotune import AutoTuner
from repro.core.dag import (_accumulate, _gather_inputs, _init_sources,
                            _terminals)
from repro.core.dwarfs import get_component
from repro.core.dwarfs.base import fit_buffer
from repro.core.workloads import PROXY_SPECS

from .common import ROOT, csv_row

BENCH_JSON = ROOT / "BENCH_engine.json"

#: reference proxy (paper Table 3) and sweep shape
REFERENCE = "terasort"
N_STEADY = int(os.environ.get("REPRO_BENCH_STEADY_ITERS", "8"))
SWEEP_WEIGHTS = (1, 2, 4, 8, 16, 32, 64)
TUNE_ITERS = int(os.environ.get("REPRO_BENCH_TUNE_ITERS", "6"))


def _reference_proxy():
    return ProxySpec.from_json(PROXY_SPECS[REFERENCE]).to_benchmark()


def _seed_build(dag):
    """The seed engine's execution form, reproduced faithfully as the
    pre-PR baseline: weight repeats Python-unrolled (graph size O(sum of
    weights)), the whole fn rebuilt and re-jitted per parameter step."""
    dag.validate()
    edges = dag._rounded_edges()
    sources, sink = dict(dag.sources), dag.sink

    def run(rng):
        nodes = _init_sources(sources, rng)
        for ei, e in enumerate(edges):
            x = _gather_inputs(e, [nodes[s] for s in e.src])
            comp = get_component(e.component)
            if e.params.weight == 0:
                out = fit_buffer(x, e.params.data_size)
            else:
                out = x
                for w in range(e.params.weight):      # unrolled repeats
                    r = jax.random.fold_in(rng, 10_000 + 131 * ei + w)
                    out = comp(fit_buffer(out, e.params.data_size),
                               e.params, r)
            nodes[e.dst] = _accumulate(nodes.get(e.dst), out)
        if sink is not None:
            return jnp.sum(nodes[sink])
        return sum(jnp.sum(nodes[t]) for t in _terminals(edges))

    return run


def bench_engine_run_path() -> Dict[str, float]:
    """First call (compile) vs steady state through the executable cache."""
    stack = get_stack("openmp")
    proxy = _reference_proxy()
    rng = jax.random.PRNGKey(0)
    t0 = cache_stats()["traces"]
    first = stack.run(proxy, rng=rng).wall_s
    steady = median(stack.run(proxy, rng=rng).wall_s
                    for _ in range(N_STEADY))
    return {
        "first_call_s": first,
        "steady_state_s": steady,
        "compile_amortization_x": first / max(steady, 1e-9),
        "traces": cache_stats()["traces"] - t0,   # must be 1 (cold only)
    }


def bench_weight_sweep() -> Dict[str, float]:
    """Stepping an edge weight across the sweep: cached executable vs the
    pre-PR model (fresh ``build()`` + ``jax.jit`` per step = retrace)."""
    stack = get_stack("openmp")
    rng = jax.random.PRNGKey(0)

    proxy = _reference_proxy()
    stack.run(proxy, rng=rng)                     # warm the cache
    t0 = cache_stats()["traces"]
    t = time.perf_counter()
    for w in SWEEP_WEIGHTS:
        proxy.dag.edges[2].params.weight = w      # quick_sort edge
        stack.run(proxy, rng=rng)
    engine_s = time.perf_counter() - t
    engine_traces = cache_stats()["traces"] - t0

    pre = _reference_proxy()
    t = time.perf_counter()
    for w in SWEEP_WEIGHTS:
        pre.dag.edges[2].params.weight = w
        out = jax.jit(_seed_build(pre.dag))(rng)  # the seed's per-step path
        jax.block_until_ready(out)
    pre_pr_s = time.perf_counter() - t

    return {
        "steps": len(SWEEP_WEIGHTS),
        "engine_s": engine_s,
        "engine_retraces": engine_traces,
        "pre_pr_s": pre_pr_s,
        "speedup_x": pre_pr_s / max(engine_s, 1e-9),
    }


def bench_autotune_sweep() -> Dict[str, float]:
    """Whole autotune sweeps, engine measurement vs legacy per-step
    whole-program profiling."""
    target = engine.measure(_reference_proxy().dag)

    def _tune(measurement: str) -> float:
        tuner = AutoTuner(target, tol=0.05, max_iter=TUNE_ITERS,
                          measurement=measurement)
        proxy = _reference_proxy()
        proxy.dag.edges[2].params.weight = 1      # detuned start
        proxy.dag.edges[3].params.weight = 8
        t = time.perf_counter()
        tuner.tune(proxy)
        return time.perf_counter() - t

    engine_s = _tune("engine")
    profile_s = _tune("profile")
    return {
        "max_iter": TUNE_ITERS,
        "engine_s": engine_s,
        "profile_s": profile_s,
        "speedup_x": profile_s / max(engine_s, 1e-9),
    }


def bench_compile_vs_run() -> List[str]:
    run_path = bench_engine_run_path()
    sweep = bench_weight_sweep()
    tune = bench_autotune_sweep()
    payload = {
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "reference_proxy": REFERENCE,
        "run_path": run_path,
        "weight_sweep": sweep,
        "autotune_sweep": tune,
        "engine_stats": engine.stats(),
        "stack_cache_stats": cache_stats(),
    }
    BENCH_JSON.write_text(json.dumps(payload, indent=1) + "\n")
    return [
        csv_row("engine/run_path", run_path["steady_state_s"] * 1e6,
                f"first_s={run_path['first_call_s']:.3f};"
                f"steady_s={run_path['steady_state_s']:.4f};"
                f"amortization={run_path['compile_amortization_x']:.0f}x;"
                f"traces={run_path['traces']:.0f}"),
        csv_row("engine/weight_sweep", sweep["engine_s"] * 1e6,
                f"engine_s={sweep['engine_s']:.3f};"
                f"pre_pr_s={sweep['pre_pr_s']:.3f};"
                f"speedup={sweep['speedup_x']:.1f}x;"
                f"retraces={sweep['engine_retraces']:.0f}"),
        csv_row("engine/autotune_sweep", tune["engine_s"] * 1e6,
                f"engine_s={tune['engine_s']:.3f};"
                f"profile_s={tune['profile_s']:.3f};"
                f"speedup={tune['speedup_x']:.1f}x"),
    ]


if __name__ == "__main__":
    for row in bench_compile_vs_run():
        print(row)
    print(f"wrote {BENCH_JSON}")
