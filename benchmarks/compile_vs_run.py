"""Compile-once/run-many engine benchmark: trace counts, first-call vs
steady-state time, and autotune-sweep wall time on a reference Table-3
proxy — against the pre-PR execution model (rebuild + re-jit per run,
whole-program lower+compile per tuner measurement).

Emits ``BENCH_engine.json`` at the repo root so future PRs have a perf
trajectory to regress against; also prints the harness CSV rows.

Perf gate: the run **fails (non-zero exit)** when the compile-once
contract regresses — ``population_retraces > 0`` — or when bucketed
population execution loses to the sequential per-candidate loop
(``exec_speedup_x < 1``); CI's smoke step keys off the exit code.

Two further gates ride on top:

* **bench-baseline regression** — the committed ``BENCH_engine.json`` is
  the baseline; a fresh run whose ``population_sweep.eval_speedup_x`` or
  ``exec_speedup_x`` drops more than ``REPRO_BENCH_REGRESSION_FRAC``
  (default 20%) below it fails.  ``REPRO_BENCH_BASELINE`` points at an
  alternate baseline file; an empty value disables the comparison.
* **structure_sweep** — the structural tuner must beat the weight-only
  population tuner on a fidelity target reachable only by a structure
  change, with zero engine retraces and zero new body compiles once the
  component pool is profiled.
* **serve_sweep** — the serving engine must hold the compile-once
  contract under a warmed mixed-proxy request stream
  (``steady_state_retraces == 0``, hard gate) and its micro-batch
  capacity ratio ``batch_speedup_x`` is baseline-gated like the
  population speedups (see :mod:`benchmarks.serve_bench`).
* **megakernel_sweep** — a mega-eligible fused chain must be
  bit-identical across the one-kernel Pallas lowering, the
  ``fori_loop``+``switch`` path, and the unfused plan, with zero
  retraces across dynamic weight steps; on a leg whose backends resolve
  pallas with the megakernel armed, at least one dispatch must actually
  take the one-kernel lowering (``mega_dispatches > 0``).
  ``stage_speedup_x`` is recorded, not value-gated (CPU CI runs the
  Pallas interpreter).
* **serve_faults** — resilient serving under a seeded chaos plan
  (injected executor failures + stragglers at ``REPRO_FAULT_RATE`` —
  CI's ``chaos`` leg): hard gates ``lost_requests == 0`` and
  ``steady_state_retraces == 0`` under injection, plus the seeded
  virtual-clock chaos run must be bit-reproducible.
* **ai_structure_sweep** — the structural tuner must reach an
  lm_train-style target (``ai_fidelity_harness``) by *inserting* an
  attention/recurrent dwarf component, again with zero engine traces
  and zero new body compiles warm.
* **distill_sweep** — the measurement-to-proxy loop: every
  ``PROXY_SPECS`` member's measured ``fingerprint`` must reproduce its
  hand-measured metric dict exactly, and ``StructuralTuner`` targeted at
  the fingerprint must recover a deviation ≤ the hand-declared-target
  run's with zero engine traces and zero new body compiles warm; the
  fingerprint suite then subsets (``core/subset.py``) with full
  coverage (every member within its cluster's recorded bound) and the
  compression ratio lands in the payload.
* **lm_proxy** — the LM-fleet proxy bench must produce non-zero
  accuracy rows for every active dry-run cell (a missing cell is
  regenerated at reduced scale; an unregenerable one raises), with
  ``mean_accuracy`` baseline-gated like the speedups.
"""

from __future__ import annotations

import json
import os
import time
from statistics import median
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ParamSpace, ProxySpec, cache_stats, get_stack
from repro.core import engine, schedule
from repro.core.autotune import AutoTuner, PopulationTuner
from repro.core.dag import (Edge, ProxyDAG, _accumulate, _gather_inputs,
                            _init_sources, _terminals)
from repro.core.dwarfs import ComponentParams, get_component
from repro.core.dwarfs.base import fit_buffer
from repro.core.proxy import ProxyBenchmark
from repro.core.structsearch import (StructuralTuner, ai_fidelity_harness,
                                     structural_fidelity_harness)
from repro.core.workloads import PROXY_SPECS

from .common import ROOT, csv_row
from .lm_proxy import lm_proxy_summary
from .serve_bench import bench_serve_faults, bench_serve_sweep

BENCH_JSON = ROOT / "BENCH_engine.json"

#: reference proxy (paper Table 3) and sweep shape
REFERENCE = "terasort"
N_STEADY = int(os.environ.get("REPRO_BENCH_STEADY_ITERS", "8"))
SWEEP_WEIGHTS = (1, 2, 4, 8, 16, 32, 64)
TUNE_ITERS = int(os.environ.get("REPRO_BENCH_TUNE_ITERS", "6"))
N_POP = int(os.environ.get("REPRO_BENCH_POPULATION", "16"))
POP_STEPS = int(os.environ.get("REPRO_BENCH_POP_STEPS", "4"))
EXEC_REPS = int(os.environ.get("REPRO_BENCH_EXEC_REPS", "3"))
#: the eval (scoring) pass is ~1ms of numpy — time EVAL_INNER passes per
#: rep and take the median of *paired* per-rep ratios over EVAL_REPS, or
#: timer noise and machine-speed drift alone trip the 20% baseline gate
EVAL_REPS = int(os.environ.get("REPRO_BENCH_EVAL_REPS", "5"))
EVAL_INNER = int(os.environ.get("REPRO_BENCH_EVAL_INNER", "8"))
STRUCT_BUDGET = int(os.environ.get("REPRO_BENCH_STRUCT_BUDGET", "96"))
#: candidate budget per distillation run (two tuner runs per proxy — the
#: hand-target run and the fingerprint-target replay — times six proxies,
#: so the default stays small)
DISTILL_BUDGET = int(os.environ.get("REPRO_BENCH_DISTILL_BUDGET", "48"))
#: clusters kept when subsetting the distilled fingerprint suite
DISTILL_CLUSTERS = int(os.environ.get("REPRO_BENCH_DISTILL_CLUSTERS", "3"))

#: >20% drop of a gated speedup vs the committed baseline fails the run
REGRESSION_FRAC = float(os.environ.get("REPRO_BENCH_REGRESSION_FRAC", "0.2"))
#: hard floor for ``exec_speedup_x``: the bucketed population path must
#: not *lose* to the sequential loop.  On a small shared host the two
#: paths are near parity (a vmapped bucket cannot out-parallelize two
#: cores) and the paired-ratio median jitters a few percent around 1.0,
#: so the floor carries a small noise margin — catastrophic losses are
#: what it exists to catch; gradual decay is the baseline gate's job
EXEC_FLOOR = float(os.environ.get("REPRO_BENCH_EXEC_FLOOR", "0.95"))
#: gated ``population_sweep`` fields (speedups are same-machine ratios, so
#: they regress meaningfully even when CI hardware differs from the
#: machine that committed the baseline)
BASELINE_GATED = ("eval_speedup_x", "exec_speedup_x")
#: gated ``serve_sweep`` fields — like the population speedups these are
#: same-machine ratios (micro-batched vs sequential makespans of one
#: paired run), comparable across runs on like hardware/backends
SERVE_GATED = ("batch_speedup_x",)


def _reference_proxy():
    return ProxySpec.from_json(PROXY_SPECS[REFERENCE]).to_benchmark()


def _seed_build(dag):
    """The seed engine's execution form, reproduced faithfully as the
    pre-PR baseline: weight repeats Python-unrolled (graph size O(sum of
    weights)), the whole fn rebuilt and re-jitted per parameter step."""
    dag.validate()
    edges = dag._rounded_edges()
    sources, sink = dict(dag.sources), dag.sink

    def run(rng):
        nodes = _init_sources(sources, rng)
        for ei, e in enumerate(edges):
            x = _gather_inputs(e, [nodes[s] for s in e.src])
            comp = get_component(e.component)
            if e.params.weight == 0:
                out = fit_buffer(x, e.params.data_size)
            else:
                out = x
                for w in range(e.params.weight):      # unrolled repeats
                    r = jax.random.fold_in(rng, 10_000 + 131 * ei + w)
                    out = comp(fit_buffer(out, e.params.data_size),
                               e.params, r)
            nodes[e.dst] = _accumulate(nodes.get(e.dst), out)
        if sink is not None:
            return jnp.sum(nodes[sink])
        return sum(jnp.sum(nodes[t]) for t in _terminals(edges))

    return run


def bench_engine_run_path() -> Dict[str, float]:
    """First call (compile) vs steady state through the executable cache."""
    stack = get_stack("openmp")
    proxy = _reference_proxy()
    rng = jax.random.PRNGKey(0)
    t0 = cache_stats()["traces"]
    first = stack.run(proxy, rng=rng).wall_s
    steady = median(stack.run(proxy, rng=rng).wall_s
                    for _ in range(N_STEADY))
    return {
        "first_call_s": first,
        "steady_state_s": steady,
        "compile_amortization_x": first / max(steady, 1e-9),
        "traces": cache_stats()["traces"] - t0,   # must be 1 (cold only)
    }


def bench_weight_sweep() -> Dict[str, float]:
    """Stepping an edge weight across the sweep: cached executable vs the
    pre-PR model (fresh ``build()`` + ``jax.jit`` per step = retrace)."""
    stack = get_stack("openmp")
    rng = jax.random.PRNGKey(0)

    proxy = _reference_proxy()
    stack.run(proxy, rng=rng)                     # warm the cache
    t0 = cache_stats()["traces"]
    t = time.perf_counter()
    for w in SWEEP_WEIGHTS:
        proxy.dag.edges[2].params.weight = w      # quick_sort edge
        stack.run(proxy, rng=rng)
    engine_s = time.perf_counter() - t
    engine_traces = cache_stats()["traces"] - t0

    pre = _reference_proxy()
    t = time.perf_counter()
    for w in SWEEP_WEIGHTS:
        pre.dag.edges[2].params.weight = w
        out = jax.jit(_seed_build(pre.dag))(rng)  # the seed's per-step path
        jax.block_until_ready(out)
    pre_pr_s = time.perf_counter() - t

    return {
        "steps": len(SWEEP_WEIGHTS),
        "engine_s": engine_s,
        "engine_retraces": engine_traces,
        "pre_pr_s": pre_pr_s,
        "speedup_x": pre_pr_s / max(engine_s, 1e-9),
    }


def bench_autotune_sweep() -> Dict[str, float]:
    """Whole autotune sweeps, engine measurement vs legacy per-step
    whole-program profiling."""
    target = engine.measure(_reference_proxy().dag)

    def _tune(measurement: str) -> float:
        tuner = AutoTuner(target, tol=0.05, max_iter=TUNE_ITERS,
                          measurement=measurement)
        proxy = _reference_proxy()
        proxy.dag.edges[2].params.weight = 1      # detuned start
        proxy.dag.edges[3].params.weight = 8
        t = time.perf_counter()
        tuner.tune(proxy)
        return time.perf_counter() - t

    engine_s = _tune("engine")
    profile_s = _tune("profile")
    return {
        "max_iter": TUNE_ITERS,
        "engine_s": engine_s,
        "profile_s": profile_s,
        "speedup_x": profile_s / max(engine_s, 1e-9),
    }


def _tuner_generation_candidates(space, base, step: int) -> "np.ndarray":
    """A tuner-generation-shaped candidate batch: multiplicative jitter of
    the dynamic leaves around the current point (what an evolution step
    actually draws), not a full log-uniform resample — execution cost of a
    vmapped batched ``while`` is ``max`` over candidates, so the candidate
    spread is part of the workload definition."""
    rs = np.random.RandomState(step)
    dyn = space.dynamic_mask()
    matrix = np.tile(base, (N_POP, 1))
    jitter = rs.uniform(0.5, 2.0, size=(N_POP, int(dyn.sum())))
    matrix[:, dyn] = np.maximum(matrix[:, dyn], 1.0) * jitter
    # clamp only the dynamic columns: static leaves define the shared
    # structure and may legitimately sit outside the nominal bounds
    matrix[:, dyn] = space.clamp(matrix)[:, dyn]
    return matrix


def bench_population_sweep() -> Dict[str, float]:
    """A population-tuner sweep over the reference proxy: per step, score
    all ``N_POP`` candidates (vectorized compositional engine) and execute
    them (one vmapped call) — against the pre-PR sequential loop
    (per-candidate clone + ``engine.measure`` + ``stack.run``).  The
    population path must compile at most as many executables as a single
    candidate and retrace zero times across the sweep."""
    stack = get_stack("openmp")
    rng = jax.random.PRNGKey(0)
    proxy = _reference_proxy()
    space = ParamSpace.from_dag(proxy.dag)
    base = space.values(proxy.dag)
    # a faithful tuner sweep: generation 0 is the log-uniform random-search
    # seed (PopulationTuner's actual first draw — the straggler-heavy batch
    # the bucket schedule exists for), later generations are evolution-step
    # jitter around the current point
    mats = [space.sample_dynamic(N_POP, base, seed=0)] + \
        [_tuner_generation_candidates(space, base, s)
         for s in range(1, POP_STEPS)]

    # executable accounting on *cold* per-instance caches: how many
    # compiles does one candidate cost vs a 16-candidate population?
    from repro.api.stack import OpenMPStack
    m0 = cache_stats()["misses"]
    OpenMPStack().run(proxy, rng=rng)
    single_compiles = cache_stats()["misses"] - m0
    m1 = cache_stats()["misses"]
    OpenMPStack().run_population(proxy, mats[0], space=space)
    population_compiles = cache_stats()["misses"] - m1

    engine.measure(proxy.dag)                   # warm the per-edge caches
    scorer = engine.PopulationScorer(proxy.dag, space)
    scorer(mats[0])                             # warm (nothing to compile)
    stack.run(proxy, rng=rng)                   # warm the shared stack
    stack.run_population(proxy, mats[0], space=space)

    # candidate-evaluation sweep (the tuner scoring hot path); both paths
    # interleaved min-of-reps — the eval pass is ~1ms, far too small for a
    # single-shot time to gate a 20% baseline regression on
    t0 = cache_stats()["traces"]
    e0 = engine.stats()

    def _eval_pop() -> float:
        t = time.perf_counter()
        for _ in range(max(EVAL_INNER, 1)):
            for m in mats:
                scorer(m)
        return (time.perf_counter() - t) / max(EVAL_INNER, 1)

    def _eval_seq() -> float:
        # the pre-PR per-candidate measure loop
        t = time.perf_counter()
        for m in mats:
            for row in m:
                trial = proxy.clone()
                space.apply(trial.dag, row)
                engine.measure(trial.dag)
        return time.perf_counter() - t

    # paired ratios: each rep measures both paths back to back, so CPU
    # frequency / neighbor drift hits numerator and denominator alike;
    # the gated speedup is the median per-rep ratio
    eval_pop_times, eval_seq_times = [], []
    for _ in range(max(EVAL_REPS, 1)):
        eval_pop_times.append(_eval_pop())
        eval_seq_times.append(_eval_seq())
    eval_pop_s, eval_seq_s = min(eval_pop_times), min(eval_seq_times)
    eval_speedup = median(s / max(p, 1e-9)
                          for p, s in zip(eval_pop_times, eval_seq_times))

    def _exec_pop() -> float:
        # bucketed execution sweep (one call per weight stratum; every
        # bucket reuses the single (plan, bucket_size) executable)
        t = time.perf_counter()
        for m in mats:
            stack.run_population(proxy, m, space=space)
        return time.perf_counter() - t

    def _exec_seq() -> float:
        # the pre-PR per-candidate evaluation loop
        t = time.perf_counter()
        for m in mats:
            for row in m:
                trial = proxy.clone()
                space.apply(trial.dag, row)
                stack.run(trial, rng=rng)
        return time.perf_counter() - t

    # interleave the passes so machine drift hits both paths alike; the
    # gated speedup is the median of *paired* per-rep ratios (robust to
    # between-rep frequency drift on a 2-core shared box), the absolute
    # times are the least-noise (min) of each path
    pop_times, seq_times = [], []
    for _ in range(max(EXEC_REPS, 1)):
        pop_times.append(_exec_pop())
        seq_times.append(_exec_seq())
    exec_pop_s, exec_seq_s = min(pop_times), min(seq_times)
    exec_speedup = median(s / max(p, 1e-9)
                          for p, s in zip(pop_times, seq_times))
    pop_retraces = cache_stats()["traces"] - t0
    pop_engine_traces = engine.stats()["traces"] - e0["traces"]

    # the pre-plan vmapped path for reference: one whole-population batch,
    # so every candidate pays the population-wide max trip count
    stack.run_population(proxy, mats[0], space=space, bucket_size=N_POP)
    t = time.perf_counter()
    for m in mats:
        stack.run_population(proxy, m, space=space, bucket_size=N_POP)
    exec_single_batch_s = time.perf_counter() - t

    # population-tuner smoke: a real (tiny) tuning run end to end
    target = engine.measure(_reference_proxy().dag)
    smoke = _reference_proxy()
    smoke.dag.edges[2].params.weight = 1
    smoke.dag.edges[3].params.weight = 8
    t = time.perf_counter()
    res = PopulationTuner(target, tol=0.10, population=8, generations=2,
                          seed=0).tune(smoke)
    tuner_smoke_s = time.perf_counter() - t

    return {
        "population": N_POP,
        "steps": POP_STEPS,
        # candidate evaluation (scoring): the >=5x tuner-throughput axis;
        # the speedups are medians of paired per-rep ratios (gate-stable)
        "eval_population_s": eval_pop_s,
        "eval_sequential_s": eval_seq_s,
        "speedup_x": eval_speedup,
        "eval_speedup_x": eval_speedup,
        # bucketed execution: per-bucket trip bounds recover the
        # sequential-sum cost model (the candidate axis still shards on a
        # mesh); exec_single_batch_s is the old whole-population vmapped
        # path whose wall-clock was max-over-candidates bound
        "exec_population_s": exec_pop_s,
        "exec_sequential_s": exec_seq_s,
        "exec_speedup_x": exec_speedup,
        "exec_single_batch_s": exec_single_batch_s,
        "bucket_speedup_x": exec_single_batch_s / max(exec_pop_s, 1e-9),
        # compile-once contract
        "executables_single_candidate": single_compiles,
        "executables_16_candidates": population_compiles,
        "population_retraces": pop_retraces,
        "population_engine_traces": pop_engine_traces,
        # end-to-end tuner smoke
        "tuner_smoke_s": tuner_smoke_s,
        "tuner_smoke_accuracy": res.final_accuracy.get("avg", 0.0),
        "tuner_smoke_candidates": res.candidates_evaluated,
    }


def bench_plan_sweep() -> Dict[str, object]:
    """ExecutionPlan lowering diagnostics: how many edges fuse per Table-3
    proxy at the live ``REPRO_FUSION_THRESHOLD``, plus the weight-bucket
    schedule of a tuner-generation candidate batch on the reference proxy
    — the per-bucket trip bounds that replace the population-wide max."""
    proxies = {}
    for name in sorted(PROXY_SPECS):
        dag = ProxySpec.from_json(PROXY_SPECS[name]).to_benchmark().dag
        rep = schedule.lower(dag).report()
        proxies[name] = {"edges": rep["edges"], "stages": rep["stages"],
                         "fused_stages": rep["fused_stages"]}
    proxy = _reference_proxy()
    space = ParamSpace.from_dag(proxy.dag)
    mat = space.sample_dynamic(N_POP, space.values(proxy.dag), seed=0)
    plan = schedule.lower(proxy.dag)
    sched = plan.bucket_schedule(
        space.stack_candidates(proxy.dag, mat))
    return {
        "fusion_threshold": schedule.fusion_threshold(),
        "reference_partition": plan.report()["partition"],
        "fused_stage_counts": proxies,
        "population": N_POP,
        "bucket_signature": list(sched.signature),
        "bucket_trip_bounds": sched.trip_bounds(),
        "bucket_valid_counts": [b.valid for b in sched.buckets],
        "bucket_masses": [float(m) for m in sched.bucket_masses()],
        "single_batch_trip_bound": max(sched.trip_bounds() or [0]),
    }


def bench_megakernel_sweep() -> Dict[str, object]:
    """Megakernel contract on a mega-eligible fused chain: engagement
    (per-trace dispatch counts from ``schedule.mega_stats()``), scalar
    parity against the ``fori_loop``+``switch`` path and the unfused
    plan, steady-state retraces across dynamic weight steps, and the
    megakernel-vs-switch stage timing ratio.

    Runs under whatever backend env the CI leg exports.  On a leg whose
    backends resolve XLA (or with ``REPRO_MEGAKERNEL=0``) the stage
    falls back and ``engaged_expected`` is False — everything is still
    recorded, but only the megakernel leg gates ``mega_dispatches > 0``.
    ``stage_speedup_x`` is recorded, never value-gated: on CPU the
    Pallas *interpreter* executes the kernel, so the ratio measures
    interpreter overhead, not accelerator wins (see ROADMAP)."""
    from repro.kernels.dispatch import megakernel_enabled, resolve_backend

    P = lambda w, **e: ComponentParams(data_size=2048, chunk_size=128,
                                       weight=w, extra=e)
    dag = ProxyDAG(
        "bench_mega", {"src": 2048},
        [Edge("quick_sort", ["src"], "a", P(2)),
         Edge("hash", ["a"], "b", P(3, rounds=2)),
         Edge("top_k", ["b"], "c", P(2, k=8)),
         Edge("min_max", ["c"], "out", P(1))],
        "out")
    fused = schedule.lower(dag, threshold=1e30, cache=False)
    unfused = schedule.lower(dag, threshold=0.0, cache=False)
    engaged_expected = (fused.mega_stage_count > 0
                        and megakernel_enabled()
                        and resolve_backend(None) == "pallas")

    space = ParamSpace.from_dag(dag)
    dyns = list(space.unstack_candidates(space.stack_candidates(
        dag, space.sample_dynamic(8, space.values(dag), seed=5))))
    rng = jax.random.PRNGKey(0)

    def jitted(plan, counter):
        pfn = plan.build_parametric()

        def counted(r, d):
            counter["n"] += 1
            return pfn(r, d)

        return jax.jit(counted)

    def steady(fn):
        fn(rng, dyns[0]).block_until_ready()     # warm
        t = time.perf_counter()
        for d in dyns:
            out = fn(rng, d)
        out.block_until_ready()
        return (time.perf_counter() - t) / len(dyns)

    schedule.reset_mega_stats()
    traces = {"n": 0}
    jmega = jitted(fused, traces)
    mega_steady_s = steady(jmega)
    mega_out = np.asarray(jmega(rng, dyns[0]))
    stats = schedule.mega_stats()
    steady_state_retraces = traces["n"] - 1      # first call is the warmup

    # the same fused plan on the fori_loop+switch path (megakernel
    # disarmed), plus the unfused per-edge plan — the parity oracles
    prev = os.environ.get("REPRO_MEGAKERNEL")
    os.environ["REPRO_MEGAKERNEL"] = "0"
    try:
        jswitch = jitted(fused, {"n": 0})
        switch_steady_s = steady(jswitch)
        switch_out = np.asarray(jswitch(rng, dyns[0]))
        unfused_out = np.asarray(jitted(unfused, {"n": 0})(rng, dyns[0]))
    finally:
        if prev is None:
            os.environ.pop("REPRO_MEGAKERNEL", None)
        else:
            os.environ["REPRO_MEGAKERNEL"] = prev

    return {
        "engaged_expected": engaged_expected,
        "mega_stages": fused.mega_stage_count,
        "partition": fused.report()["partition"],
        "mega_dispatches": stats["mega"],
        "fallback_dispatches": stats["fallback"],
        "parity_vs_switch": bool(mega_out == switch_out),
        "parity_vs_unfused": bool(mega_out == unfused_out),
        "steady_state_retraces": steady_state_retraces,
        "weight_steps": len(dyns),
        "mega_steady_s": mega_steady_s,
        "switch_steady_s": switch_steady_s,
        "stage_speedup_x": (switch_steady_s / mega_steady_s
                            if mega_steady_s > 0 else 0.0),
    }


def bench_structure_sweep() -> Dict[str, float]:
    """Structural vs weight-only tuning under one fixed candidate budget,
    on a fidelity target reachable **only** by a structure change: the
    reference pipeline carries an fft stage the detuned seed structure
    lacks entirely, so no re-weighting of the seed's edges can create the
    missing transform channel — the weight-only tuner saturates while the
    structural tuner must insert the edge and converge.  The harness
    (DAGs + component pool) is the one definition shared with
    ``tests/test_fidelity.py`` — ``structural_fidelity_harness`` — so the
    gate and the tier-1 test verify the same contract.  The whole search
    scores through the compositional engine: after the component pool is
    profiled (the warmup dag), structure scoring triggers zero executable
    traces and zero new body compiles."""
    reference, detuned, pool = structural_fidelity_harness()
    size = reference.sources["records"]
    chunk = reference.edges[0].params.chunk_size

    # profile every pool component at the mutation-site shape (extras-free
    # edges, exactly what machine-inserted edges carry) so the search
    # itself compiles nothing
    warmup = ProxyDAG(
        "struct_warmup", {"records": size},
        [Edge(c, ["records"] if i == 0 else [f"w{i - 1}"], f"w{i}",
              ComponentParams(data_size=size, chunk_size=chunk))
         for i, c in enumerate(pool)], f"w{len(pool) - 1}")
    engine.measure(warmup)
    target = engine.measure(reference)

    budget = STRUCT_BUDGET
    t = time.perf_counter()
    weight_only = PopulationTuner(
        target, tol=0.10, population=16,
        generations=max(2, budget // 16), max_candidates=budget,
        seed=0, execute=False).tune(ProxyBenchmark(detuned))
    weight_only_s = time.perf_counter() - t

    e0 = engine.stats()
    t = time.perf_counter()
    structural = StructuralTuner(
        target, tol=0.10, max_candidates=budget, generations=4,
        components=pool, seed=0).tune(ProxyBenchmark(detuned))
    structural_s = time.perf_counter() - t
    e1 = engine.stats()

    return {
        "budget": budget,
        "weight_only_deviation": weight_only.final_deviation,
        "weight_only_candidates": weight_only.candidates_evaluated,
        "weight_only_s": weight_only_s,
        "structural_deviation": structural.final_deviation,
        "structural_converged": float(structural.converged),
        "structures_scored": structural.structures_scored,
        "weight_candidates": structural.weight_candidates,
        "structural_candidates": structural.candidates_evaluated,
        "structural_s": structural_s,
        "structural_generations": structural.generations,
        "best_lineage": structural.best_lineage,
        # the cheap-scoring contract
        "structure_engine_traces": e1["traces"] - e0["traces"],
        "structure_new_body_compiles": structural.new_body_compiles,
    }


def bench_distill_sweep() -> Dict[str, object]:
    """The measurement-to-proxy distillation contract, per proxy:

    1. **Fingerprint fidelity** — ``fingerprint(dag).metrics()`` must
       equal the engine's measured metric dict *exactly* (the channel
       basis is lossless by construction; this gate keeps it so).
    2. **Distilled ≥ hand** — a ``StructuralTuner`` run targeting the
       measured fingerprint must recover a channel deviation no worse
       than the identically-budgeted run targeting the hand-declared
       metric dict, on a detuned (all-weights-1) seed of the same
       structure.
    3. **Zero-cost warm** — the fingerprint-target run replays the same
       deterministic search, so it must hit the process-wide body cache:
       0 engine traces, 0 new body compiles.

    The distilled fingerprint suite then subsets
    (:func:`repro.core.subset.subset_fingerprints`,
    ``DISTILL_CLUSTERS`` representatives): full coverage — every member
    within its cluster's recorded bound — and the compression ratio land
    in the payload."""
    from repro.core.engine import fingerprint
    from repro.core.subset import subset_fingerprints
    from repro.core.workloads import seed_components

    pool = seed_components()
    per: Dict[str, Dict[str, float]] = {}
    fps = []
    kw = dict(tol=0.10, max_candidates=DISTILL_BUDGET, generations=2,
              structure_population=4, mutations_per_parent=2,
              components=pool, seed=0)

    def _detuned(spec):
        bench = spec.to_benchmark()
        for e in bench.dag.edges:
            e.params.extra["weight"] = 1.0
        return bench

    t_total = time.perf_counter()
    for name in sorted(PROXY_SPECS):
        spec = ProxySpec.from_json(PROXY_SPECS[name])
        dag = spec.to_dag()
        hand = engine.measure(dag)               # also warms the bodies
        fp = fingerprint(dag, name=name)
        exact = fp.metrics() == hand
        fps.append(fp)
        hand_res = StructuralTuner(hand, **kw).tune(_detuned(spec))
        e0 = engine.stats()
        fp_res = StructuralTuner(fp, **kw).tune(_detuned(spec))
        e1 = engine.stats()
        per[name] = {
            "fingerprint_exact": float(exact),
            "hand_deviation": hand_res.final_deviation,
            "distilled_deviation": fp_res.final_deviation,
            "engine_traces": e1["traces"] - e0["traces"],
            "new_body_compiles": fp_res.new_body_compiles,
        }
    wall = time.perf_counter() - t_total

    subset = subset_fingerprints(fps, k=min(DISTILL_CLUSTERS, len(fps)),
                                 seed=0)
    full_coverage = all(
        subset.distances[m] <= subset.max_distance[rep] + 1e-12
        for rep, members in subset.clusters.items() for m in members)
    return {
        "budget": DISTILL_BUDGET,
        "proxies": per,
        "wall_s": wall,
        "subset": subset.to_json(),
        "compression_x": subset.compression_x,
        "coverage": subset.coverage,
        "full_coverage": float(full_coverage),
        "representatives": subset.representatives,
    }


def bench_ai_structure_sweep() -> Dict[str, object]:
    """The AI-dwarf structural contract (``ai_fidelity_harness``, shared
    with ``tests/test_ai_dwarfs.py``): an lm_train-style reference whose
    attention stage the detuned seed lacks entirely.  No re-weighting of
    the seed's GEMM edges can create the missing ``mix_attention`` channel
    (exp-gated contractions — see :class:`repro.core.metrics.CostReport`),
    so the tuner must *insert* an attention-class component; and it must do
    so entirely through the compositional engine — zero executable traces
    and zero new body compiles once the pool is profiled."""
    reference, detuned, pool = ai_fidelity_harness()
    size = reference.sources["tokens"]
    chunk = reference.edges[0].params.chunk_size

    warmup = ProxyDAG(
        "ai_struct_warmup", {"tokens": size},
        [Edge(c, ["tokens"] if i == 0 else [f"w{i - 1}"], f"w{i}",
              ComponentParams(data_size=size, chunk_size=chunk))
         for i, c in enumerate(pool)], f"w{len(pool) - 1}")
    engine.measure(warmup)
    target = engine.measure(reference)
    from repro.core.autotune import _deviations
    seed_dev = max((abs(d) for d in _deviations(
        target, engine.measure(detuned),
        [k for k in target if abs(target[k]) > 1e-12]).values()),
        default=float("inf"))

    e0 = engine.stats()
    t = time.perf_counter()
    res = StructuralTuner(target, tol=0.10, max_candidates=STRUCT_BUDGET,
                          generations=4, components=pool,
                          seed=0).tune(ProxyBenchmark(detuned))
    wall = time.perf_counter() - t
    e1 = engine.stats()

    from repro.core.dwarfs import REGISTRY
    ai_names = {n for n, c in REGISTRY.items()
                if c.dwarf in ("attention", "gemm", "recurrent")}
    # components only a structural insertion can contribute: the seed
    # already carries gemm_train edges, so the gate keys on the
    # attention/recurrent classes (the exp-gated ones)
    attn_names = {n for n, c in REGISTRY.items()
                  if c.dwarf in ("attention", "recurrent")}
    used = {e.component for e in res.proxy.dag.edges}
    return {
        "budget": STRUCT_BUDGET,
        "deviation": res.final_deviation,
        "seed_deviation": seed_dev,
        "converged": float(res.converged),
        "structures_scored": res.structures_scored,
        "weight_candidates": res.weight_candidates,
        "ai_components_used": sorted(used & ai_names),
        "attention_class_used": sorted(used & attn_names),
        "best_lineage": res.best_lineage,
        "wall_s": wall,
        "engine_traces": e1["traces"] - e0["traces"],
        "new_body_compiles": res.new_body_compiles,
    }


def _resolved_backend() -> str:
    """The kernel backend this run measures under — part of the baseline
    identity: interpret-mode Pallas shifts absolute per-candidate costs,
    so cross-backend speedup comparisons are not regressions."""
    from repro.kernels.dispatch import default_interpret, resolve_backend
    backend = resolve_backend(None)
    if backend == "pallas" and default_interpret():
        return "pallas-interpret"
    return backend


def _load_baseline() -> Dict:
    """The **committed** ``BENCH_engine.json`` (or
    ``REPRO_BENCH_BASELINE``; empty override disables).  Read from git
    HEAD so repeated local runs — which overwrite the on-disk file — keep
    gating against the committed numbers instead of self-ratcheting on
    their own last (possibly lucky) measurement; falls back to the
    on-disk file outside a git checkout (CI checkouts are identical)."""
    path_env = os.environ.get("REPRO_BENCH_BASELINE")
    if path_env is not None and path_env.strip() == "":
        return {}
    if path_env:
        # an explicitly named baseline must load — a typo'd path silently
        # disabling the gate is exactly the rot the gate exists to stop
        with open(path_env) as f:
            return json.load(f)
    import subprocess
    try:
        committed = subprocess.run(
            ["git", "show", f"HEAD:{BENCH_JSON.name}"], cwd=str(ROOT),
            capture_output=True, text=True, timeout=30)
        if committed.returncode == 0:
            return json.loads(committed.stdout)
    except (OSError, ValueError, subprocess.SubprocessError):
        pass
    try:
        with open(BENCH_JSON) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _baseline_regressions(population: Dict[str, float],
                          baseline: Dict) -> List[str]:
    """>REGRESSION_FRAC drops of the gated population-sweep speedups vs
    the committed baseline.  Skipped when the baseline was measured under
    a different kernel backend (e.g. the pallas-interpret CI leg vs an
    XLA-measured baseline): the ratios are only comparable like-for-like,
    and the hard ``exec_speedup_x >= 1`` floor still applies everywhere."""
    base_backend = baseline.get("kernel_backend", "xla")
    if baseline and base_backend != _resolved_backend():
        return []
    base_pop = baseline.get("population_sweep", {})
    failures = []
    for key in BASELINE_GATED:
        base = base_pop.get(key)
        if base is None and key == "eval_speedup_x":
            base = base_pop.get("speedup_x")     # pre-alias baselines
        new = population.get(key)
        if not base or base <= 0 or new is None:
            continue
        if new < base * (1.0 - REGRESSION_FRAC):
            failures.append(
                f"population_sweep.{key}={new:.2f} regressed "
                f">{REGRESSION_FRAC:.0%} vs committed baseline {base:.2f}")
    return failures


def _serve_baseline_regressions(serve: Dict[str, object],
                                baseline: Dict) -> List[str]:
    """>REGRESSION_FRAC drops of the gated serve-sweep ratios vs the
    committed baseline, with the same cross-backend skip as the
    population gate (the hard ``steady_state_retraces == 0`` floor still
    applies everywhere).  Also skipped when the workload *shape* differs
    (request count / batching knobs): the capacity ratio depends on how
    full the micro-batch chunks run — a 12-request CI leg is not
    comparable to a 24-request committed baseline."""
    base_backend = baseline.get("kernel_backend", "xla")
    if baseline and base_backend != _resolved_backend():
        return []
    base_serve = baseline.get("serve_sweep", {})
    shape_keys = ("requests", "max_batch", "bucket_size", "rate_rps", "mix")
    if base_serve and any(base_serve.get(k) != serve.get(k)
                          for k in shape_keys):
        return []
    failures = []
    for key in SERVE_GATED:
        base, new = base_serve.get(key), serve.get(key)
        if not base or base <= 0 or new is None:
            continue
        if new < base * (1.0 - REGRESSION_FRAC):
            failures.append(
                f"serve_sweep.{key}={new:.2f} regressed "
                f">{REGRESSION_FRAC:.0%} vs committed baseline {base:.2f}")
    return failures


def _lm_baseline_regressions(lm: Dict[str, object],
                             baseline: Dict) -> List[str]:
    """>REGRESSION_FRAC drop of lm_proxy ``mean_accuracy`` vs the committed
    baseline.  Like the other baseline gates this only compares
    like-for-like: same kernel backend and the same cell set — including
    each cell's reduced-ness, since a reduced (CPU-smoke) cell and a full
    512-chip cell are different targets.  The hard per-cell ``acc > 0``
    floor applies everywhere regardless."""
    base_backend = baseline.get("kernel_backend", "xla")
    if baseline and base_backend != _resolved_backend():
        return []
    base_lm = baseline.get("lm_proxy", {})
    ident = [(c["name"], bool(c.get("reduced"))) for c in lm["cells"]]
    base_ident = [(c["name"], bool(c.get("reduced")))
                  for c in base_lm.get("cells", [])]
    if not base_ident or ident != base_ident:
        return []
    base, new = base_lm.get("mean_accuracy"), lm.get("mean_accuracy")
    if base and base > 0 and new is not None and \
            new < base * (1.0 - REGRESSION_FRAC):
        return [f"lm_proxy.mean_accuracy={new:.3f} regressed "
                f">{REGRESSION_FRAC:.0%} vs committed baseline {base:.3f}"]
    return []


class BenchGateError(RuntimeError):
    """A perf-contract regression the harness must not let rot silently."""


def bench_compile_vs_run() -> List[str]:
    baseline = _load_baseline()    # before this run overwrites the file
    run_path = bench_engine_run_path()
    sweep = bench_weight_sweep()
    tune = bench_autotune_sweep()
    population = bench_population_sweep()
    plan_sweep = bench_plan_sweep()
    mega = bench_megakernel_sweep()
    structure = bench_structure_sweep()
    ai_structure = bench_ai_structure_sweep()
    distill = bench_distill_sweep()
    serve = bench_serve_sweep()
    serve_faults = bench_serve_faults()
    # raises LmProxyError on a missing/unparseable dry-run cell — a dead
    # bench is a harness failure, not a quiet 0.0 csv row
    lm = lm_proxy_summary()
    failures = []
    if serve["steady_state_retraces"] > 0:
        failures.append(
            f"steady_state_retraces={serve['steady_state_retraces']} "
            f"(serving compile-once contract broken: a warmed request "
            f"stream retraced)")
    failures += _serve_baseline_regressions(serve, baseline)
    if serve_faults["lost_requests"] > 0:
        failures.append(
            f"serve_faults.lost_requests={serve_faults['lost_requests']} "
            f"(a request vanished under injected failures — the zero-loss "
            f"invariant is broken)")
    if serve_faults["steady_state_retraces"] > 0:
        failures.append(
            f"serve_faults.steady_state_retraces="
            f"{serve_faults['steady_state_retraces']} (injected failures "
            f"and stragglers must recover without retracing)")
    if not serve_faults["virtual_chaos_deterministic"]:
        failures.append(
            "serve_faults.virtual_chaos_deterministic=False (the same "
            "seeded FaultPlan produced two different virtual-clock "
            "reports)")
    if population["population_retraces"] > 0:
        failures.append(
            f"population_retraces={population['population_retraces']:.0f} "
            f"(compile-once contract broken)")
    if population["exec_speedup_x"] < EXEC_FLOOR:
        failures.append(
            f"exec_speedup_x={population['exec_speedup_x']:.2f} < "
            f"{EXEC_FLOOR:g} (bucketed population execution lost to the "
            f"sequential loop)")
    failures += _baseline_regressions(population, baseline)
    if mega["engaged_expected"] and mega["mega_dispatches"] < 1:
        failures.append(
            f"megakernel_sweep.mega_dispatches="
            f"{mega['mega_dispatches']} (backends resolve pallas and the "
            f"megakernel is armed, but no fused stage took the one-kernel "
            f"lowering)")
    if not (mega["parity_vs_switch"] and mega["parity_vs_unfused"]):
        failures.append(
            f"megakernel_sweep parity broken (vs_switch="
            f"{mega['parity_vs_switch']}, vs_unfused="
            f"{mega['parity_vs_unfused']}): the megakernel lowering is "
            f"not bit-identical to the fori_loop+switch path")
    if mega["steady_state_retraces"] > 0:
        failures.append(
            f"megakernel_sweep.steady_state_retraces="
            f"{mega['steady_state_retraces']} (dynamic weight steps "
            f"retraced a warmed megakernel executable)")
    if (structure["structural_deviation"]
            >= structure["weight_only_deviation"]):
        failures.append(
            f"structural_deviation={structure['structural_deviation']:.3f} "
            f">= weight_only {structure['weight_only_deviation']:.3f} "
            f"(structure search no longer beats weight-only tuning)")
    if structure["structure_engine_traces"] > 0:
        failures.append(
            f"structure_engine_traces="
            f"{structure['structure_engine_traces']:.0f} (structure "
            f"scoring executed the proxy)")
    if structure["structure_new_body_compiles"] > 0:
        failures.append(
            f"structure_new_body_compiles="
            f"{structure['structure_new_body_compiles']:.0f} (mutated "
            f"plans recompiled already-profiled components)")
    if ai_structure["deviation"] >= ai_structure["seed_deviation"]:
        failures.append(
            f"ai_structure.deviation={ai_structure['deviation']:.3f} >= "
            f"seed {ai_structure['seed_deviation']:.3f} (structure search "
            f"did not improve on the attention-free seed)")
    if not ai_structure["attention_class_used"]:
        failures.append(
            "ai_structure.attention_class_used is empty (the structural "
            "tuner reached an lm_train-style target without inserting any "
            "attention/recurrent dwarf component)")
    if ai_structure["engine_traces"] > 0:
        failures.append(
            f"ai_structure.engine_traces="
            f"{ai_structure['engine_traces']:.0f} (AI structure scoring "
            f"executed the proxy)")
    if ai_structure["new_body_compiles"] > 0:
        failures.append(
            f"ai_structure.new_body_compiles="
            f"{ai_structure['new_body_compiles']:.0f} (mutated plans "
            f"recompiled already-profiled AI components)")
    for name, row in sorted(distill["proxies"].items()):
        if not row["fingerprint_exact"]:
            failures.append(
                f"distill_sweep.{name}.fingerprint_exact=False (the "
                f"channel-basis fingerprint no longer reproduces the "
                f"measured metric dict — the basis went lossy)")
        if row["distilled_deviation"] > row["hand_deviation"] + 1e-9:
            failures.append(
                f"distill_sweep.{name}.distilled_deviation="
                f"{row['distilled_deviation']:.4f} > hand-target "
                f"{row['hand_deviation']:.4f} (tuning against the "
                f"measured fingerprint lost to the hand-declared target)")
        if row["engine_traces"] > 0:
            failures.append(
                f"distill_sweep.{name}.engine_traces="
                f"{row['engine_traces']:.0f} (fingerprint-target tuning "
                f"executed the proxy)")
        if row["new_body_compiles"] > 0:
            failures.append(
                f"distill_sweep.{name}.new_body_compiles="
                f"{row['new_body_compiles']:.0f} (fingerprint-target "
                f"tuning recompiled already-profiled components)")
    if not distill["full_coverage"]:
        failures.append(
            "distill_sweep.full_coverage=False (a fingerprint fell "
            "outside its cluster's recorded coverage bound)")
    for c in lm["cells"]:
        if c["acc"] <= 0:
            failures.append(
                f"lm_proxy cell {c['name']} accuracy == 0 "
                f"(dead bench row)")
    failures += _lm_baseline_regressions(lm, baseline)
    payload = {
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "kernel_backend": _resolved_backend(),
        "reference_proxy": REFERENCE,
        "run_path": run_path,
        "weight_sweep": sweep,
        "autotune_sweep": tune,
        "population_sweep": population,
        "plan_sweep": plan_sweep,
        "megakernel_sweep": mega,
        "structure_sweep": structure,
        "ai_structure_sweep": ai_structure,
        "distill_sweep": distill,
        "serve_sweep": serve,
        "serve_faults": serve_faults,
        "lm_proxy": lm,
        "gate_failures": failures,
        "engine_stats": engine.stats(),
        "stack_cache_stats": cache_stats(),
    }
    BENCH_JSON.write_text(json.dumps(payload, indent=1) + "\n")
    rows = _csv_rows(run_path, sweep, tune, population, plan_sweep, mega,
                     structure, ai_structure, distill, serve, serve_faults,
                     lm)
    if failures:
        for row in rows:           # the evidence still lands on failure
            print(row, flush=True)
        raise BenchGateError("; ".join(failures))
    return rows


def _csv_rows(run_path, sweep, tune, population, plan_sweep, mega,
              structure, ai_structure, distill, serve, serve_faults,
              lm) -> List[str]:
    return [
        csv_row("engine/run_path", run_path["steady_state_s"] * 1e6,
                f"first_s={run_path['first_call_s']:.3f};"
                f"steady_s={run_path['steady_state_s']:.4f};"
                f"amortization={run_path['compile_amortization_x']:.0f}x;"
                f"traces={run_path['traces']:.0f}"),
        csv_row("engine/weight_sweep", sweep["engine_s"] * 1e6,
                f"engine_s={sweep['engine_s']:.3f};"
                f"pre_pr_s={sweep['pre_pr_s']:.3f};"
                f"speedup={sweep['speedup_x']:.1f}x;"
                f"retraces={sweep['engine_retraces']:.0f}"),
        csv_row("engine/autotune_sweep", tune["engine_s"] * 1e6,
                f"engine_s={tune['engine_s']:.3f};"
                f"profile_s={tune['profile_s']:.3f};"
                f"speedup={tune['speedup_x']:.1f}x"),
        csv_row("engine/population_sweep", population["eval_population_s"] * 1e6,
                f"eval_speedup={population['speedup_x']:.1f}x;"
                f"exec_speedup={population['exec_speedup_x']:.1f}x;"
                f"bucket_speedup={population['bucket_speedup_x']:.1f}x;"
                f"retraces={population['population_retraces']:.0f};"
                f"executables_16={population['executables_16_candidates']:.0f};"
                f"tuner_smoke_s={population['tuner_smoke_s']:.2f}"),
        csv_row("engine/plan_sweep", 0.0,
                f"threshold={plan_sweep['fusion_threshold']:g};"
                f"ref_stages={len(plan_sweep['reference_partition'])};"
                f"buckets={plan_sweep['bucket_signature']};"
                f"trip_bounds={plan_sweep['bucket_trip_bounds']};"
                f"single_batch_trips={plan_sweep['single_batch_trip_bound']}"),
        csv_row("engine/megakernel_sweep", mega["mega_steady_s"] * 1e6,
                f"engaged={mega['engaged_expected']};"
                f"mega_dispatches={mega['mega_dispatches']};"
                f"fallbacks={mega['fallback_dispatches']};"
                f"stage_speedup={mega['stage_speedup_x']:.2f}x;"
                f"parity={mega['parity_vs_switch'] and mega['parity_vs_unfused']};"
                f"retraces={mega['steady_state_retraces']}"),
        csv_row("engine/structure_sweep", structure["structural_s"] * 1e6,
                f"structural_dev={structure['structural_deviation']:.3f};"
                f"weight_only_dev={structure['weight_only_deviation']:.3f};"
                f"budget={structure['budget']:.0f};"
                f"structures={structure['structures_scored']:.0f};"
                f"engine_traces={structure['structure_engine_traces']:.0f};"
                f"new_compiles="
                f"{structure['structure_new_body_compiles']:.0f}"),
        csv_row("engine/ai_structure", ai_structure["wall_s"] * 1e6,
                f"deviation={ai_structure['deviation']:.3f};"
                f"converged={ai_structure['converged']:.0f};"
                f"ai_used={'+'.join(ai_structure['ai_components_used'])};"
                f"attention_class="
                f"{'+'.join(ai_structure['attention_class_used'])};"
                f"engine_traces={ai_structure['engine_traces']:.0f};"
                f"new_compiles={ai_structure['new_body_compiles']:.0f}"),
        csv_row("engine/distill_sweep", distill["wall_s"] * 1e6,
                f"proxies={len(distill['proxies'])};"
                f"max_distilled_dev="
                f"{max(r['distilled_deviation'] for r in distill['proxies'].values()):.3f};"
                f"traces={sum(r['engine_traces'] for r in distill['proxies'].values()):.0f};"
                f"new_compiles="
                f"{sum(r['new_body_compiles'] for r in distill['proxies'].values()):.0f};"
                f"compression={distill['compression_x']:.1f}x;"
                f"coverage={distill['coverage']:.2f};"
                f"reps={'+'.join(distill['representatives'])}"),
        csv_row("engine/lm_proxy", lm["mean_accuracy"] * 100,
                f"cells={lm['n_cells']};"
                f"mean_acc={lm['mean_accuracy']:.3f};"
                f"min_acc={lm['min_accuracy']:.3f};"
                f"reduced={lm['n_reduced']}"),
        csv_row("engine/serve_sweep", serve["latency_p95_s"] * 1e6,
                f"p50_s={serve['latency_p50_s']:.4f};"
                f"p95_s={serve['latency_p95_s']:.4f};"
                f"p99_s={serve['latency_p99_s']:.4f};"
                f"throughput_rps={serve['throughput_rps']:.2f};"
                f"ttfr_s={serve['time_to_first_result_s']:.4f};"
                f"batch_speedup={serve['batch_speedup_x']:.2f}x;"
                f"retraces={serve['steady_state_retraces']};"
                f"warmup_compiles={serve['warmup_compiles']}"),
        csv_row("engine/serve_faults",
                serve_faults["chaos_latency_p99_s"] * 1e6,
                f"fault_rate={serve_faults['fault_rate']:g};"
                f"lost={serve_faults['lost_requests']};"
                f"failures={serve_faults['failures']};"
                f"retries={serve_faults['retries']};"
                f"retraces={serve_faults['steady_state_retraces']};"
                f"flush_p99_win="
                f"{serve_faults['flush_p99_improvement_x']:.2f}x;"
                f"deterministic="
                f"{serve_faults['virtual_chaos_deterministic']}"),
    ]


if __name__ == "__main__":
    for row in bench_compile_vs_run():
        print(row)
    print(f"wrote {BENCH_JSON}")
