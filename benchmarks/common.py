"""Shared benchmark plumbing: tuned-proxy cache, profiling helpers.

Benchmarks cache expensive artifacts (tuned proxy DAGs, full-scale original
profiles) under experiments/bench/ so ``python -m benchmarks.run`` stays
re-runnable; delete the directory (or REPRO_BENCH_REFRESH=1) to recompute.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, Optional, Tuple

from repro.api import ProxySpec
from repro.core import (ProxyBenchmark, characterize, vector_accuracy)
from repro.core.autotune import autotune
from repro.core.metrics import REPORT_METRICS
from repro.core.workloads import WORKLOADS, workload_step_fn

ROOT = Path(__file__).resolve().parents[1]
BENCH_DIR = ROOT / "experiments" / "bench"
SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")
EVAL_SCALE = os.environ.get("REPRO_BENCH_EVAL_SCALE", "full")
REFRESH = os.environ.get("REPRO_BENCH_REFRESH", "") == "1"

RATE_KEYS = ("mips", "mem_bw", "flop_rate")


def _proxy_from_json(d: Dict) -> ProxyBenchmark:
    # accepts current versioned specs and the seed's legacy bare-DAG dicts
    return ProxySpec.from_json(d).to_benchmark()


def original_profile(name: str, scale: str, execute: bool = True,
                     exec_iters: int = 2):
    fn, args = workload_step_fn(name, scale)
    return characterize(fn, args, name=f"{name}@{scale}", execute=execute,
                        exec_iters=exec_iters)


def tuned_proxy(name: str) -> Tuple[ProxyBenchmark, Dict]:
    """Table-3 proxy tuned per the paper's two-stage process (cached)."""
    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    path = BENCH_DIR / f"proxy_{name}_{SCALE}.json"
    if path.exists() and not REFRESH:
        d = json.loads(path.read_text())
        return _proxy_from_json(d["dag"]), d["tune_info"]
    target = original_profile(name, SCALE, execute=True).metrics
    proxy = WORKLOADS[name].make_proxy()
    # stage 1: structural metrics (no execution needed)
    res1 = autotune(proxy, target, tol=0.15, max_iter=25)
    # stage 2: rate metrics (IPC/MIPS/bandwidth analogs), measured
    res2 = autotune(res1.proxy, target, metric_keys=RATE_KEYS,
                    tol=0.15, max_iter=18, execute=True)
    info = {
        "structural": {"converged": res1.converged,
                       "iters": res1.iterations,
                       "profiles": res1.profiles_run,
                       "acc": res1.final_accuracy},
        "rates": {"converged": res2.converged, "iters": res2.iterations,
                  "acc": res2.final_accuracy},
    }
    spec = ProxySpec.from_benchmark(res2.proxy, scale=SCALE)
    path.write_text(json.dumps({"dag": spec.to_json(),
                                "tune_info": info}, indent=1))
    return res2.proxy, info


def evaluate_pair(name: str, scale: Optional[str] = None):
    """(orig_profile, proxy_profile, accuracy dict) at evaluation scale."""
    scale = scale or EVAL_SCALE
    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    cache = BENCH_DIR / f"eval_{name}_{scale}.json"
    proxy, _ = tuned_proxy(name)
    orig = original_profile(name, scale, execute=True)
    pp = proxy.profile(execute=True, exec_iters=3)
    keys = [k for k in REPORT_METRICS if k in orig.metrics and k in pp.metrics]
    acc = vector_accuracy(orig.metrics, pp.metrics, keys=keys)
    return orig, pp, acc


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
