#!/usr/bin/env python
"""Documentation checker: dangling references + runnable snippets.

Two jobs, both exposed as functions for the tier-1 test
(``tests/test_docs.py``) and as a CLI for CI's ``docs`` leg:

1. **Reference check** — every backticked token in the checked markdown
   files (README.md, docs/ARCHITECTURE.md, ROADMAP.md) that *looks like*
   a repo path (contains ``/`` or a known file extension) must exist on
   disk, and every dotted ``repro.*`` / ``benchmarks.*`` name must
   resolve to an importable module, optionally walking attributes with
   ``--import``.  Docs rot silently; this gate makes a rename that
   forgets its documentation a CI failure.
2. **Snippet check** — fenced ``python`` blocks whose first line is
   ``# doc-snippet`` are executed (``--run-snippets``), sharing one
   namespace per file in document order, so the examples users copy
   cannot drift from the API.

Exit status is non-zero on any dangling reference or failing snippet.

Usage::

    PYTHONPATH=src python tools/check_docs.py --import --run-snippets
"""

from __future__ import annotations

import argparse
import io
import re
import sys
from contextlib import redirect_stdout
from pathlib import Path
from typing import Dict, List, Tuple

ROOT = Path(__file__).resolve().parent.parent

#: the markdown files under contract
DOC_FILES = ("README.md", "docs/ARCHITECTURE.md", "ROADMAP.md")

#: extensions that mark a backticked token as a file reference
_FILE_EXTS = (".py", ".md", ".json", ".yml", ".yaml", ".toml", ".txt")

#: path-like tokens that intentionally name things outside this repo
#: (related-work idioms quoted from PAPERS.md / the retrieval set)
KNOWN_EXTERNAL = ("benchmark/config", "benchmark/Benchmarks.md")

#: importable roots the dotted-name check recognizes
_MODULE_ROOTS = ("repro", "benchmarks", "tools", "tests")

_TICK = re.compile(r"`([^`\n]+)`")
_FENCE = re.compile(r"```python\n(.*?)```", re.S)


def _is_path_token(tok: str) -> bool:
    # parens mark code (method chains like `a()/b()`), not paths
    if any(c in tok for c in "*<>{}$| ()=") or "://" in tok:
        return False
    if tok.startswith(("/", "~", "-", "#")):
        return False
    return "/" in tok or tok.endswith(_FILE_EXTS)


def _path_candidates(tok: str) -> List[Path]:
    tok = tok.rstrip("/")
    bases = (ROOT, ROOT / "src", ROOT / "src" / "repro",
             ROOT / "benchmarks", ROOT / "docs", ROOT / "tools",
             ROOT / "tests")
    out = []
    for b in bases:
        out.append(b / tok)
        if not tok.endswith(_FILE_EXTS):
            out.append(b / (tok + ".py"))
    return out


def _is_dotted_name(tok: str) -> bool:
    tok = tok.rstrip("()")
    if not re.fullmatch(r"[A-Za-z_][\w.]*", tok) or "." not in tok:
        return False
    return tok.split(".", 1)[0] in _MODULE_ROOTS


def _resolve_dotted(tok: str, do_import: bool) -> str:
    """'' when ``tok`` resolves, else the failure reason."""
    import importlib.util
    parts = tok.rstrip("()").split(".")
    # longest prefix that is a module on disk
    mod_parts = list(parts)
    while mod_parts:
        name = ".".join(mod_parts)
        try:
            if importlib.util.find_spec(name) is not None:
                break
        except (ImportError, ModuleNotFoundError, ValueError):
            pass
        mod_parts.pop()
    if not mod_parts:
        return "no importable module prefix"
    if not do_import:
        return ""
    import importlib
    try:
        obj = importlib.import_module(".".join(mod_parts))
    except Exception as e:                      # pragma: no cover - env issue
        return f"import failed: {e}"
    for attr in parts[len(mod_parts):]:
        if not hasattr(obj, attr):
            return (f"module {'.'.join(mod_parts)} has no attribute "
                    f"{attr!r}")
        obj = getattr(obj, attr)
    return ""


def check_references(path: Path, do_import: bool = False) -> List[str]:
    """Dangling backticked references in one markdown file."""
    text = path.read_text()
    # fenced code blocks are snippets, not references
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    problems = []
    seen = set()
    for tok in _TICK.findall(text):
        tok = tok.strip().rstrip(",;:")
        if tok in seen:
            continue
        seen.add(tok)
        if any(tok.startswith(p) for p in KNOWN_EXTERNAL):
            continue
        if _is_path_token(tok):
            if not any(c.exists() for c in _path_candidates(tok)):
                problems.append(f"{path.name}: dangling path `{tok}`")
        elif _is_dotted_name(tok):
            why = _resolve_dotted(tok, do_import)
            if why:
                problems.append(f"{path.name}: dangling name `{tok}` "
                                f"({why})")
    return problems


def extract_snippets(path: Path) -> List[Tuple[int, str]]:
    """(ordinal, code) for each ``# doc-snippet``-marked python fence."""
    out = []
    for i, code in enumerate(_FENCE.findall(path.read_text())):
        first = code.lstrip().splitlines()[0] if code.strip() else ""
        if first.strip() == "# doc-snippet":
            out.append((i, code))
    return out


def run_snippets(path: Path) -> List[str]:
    """Execute the file's marked snippets in one shared namespace."""
    problems = []
    ns: Dict[str, object] = {"__name__": f"doc_snippet:{path.name}"}
    for i, code in extract_snippets(path):
        try:
            with redirect_stdout(io.StringIO()):
                exec(compile(code, f"{path.name}:snippet{i}", "exec"), ns)
        except Exception as e:
            problems.append(f"{path.name} snippet #{i} raised "
                            f"{type(e).__name__}: {e}")
    return problems


def documented_api(md_text: str) -> List[str]:
    """The export names listed in ARCHITECTURE.md's "Public API" table —
    the surface ``tests/test_docs.py`` locks against
    ``repro.api.__all__``."""
    lines = md_text.splitlines()
    names: List[str] = []
    in_section = False
    for line in lines:
        if line.startswith("#"):
            in_section = "public api" in line.lower()
            continue
        if in_section and line.lstrip().startswith("|"):
            cells = [c.strip() for c in line.strip().strip("|").split("|")]
            if not cells or cells[0].startswith("-") or cells[0] in (
                    "Export", "Exports"):
                continue
            for m in _TICK.findall(cells[0]):
                names.extend(n.strip().rstrip("()")
                             for n in m.split(",") if n.strip())
    return names


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--import", dest="do_import", action="store_true",
                    help="resolve dotted names by real import + getattr")
    ap.add_argument("--run-snippets", action="store_true",
                    help="execute # doc-snippet fenced blocks")
    ap.add_argument("files", nargs="*", default=None,
                    help=f"markdown files to check (default: {DOC_FILES})")
    args = ap.parse_args(argv)

    files = [Path(f) for f in (args.files or
                               [ROOT / f for f in DOC_FILES])]
    problems = []
    for path in files:
        if not path.exists():
            problems.append(f"{path}: file missing")
            continue
        problems += check_references(path, do_import=args.do_import)
        if args.run_snippets:
            problems += run_snippets(path)
    for p in problems:
        print(f"FAIL {p}")
    if not problems:
        n = sum(len(extract_snippets(p)) for p in files if p.exists())
        print(f"docs OK: {len(files)} files, {n} snippets")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
